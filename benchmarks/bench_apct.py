"""Table 1: dataset profiling time (APCT construction) per graph."""
from __future__ import annotations

from benchmarks.common import emit
from repro.core.apct import APCT
from repro.graph import generators as gen


def run(scale: str = "small"):
    graphs = {
        "er-3k": gen.erdos_renyi(3000, 8.0, seed=1),
        "ws-8k": gen.small_world(8000, 8, 0.2, seed=2),
        "rmat-8k": gen.rmat(13, 10.0, seed=3),
        "tri-2k": gen.triangle_rich(2000, 60, seed=4),
    }
    for name, g in graphs.items():
        apct = APCT(g, num_samples=32768)
        emit(f"apct/profile/{name}", apct.profile_time_s * 1e6,
             f"entries={len(apct.table)}")


if __name__ == "__main__":
    run()
