"""Fig 29 + Table 7: scaling to larger patterns (k-chain mining) and
larger graphs (4-motif on an RMAT graph)."""
from __future__ import annotations

from benchmarks.common import emit, timeit
from repro.core.engine import MiningEngine
from repro.core.pattern import chain
from repro.graph import generators as gen


def run(scale: str = "small", kmax: int = 8):
    g = gen.erdos_renyi(3000, 8.0, seed=1)
    eng = MiningEngine(g)
    for k in range(3, kmax + 1):
        dt, c = timeit(eng.get_pattern_count, chain(k))
        emit(f"chains/er3000/{k}-CHM", dt * 1e6, f"count={c:.3e}")
    # larger-graph 4-motif (RMAT, Table 7 shape)
    g2 = gen.rmat(13, 12.0, seed=2)                  # 8192 vertices
    eng2 = MiningEngine(g2)
    dt, table = timeit(lambda: eng2.counter.motif_table(4))
    emit("chains/rmat8k/4-MC", dt * 1e6,
         f"total={sum(table.values()):.3e}")


if __name__ == "__main__":
    run()
