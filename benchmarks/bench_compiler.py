"""Compile-once-execute-many: the plan cache's serving-path payoff.

Three regimes over the same query stream (Q repeats of one application
pattern set):

  uncached   — every query re-runs decomposition search + costing and
               contracts with a fresh engine (the pre-compiler behaviour
               of ``MiningEngine.choose_cut`` per query);
  compiled   — compile the joint plan once, execute the lowered plan per
               query (warm plan cache + warm hom memo);
  cold-cache — one full compile per query but against a shared PlanCache,
               so queries 2..Q deserialise the cached plan (the cross-
               process steady state).

Emits microseconds per query and the uncached/compiled speedup.
"""
from __future__ import annotations

from benchmarks.common import bench_graphs, emit, timeit
from repro import compiler
from repro.compiler.cache import PlanCache
from repro.core.apct import APCT
from repro.core.counting import CountingEngine
from repro.core.engine import MiningEngine
from repro.core.motifs import motif_patterns
from repro.core.pattern import chain, tailed_triangle


def pattern_sets(k: int):
    return {
        f"{k}-motif": tuple(motif_patterns(k)),
        "chain+tail": (chain(4), chain(5), tailed_triangle()),
    }


def uncached_queries(g, pats, apct, q: int):
    for _ in range(q):
        eng = MiningEngine(g, apct=apct)      # fresh memo: no reuse
        for p in pats:
            eng.get_pattern_count(p, use_compiler=False)


def compiled_queries(cp, pats, q: int):
    for _ in range(q):
        for p in pats:
            cp.count(p)


def cached_compiles(g, pats, apct, cache, q: int):
    for _ in range(q):
        cp = compiler.compile(pats, g, apct=apct, cache=cache)
        for p in pats:
            cp.count(p)


def run(scale: str = "micro", k: int = 4, q: int = 10):
    graphs = bench_graphs(scale)
    for gname, g in graphs.items():
        apct = APCT(g, num_samples=8192)
        for sname, pats in pattern_sets(k).items():
            dt_un, _ = timeit(uncached_queries, g, pats, apct, q)
            emit(f"compiler/{gname}/{sname}/uncached",
                 dt_un / q * 1e6, f"q={q}")

            cache = PlanCache()
            counter = CountingEngine(g)
            dt_compile, cp = timeit(compiler.compile, pats, g, apct=apct,
                                    cache=cache, counter=counter)
            emit(f"compiler/{gname}/{sname}/compile", dt_compile * 1e6,
                 f"nodes={len(cp.plan.nodes)}")
            dt_c, _ = timeit(compiled_queries, cp, pats, q, warmup=True)
            emit(f"compiler/{gname}/{sname}/compiled", dt_c / q * 1e6,
                 f"speedup={dt_un / max(dt_c, 1e-12):.1f}x")

            dt_cc, _ = timeit(cached_compiles, g, pats, apct, cache, q)
            emit(f"compiler/{gname}/{sname}/cold-cache", dt_cc / q * 1e6,
                 f"hits={cache.hits}")


if __name__ == "__main__":
    run()
