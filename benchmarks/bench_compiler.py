"""Compile-once-execute-many: the plan cache's serving-path payoff.

Three regimes over the same query stream (Q repeats of one application
pattern set):

  uncached   — every query re-runs decomposition search + costing and
               contracts with a fresh engine (the pre-compiler behaviour
               of ``MiningEngine.choose_cut`` per query);
  compiled   — compile the joint plan once, execute the lowered plan per
               query (warm plan cache + warm hom memo);
  cold-cache — one full compile per query, each through a *fresh*
               PlanCache instance over a shared on-disk directory, so
               every query deserialises the cached plan from disk (the
               cross-process steady state).

Emits microseconds per query and the uncached/compiled speedup.
"""
from __future__ import annotations

import tempfile

from benchmarks.common import bench_graphs, emit, save_json, timeit
from repro import compiler
from repro.compiler.cache import PlanCache
from repro.core.apct import APCT
from repro.core.counting import CountingEngine
from repro.core.engine import MiningEngine
from repro.core.motifs import motif_patterns
from repro.core.pattern import chain, tailed_triangle


def pattern_sets(k: int):
    return {
        f"{k}-motif": tuple(motif_patterns(k)),
        "chain+tail": (chain(4), chain(5), tailed_triangle()),
    }


def uncached_queries(g, pats, apct, q: int):
    for _ in range(q):
        eng = MiningEngine(g, apct=apct)      # fresh memo: no reuse
        for p in pats:
            eng.get_pattern_count(p, use_compiler=False)


def compiled_queries(cp, pats, q: int):
    for _ in range(q):
        for p in pats:
            cp.count(p)


def cached_compiles(g, pats, apct, path: str, q: int):
    """Each query simulates a fresh process: a new PlanCache over the
    same directory, so the plan really is deserialised from disk."""
    hits = 0
    for _ in range(q):
        cache = PlanCache(path)
        cp = compiler.compile(pats, g, apct=apct, cache=cache)
        for p in pats:
            cp.count(p)
        hits += cache.hits
    return hits


def run(scale: str = "micro", k: int = 4, q: int = 10):
    graphs = bench_graphs(scale)
    for gname, g in graphs.items():
        apct = APCT(g, num_samples=8192)
        for sname, pats in pattern_sets(k).items():
            dt_un, _ = timeit(uncached_queries, g, pats, apct, q)
            emit(f"compiler/{gname}/{sname}/uncached",
                 dt_un / q * 1e6, f"q={q}")

            with tempfile.TemporaryDirectory() as tmp:
                cache = PlanCache(tmp)
                counter = CountingEngine(g)
                dt_compile, cp = timeit(compiler.compile, pats, g,
                                        apct=apct, cache=cache,
                                        counter=counter)
                emit(f"compiler/{gname}/{sname}/compile", dt_compile * 1e6,
                     f"nodes={len(cp.plan.nodes)}")
                dt_c, _ = timeit(compiled_queries, cp, pats, q, warmup=True)
                emit(f"compiler/{gname}/{sname}/compiled", dt_c / q * 1e6,
                     f"speedup={dt_un / max(dt_c, 1e-12):.1f}x")

                dt_cc, hits = timeit(cached_compiles, g, pats, apct, tmp, q)
                emit(f"compiler/{gname}/{sname}/cold-cache", dt_cc / q * 1e6,
                     f"hits={hits}")


def main():
    import argparse
    from benchmarks.common import RESULTS
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one micro configuration (CI), JSON results")
    args = ap.parse_args()
    start = len(RESULTS)
    if args.smoke:
        run(scale="micro", k=3, q=5)
    else:
        run()
    save_json("compiler", start)


if __name__ == "__main__":
    main()
