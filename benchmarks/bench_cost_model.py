"""Fig 22: cost-model accuracy — estimated cost vs actual runtime for
random decompositions, APCT model vs AutoMine random-graph model
(correlation coefficients)."""
from __future__ import annotations

import random
import time

import numpy as np

from benchmarks.common import bench_graphs, emit
from repro.core import cost_model as CM
from repro.core.apct import APCT
from repro.core.counting import CountingEngine
from repro.core.decomposition import candidates
from repro.core.motifs import motif_patterns


def run(scale: str = "small", k: int = 5, num_algos: int = 40, seed: int = 0):
    g = bench_graphs("micro")["cs-like"]
    apct = APCT(g, num_samples=8192)
    rng = random.Random(seed)
    pats = motif_patterns(k)
    deg = float(np.mean(g.degrees))

    actual, est_apct, est_am = [], [], []
    for i in range(num_algos):
        p = rng.choice(pats)
        cut = rng.choice(candidates(p))
        eng = CountingEngine(g)
        t0 = time.perf_counter()
        eng.edge_induced(p, cut=cut)
        actual.append(time.perf_counter() - t0)
        est_apct.append(CM.pattern_cost(p, cut, apct, g.n))
        est_am.append(CM.pattern_cost_automine(p, cut, g.n, deg))

    r_apct = float(np.corrcoef(np.log1p(actual), np.log1p(est_apct))[0, 1])
    r_am = float(np.corrcoef(np.log1p(actual), np.log1p(est_am))[0, 1])
    emit("cost_model/corr/apct", r_apct * 1000, f"r={r_apct:.3f}")
    emit("cost_model/corr/automine", r_am * 1000, f"r={r_am:.3f}")
    # the chosen-best check of Fig 22's discussion
    best_pred = int(np.argmin(est_apct))
    emit("cost_model/chosen_vs_best", actual[best_pred] * 1e6,
         f"fastest={min(actual) * 1e6:.0f}us")
    return r_apct, r_am


if __name__ == "__main__":
    run()
