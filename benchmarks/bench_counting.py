"""Tables 4/5: decomposition-based counting vs direct enumeration.

The 'AutoMine' baseline of the paper maps to the direct tensor contraction
of each pattern with a greedy plan and no cross-pattern reuse, no
cost-model decomposition; DwarvesGraph = cost-model-chosen cuts + shared
quotient pool + vertex-induced overlay.
"""
from __future__ import annotations

from benchmarks.common import bench_graphs, emit, timeit
from repro.core.apct import APCT
from repro.core.counting import CountingEngine, solve_overlay
from repro.core.engine import MiningEngine
from repro.core.motifs import motif_patterns


def direct_motifs(g, k):
    """Baseline: fresh engine per pattern (no reuse), greedy plans."""
    e = {}
    for p in motif_patterns(k):
        eng = CountingEngine(g)                # no shared memo
        e[p] = eng.edge_induced(p, cut=None)
    return solve_overlay(k, e)


def dwarves_motifs(g, k, cuts, apct=None):
    eng = MiningEngine(g, apct=apct)
    return eng.counter.motif_table(k, cuts=cuts)


def run(scale: str = "small", ks=(3, 4, 5)):
    import time as _t
    graphs = bench_graphs(scale)
    if 5 in ks:
        # width-3 contractions of 5-pattern quotients need a small N
        graphs["cs-micro"] = bench_graphs("micro")["cs-like"]
    for gname, g in graphs.items():
        apct = APCT(g, num_samples=8192)
        for k in ks:
            if k >= 5 and gname != "cs-micro":
                continue                   # keep the harness tractable
            # decomposition search = compile time (paper's ST), reported
            # separately from the counting runtime (RT)
            eng0 = MiningEngine(g, apct=apct)
            t0 = _t.perf_counter()
            cuts = {p: eng0.choose_cut(p) for p in motif_patterns(k)}
            st = _t.perf_counter() - t0
            td, table_d = timeit(dwarves_motifs, g, k, cuts, apct,
                                 warmup=True)
            tb, table_b = timeit(direct_motifs, g, k, warmup=True)
            emit(f"counting/{gname}/{k}-MC/search", st * 1e6, "")
            # correctness cross-check between the two paths
            for p in table_d:
                assert abs(table_d[p] - table_b[p]) < 1e-6 * \
                    max(1.0, abs(table_b[p])) + 1e-6, (gname, k, p)
            emit(f"counting/{gname}/{k}-MC/dwarves", td * 1e6,
                 f"speedup={tb / max(td, 1e-12):.2f}x")
            emit(f"counting/{gname}/{k}-MC/direct", tb * 1e6, "")
    _vs_loop_enumeration()


def _vs_loop_enumeration():
    """Tensor engine vs host nested-loop enumeration (the AutoMine-style
    baseline the paper's Table 4 speedups are measured against)."""
    from repro.core.counting import brute_force_edge_induced
    g = bench_graphs("micro")["cs-like"]
    eng = MiningEngine(g)
    for k in (3, 4):
        pats = motif_patterns(k)
        cuts = {p: eng.choose_cut(p) for p in pats}
        te, _ = timeit(lambda: [eng.counter.edge_induced(p, cut=cuts[p])
                                for p in pats], warmup=True)
        tl, _ = timeit(lambda: [brute_force_edge_induced(g, p)
                                for p in pats])
        emit(f"counting/vs-loops/{k}-MC/tensor", te * 1e6,
             f"speedup_vs_nested_loops={tl / max(te, 1e-12):.1f}x")
        emit(f"counting/vs-loops/{k}-MC/nested-loops", tl * 1e6, "")


if __name__ == "__main__":
    run()
