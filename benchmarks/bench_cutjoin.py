"""CutJoin execution tiers: Pallas masked-reduce kernel vs the XLA
``_join_reduce`` (dense factor stack x materialised mask) vs the legacy
direct contraction path.

Two levels:

* primitive — synthetic integer cut tensors, |cut| in {1, 2}, timing one
  join evaluation per tier (the mask the XLA tier needs is prebuilt and
  amortised, which flatters it; the kernel never builds one);
* end-to-end — a decomposed tailed-triangle plan against an ER graph,
  timing a full compiled count with the kernel tier on/off, plus the
  legacy ``CountingEngine.edge_induced`` direct path.

Run: PYTHONPATH=src python benchmarks/bench_cutjoin.py [--scale small]
"""
from __future__ import annotations

import sys

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, "benchmarks")
from common import emit, timeit

from repro.graph import generators as gen
from repro.kernels import ops
from repro.compiler import frontend, lowering


def _factors(n: int, cut: int, k: int, seed: int):
    rng = np.random.default_rng(seed)
    shape = (n,) * cut
    return [rng.integers(0, 8, size=shape).astype(np.float64)
            for _ in range(k)]


def bench_primitive(n: int, cut: int, k: int = 2, repeat: int = 0):
    repeat = repeat or (50 if cut == 1 else 20)
    Ms = _factors(n, cut, k, seed=n + cut)

    # the same routing the compiler uses: chunk size from the exactness
    # guard (per-chunk f32 partials provably exact on integer factors)
    block = ops.cutjoin_exact_block(Ms)
    assert block is not None

    dt, got_k = timeit(lambda: ops.cutjoin_reduce(Ms, distinct=cut >= 2,
                                                  bm=block, bn=block),
                       repeat=repeat, warmup=True)
    emit(f"cutjoin/kernel/n={n}/cut={cut}", dt * 1e6)

    mask = None
    if cut >= 2:
        mask = 1.0 - np.eye(n)              # prebuilt: amortises the XLA tier

    def xla_join():
        with jax.experimental.enable_x64():
            stack = [jnp.asarray(M) for M in Ms]
            if mask is not None:
                stack.append(jnp.asarray(mask))
            return float(lowering._join_reduce(jnp.stack(stack)))

    dt, got_x = timeit(xla_join, repeat=repeat, warmup=True)
    emit(f"cutjoin/xla/n={n}/cut={cut}", dt * 1e6)
    assert got_k == got_x, (n, cut, got_k, got_x)


def bench_end_to_end(n: int, repeat: int = 3):
    from repro.core.counting import CountingEngine
    from repro.core.pattern import cycle
    g = gen.erdos_renyi(n, 8.0, seed=11)
    p = cycle(4)                            # cut {0, 2}: a true 2-cut join
    cand = frontend.decomposed_candidate(p, frozenset({0, 2}), graph_n=g.n)
    plan = frontend.assemble([(p, cand)])

    join = next(node for node in plan.nodes.values()
                if type(node).__name__ == "CutJoin")
    eng = CountingEngine(g)
    cp = lowering.lower(plan, g, counter=eng, cutjoin_kernel=True)
    cp.count(p)                             # materialise factor tensors
    dt, got_k = timeit(lambda: cp._eval_cutjoin(join), repeat=repeat,
                       warmup=True)
    emit(f"cutjoin/e2e-kernel/n={n}", dt * 1e6)

    cx = lowering.lower(plan, g, counter=eng, cutjoin_kernel=False)
    cx.count(p)
    dt, got_x = timeit(lambda: cx._eval_cutjoin(join), repeat=repeat,
                       warmup=True)
    emit(f"cutjoin/e2e-xla/n={n}", dt * 1e6)
    assert got_k == got_x, (got_k, got_x)

    dt, got_d = timeit(lambda: CountingEngine(g).edge_induced(p), repeat=1,
                       warmup=False)
    emit(f"cutjoin/e2e-direct/n={n}", dt * 1e6)
    assert abs(got_d - cp.count(p)) < 1e-6, (got_d, cp.count(p))


def main():
    sizes = (512, 1024) if "--scale" not in sys.argv else (512,)
    for n in sizes:
        for cut in (1, 2):
            bench_primitive(n, cut)
    bench_end_to_end(512)


if __name__ == "__main__":
    main()
