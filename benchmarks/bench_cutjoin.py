"""CutJoin execution tiers: Pallas masked-reduce kernels vs the XLA
dense-mask joins vs the legacy direct contraction path.

Three levels:

* primitive — synthetic integer cut tensors, |cut| in {1, 2, 3}, timing
  one join evaluation per tier (the mask the XLA tier needs is prebuilt
  and amortised for |cut| <= 2, which flatters it; the |cut| = 3 XLA
  join builds its O(n³) mask the way the lowered fallback does — that
  materialisation is precisely what the tri kernel avoids).  The tri
  regime times both factor mixes: pair-tensor-only (the axis-subset
  form, e.g. a 6-cycle over cut {0,2,4}) and genuinely 3-D factors
  (e.g. 5-clique minus an edge);
* end-to-end 2-cut — a decomposed tailed-triangle plan against an ER
  graph, timing a full compiled count with the kernel tier on/off, plus
  the legacy ``CountingEngine.edge_induced`` direct path;
* end-to-end 3-cut — 5-clique minus an edge (its only cutting set has
  three vertices): the committed tri-join plan with the kernel on vs
  the XLA dense-mask fallback vs the best plan ``max_cutjoin_cut=2``
  can offer (the dense Möbius route — no eligible narrow cut exists),
  vs the legacy direct engine.  Counts must agree bit-for-bit.

Run:  PYTHONPATH=src python -m benchmarks.bench_cutjoin [--smoke]
``--smoke`` runs the tiny CI configuration; either way the rows land in
``benchmarks/results/BENCH_cutjoin.json`` for the trend renderer.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, save_json, timeit
from repro.graph import generators as gen
from repro.kernels import ops
from repro.compiler import frontend, lowering
from repro.core.pattern import Pattern

K5_MINUS_EDGE = Pattern(5, [(u, v) for u in range(5)
                            for v in range(u + 1, 5) if (u, v) != (3, 4)])


def _factors(n: int, cut: int, k: int, seed: int):
    rng = np.random.default_rng(seed)
    shape = (n,) * cut
    return [rng.integers(0, 8, size=shape).astype(np.float64)
            for _ in range(k)]


def bench_primitive(n: int, cut: int, k: int = 2, repeat: int = 0):
    repeat = repeat or (50 if cut == 1 else 20)
    Ms = _factors(n, cut, k, seed=n + cut)

    # the same routing the compiler uses: chunk size from the exactness
    # guard (per-chunk f32 partials provably exact on integer factors)
    block = ops.cutjoin_exact_block(Ms)
    assert block is not None

    dt, got_k = timeit(lambda: ops.cutjoin_reduce(Ms, distinct=cut >= 2,
                                                  bm=block, bn=block),
                       repeat=repeat, warmup=True)
    emit(f"cutjoin/kernel/n={n}/cut={cut}", dt * 1e6)

    mask = None
    if cut >= 2:
        mask = 1.0 - np.eye(n)              # prebuilt: amortises the XLA tier

    def xla_join():
        with jax.experimental.enable_x64():
            stack = [jnp.asarray(M) for M in Ms]
            if mask is not None:
                stack.append(jnp.asarray(mask))
            return float(lowering._join_reduce(jnp.stack(stack)))

    dt, got_x = timeit(xla_join, repeat=repeat, warmup=True)
    emit(f"cutjoin/xla/n={n}/cut={cut}", dt * 1e6)
    assert got_k == got_x, (n, cut, got_k, got_x)


def _tri_mask(n: int) -> np.ndarray:
    x = np.arange(n)
    return (((x[:, None, None] != x[None, :, None])
             & (x[:, None, None] != x[None, None, :])
             & (x[None, :, None] != x[None, None, :]))
            .astype(np.float64))


def bench_primitive3(n: int, mix: str, repeat: int = 5):
    """|cut| = 3 regime: the tri kernel (axis-subset factors broadcast
    per tile, in-kernel mask) vs the XLA dense path (factors expanded to
    n³, O(n³) mask materialised — what the lowered fallback pays)."""
    rng = np.random.default_rng(n)
    if mix == "pairs":                      # 6-cycle-style axis-subset join
        axes = [(0, 1), (1, 2), (0, 2)]
    else:                                   # K5-minus-edge-style 3-D factors
        axes = [(0, 1, 2), (0, 1, 2)]
    Ms = [rng.integers(0, 6, size=(n,) * len(ax)).astype(np.float64)
          for ax in axes]
    block = ops.cutjoin_exact_block(Ms)
    assert block is not None

    dt_k, got_k = timeit(lambda: ops.cutjoin_reduce3(Ms, axes, n=n,
                                                     block=block),
                         repeat=repeat, warmup=True)
    emit(f"cutjoin/kernel3/{mix}/n={n}", dt_k * 1e6)

    def xla_join():
        with jax.experimental.enable_x64():
            stack = [jnp.asarray(np.broadcast_to(
                M.reshape(tuple(n if a in ax else 1 for a in range(3))),
                (n, n, n))) for M, ax in zip(Ms, axes)]
            stack.append(jnp.asarray(_tri_mask(n)))   # the O(n³) mask
            return float(lowering._join_reduce(jnp.stack(stack)))

    dt_x, got_x = timeit(xla_join, repeat=max(repeat // 2, 1), warmup=True)
    emit(f"cutjoin/xla3/{mix}/n={n}", dt_x * 1e6,
         f"kernel_speedup={dt_x / max(dt_k, 1e-12):.1f}x")
    assert got_k == got_x, (n, mix, got_k, got_x)


def bench_end_to_end(n: int, repeat: int = 3):
    from repro.core.counting import CountingEngine
    from repro.core.pattern import cycle
    g = gen.erdos_renyi(n, 8.0, seed=11)
    p = cycle(4)                            # cut {0, 2}: a true 2-cut join
    cand = frontend.decomposed_candidate(p, frozenset({0, 2}), graph_n=g.n)
    plan = frontend.assemble([(p, cand)])

    join = next(node for node in plan.nodes.values()
                if type(node).__name__ == "CutJoin")
    eng = CountingEngine(g)
    cp = lowering.lower(plan, g, counter=eng, cutjoin_kernel=True)
    cp.count(p)                             # materialise factor tensors
    dt, got_k = timeit(lambda: cp._eval_cutjoin(join), repeat=repeat,
                       warmup=True)
    emit(f"cutjoin/e2e-kernel/n={n}", dt * 1e6)

    cx = lowering.lower(plan, g, counter=eng, cutjoin_kernel=False)
    cx.count(p)
    dt, got_x = timeit(lambda: cx._eval_cutjoin(join), repeat=repeat,
                       warmup=True)
    emit(f"cutjoin/e2e-xla/n={n}", dt * 1e6)
    assert got_k == got_x, (got_k, got_x)

    dt, got_d = timeit(lambda: CountingEngine(g).edge_induced(p), repeat=1,
                       warmup=False)
    emit(f"cutjoin/e2e-direct/n={n}", dt * 1e6)
    assert abs(got_d - cp.count(p)) < 1e-6, (got_d, cp.count(p))


def bench_end_to_end3(n: int, repeat: int = 2, direct: bool = True):
    """The acceptance regime: a pattern whose best (only) cutting set
    has |cut| = 3.  The compiler must commit the 3-cut plan, and the
    tri kernel must beat both the XLA dense-mask fallback and the best
    ``max_cutjoin_cut=2`` plan, counts bit-for-bit equal."""
    from repro import compiler
    from repro.core.counting import CountingEngine
    from repro.compiler.ir import CutJoin
    g = gen.erdos_renyi(n, 10.0, seed=7)
    p = K5_MINUS_EDGE

    eng = CountingEngine(g)
    cp = compiler.compile((p,), g, counter=eng, cache=False)
    join = next(node for node in cp.plan.nodes.values()
                if isinstance(node, CutJoin))
    assert join.cut_size == 3, "compiler did not commit the 3-cut plan"
    cp.count(p)                             # materialise factor tensors
    dt_k, got_k = timeit(lambda: cp._eval_cutjoin(join), repeat=repeat,
                         warmup=True)
    emit(f"cutjoin/e2e3-kernel/n={n}", dt_k * 1e6)

    cx = lowering.lower(cp.plan, g, counter=eng, cutjoin_kernel=False)
    cx.count(p)
    dt_x, got_x = timeit(lambda: cx._eval_cutjoin(join), repeat=1,
                         warmup=True)
    emit(f"cutjoin/e2e3-xla-densemask/n={n}", dt_x * 1e6,
         f"kernel_speedup={dt_x / max(dt_k, 1e-12):.1f}x")
    assert got_k == got_x, (got_k, got_x)

    # the best |cut| <= 2 the compiler can offer for this pattern is the
    # dense Möbius route (no eligible narrow cutting set exists): time
    # the full count on a fresh engine — same for the committed plan
    dt, cnt2 = timeit(
        lambda: compiler.compile((p,), g, counter=CountingEngine(g),
                                 cache=False,
                                 max_cutjoin_cut=2).count(p),
        repeat=1)
    emit(f"cutjoin/e2e3-forced-cut2/n={n}", dt * 1e6)
    dt, cnt3 = timeit(
        lambda: compiler.compile((p,), g, counter=CountingEngine(g),
                                 cache=False).count(p),
        repeat=1)
    emit(f"cutjoin/e2e3-tri-plan-full/n={n}", dt * 1e6)
    assert cnt3 == cnt2, (cnt3, cnt2)

    if direct:
        dt, got_d = timeit(lambda: CountingEngine(g).edge_induced(p),
                           repeat=1)
        emit(f"cutjoin/e2e3-direct/n={n}", dt * 1e6)
        assert got_d == cnt3, (got_d, cnt3)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI configuration")
    ap.add_argument("--scale", default=None, help="legacy small-scale flag")
    args = ap.parse_args(argv)

    if args.smoke:
        sizes, tri_sizes = (256,), (128,)
    elif args.scale:
        sizes, tri_sizes = (512,), (256,)
    else:
        sizes, tri_sizes = (512, 1024), (256, 512)

    for n in sizes:
        for cut in (1, 2):
            bench_primitive(n, cut)
    for n in tri_sizes:
        for mix in ("pairs", "tri"):
            bench_primitive3(n, mix)
    bench_end_to_end(256 if args.smoke else 512)
    bench_end_to_end3(tri_sizes[-1], direct=not args.smoke)
    save_json("cutjoin")


if __name__ == "__main__":
    main()
