"""Fig 30 + FSM rows of Tables 4/5: FSM runtime across support thresholds
(3-FSM and 4-FSM on a labelled clustered graph).

Three regimes per (k, support) cell, same lattice walk:

  legacy   — the pre-refactor per-vertex path: one Möbius expansion per
             pattern vertex, H.hom_count called directly (no memo);
  batched  — the vectorised fallback: one ``inj_free_all`` matrix per
             pattern through the shared engine's canonical free-hom memo;
  compiled — level-wise joint compilation: one
             ``compiler.compile(frontier, domains=True)`` per lattice
             level, domains per automorphism orbit, CSE across siblings.

``--smoke`` runs one tiny configuration (CI) and writes
``benchmarks/results/BENCH_fsm.json`` either way.
"""
from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import emit, save_json, timeit
from repro.core import homomorphism as H
from repro.core.counting import CountingEngine
from repro.core.fsm import fsm
from repro.core.quotient import mobius, partitions
from repro.graph import generators as gen


def _legacy_mini_support(counter: CountingEngine, p) -> int:
    """The pre-refactor MINI support: p.n separate inj expansions, each
    contracting afresh (no cross-vertex, cross-pattern, or cross-level
    reuse) — the baseline the compiled path is measured against."""
    sup = counter.graph.n
    with counter._x64():                   # exact f64, as the seed path
        for v in range(p.n):
            total = np.zeros(counter.graph.n)
            for sigma in partitions(tuple(range(p.n))):
                q, blk = p.quotient_with_map(sigma)
                if q is None:
                    continue
                vec = H.hom_count(q, counter.A, free=(blk[v],),
                                  unary=counter._unary_for(q),
                                  budget=counter.budget)
                total = total + mobius(sigma) * np.asarray(vec, np.float64)
            sup = min(sup, int(np.count_nonzero(total > 0.5)))
    return sup


def _cell(g, support: int, kv: int, apct):
    """One (k, support) cell: run all three regimes on fresh engines."""
    dt_l, r_l = timeit(fsm, g, support, kv, None, CountingEngine(g),
                       use_compiler=False,
                       support_fn=_legacy_mini_support)
    dt_b, r_b = timeit(fsm, g, support, kv, None, CountingEngine(g),
                       use_compiler=False)
    dt_c, r_c = timeit(fsm, g, support, kv, None, CountingEngine(g),
                       apct=apct, plan_cache=False)
    assert r_l.frequent == r_b.frequent == r_c.frequent, \
        "FSM regimes disagree"
    tag = f"fsm/{kv}-FSM/sup{support}"
    emit(f"{tag}/legacy", dt_l * 1e6,
         f"frequent={len(r_l.frequent)} pruned={r_l.pruned}")
    emit(f"{tag}/batched", dt_b * 1e6,
         f"speedup={dt_l / max(dt_b, 1e-12):.1f}x")
    emit(f"{tag}/compiled", dt_c * 1e6,
         f"speedup={dt_l / max(dt_c, 1e-12):.1f}x "
         f"levels={r_c.compiled_levels}/{r_c.levels}")


def run(scale: str = "small"):
    from repro.core.apct import APCT
    if scale == "smoke":
        g = gen.triangle_rich(240, 8, seed=5, num_labels=3)
        cells = [(3, 20), (3, 60)]
    else:
        g = gen.triangle_rich(800, 24, seed=5, num_labels=6)
        # max seed support on this graph is ~92; low thresholds explode
        # the candidate set (4-FSM sup30 mines 670 patterns in ~10 min)
        cells = [(3, s) for s in (50, 100, 300, 1000)] + \
                [(4, s) for s in (80, 100, 300, 1000)]
    apct = APCT(g, num_samples=4096)
    for kv, support in cells:
        _cell(g, support, kv, apct)


def main():
    from benchmarks.common import RESULTS
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one tiny configuration (CI)")
    ap.add_argument("--scale", default="small")
    args = ap.parse_args()
    start = len(RESULTS)
    run("smoke" if args.smoke else args.scale)
    save_json("fsm", start)


if __name__ == "__main__":
    main()
