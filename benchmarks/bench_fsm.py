"""Fig 30 + FSM rows of Tables 4/5: FSM runtime across support thresholds
(3-FSM and 4-FSM on a labelled clustered graph)."""
from __future__ import annotations

from benchmarks.common import emit, timeit
from repro.core.counting import CountingEngine
from repro.core.fsm import fsm
from repro.graph import generators as gen


def run(scale: str = "small"):
    g = gen.triangle_rich(800, 24, seed=5, num_labels=6)
    counter = CountingEngine(g)
    for kv in (3, 4):
        # max seed support on this graph is ~92; low thresholds explode
        # the candidate set (4-FSM sup30 mines 670 patterns in ~10 min)
        for support in ((50, 100, 300, 1000) if kv == 3
                        else (80, 100, 300, 1000)):
            dt, r = timeit(fsm, g, support, kv, None, counter)
            emit(f"fsm/{kv}-FSM/sup{support}", dt * 1e6,
                 f"frequent={len(r.frequent)} pruned={r.pruned}")


if __name__ == "__main__":
    run()
