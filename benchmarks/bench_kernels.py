"""Kernel-level roofline deltas (supports §Perf): HBM traffic of the
Pallas kernels vs the XLA lowering of the same computation, computed
analytically from the BlockSpecs (the kernels execute in interpret mode
here; on TPU the same BlockSpecs bound the traffic)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timeit
from repro.kernels import ops


def _xla_triangle_bytes(n: int) -> int:
    # A@A materialised (n*n f32 write + read) + two A reads + product read
    return 4 * n * n * 4


def _kernel_triangle_bytes(n: int, bm=128, bn=128, bk=128) -> int:
    # per grid step: lhs tile + rhs tile + mask tile; product stays in VMEM
    steps = (n // bm) * (n // bn) * (n // bk)
    return steps * (bm * bk + bn * bk + bm * bn) * 4


def run(scale: str = "small"):
    from repro.graph.generators import erdos_renyi
    for n in (512, 1024):
        g = erdos_renyi(n, 12.0, seed=1)
        adj = g.dense_adjacency(np.float32, pad=True)
        npad = adj.shape[0]
        dt, cnt = timeit(lambda: float(ops.triangle_count(adj,
                                                          interpret=True)))
        xb = _xla_triangle_bytes(npad)
        kb = _kernel_triangle_bytes(npad)
        emit(f"kernels/triangle/{n}", dt * 1e6,
             f"hbm_xla={xb / 1e6:.1f}MB hbm_kernel={kb / 1e6:.1f}MB "
             f"saving={xb / kb:.2f}x count={cnt:.0f}")
    # flash attention traffic: score tensor never leaves VMEM
    B, S, H, D, bq, bk = 1, 2048, 8, 128, 128, 128
    xla_scores = B * H * (S // bq) * S * bq * 4 * 3     # s, p r/w per block
    kern = B * H * S * D * 2 * 4                         # q,k,v,o tiles
    emit("kernels/flashattn/2048", 0.0,
         f"score_traffic_removed={xla_scores / 1e9:.2f}GB "
         f"kernel_io={kern / 1e9:.3f}GB")


if __name__ == "__main__":
    run()
