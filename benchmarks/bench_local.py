"""Partial-embedding API timings: local counts off the decomposition
join vs the routes that rebuild them.

Regimes per (pattern, graph) cell:

  direct    — the flat Möbius anchored route (one inj_free expansion per
              anchor, the route a system without decomposition reuse
              pays), fresh engine;
  compiled  — ``compiler.compile(local=True)`` once, then every anchored
              vector and the full local tensor read off the plan
              (repeat-query regime: plan + node-value memos warm);
  kernel    — the |cut| = 2 keep-axis Pallas reduce vs the XLA
              mask-and-sum on synthetic integer factors (the raw kernel
              tier the anchored path routes through).

``--smoke`` runs one tiny configuration (CI) and writes
``benchmarks/results/BENCH_local.json`` either way.
"""
from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import emit, save_json, timeit
from repro.core.counting import CountingEngine
from repro.core.pattern import Pattern, chain, cycle, tailed_triangle
from repro.graph import generators as gen


def _direct_all_anchors(g, p):
    eng = CountingEngine(g)
    from repro.api import local_counts
    return [local_counts(p, g, anchor=o[0], counter=eng,
                         use_compiler=False).counts
            for o in p.vertex_orbits()]


def _compiled_all_anchors(cp, p):
    return [cp.local_counts(p, o[0]) for o in p.vertex_orbits()]


def _cell(g, gname, p, pname):
    from repro import compiler
    dt_d, vecs_d = timeit(_direct_all_anchors, g, p)
    cp = compiler.compile((p,), g, counter=CountingEngine(g),
                          cache=False, local=True)
    dt_c, vecs_c = timeit(_compiled_all_anchors, cp, p, warmup=True)
    for a, b in zip(vecs_d, vecs_c):
        assert np.array_equal(a, b), "regimes disagree"
    tag = f"local/{gname}/{pname}"
    emit(f"{tag}/direct", dt_d * 1e6, f"orbits={len(vecs_d)}")
    emit(f"{tag}/compiled", dt_c * 1e6,
         f"speedup={dt_d / max(dt_c, 1e-12):.1f}x")


def _kernel_cell(n: int, k: int):
    from repro.kernels import ops
    rng = np.random.default_rng(n + k)
    Fs = [rng.integers(0, 5, size=(n, n)).astype(np.float64)
          for _ in range(k)]

    def xla(Fs):
        prod = np.ones((n, n))
        for F in Fs:
            prod *= F
        np.fill_diagonal(prod, 0.0)
        return prod.sum(axis=1)

    dt_k, out_k = timeit(ops.cutjoin_reduce_keep, Fs, keep=0, warmup=True)
    dt_x, out_x = timeit(xla, Fs)
    assert np.array_equal(out_k, out_x), "kernel vs host disagree"
    emit(f"local/keep-kernel/n{n}/f{k}", dt_k * 1e6,
         f"host={dt_x * 1e6:.1f}us")


def run(scale: str = "small"):
    if scale == "smoke":
        graphs = {"cs-like": gen.triangle_rich(256, 12, seed=1)}
        kernel_ns = [256]
    else:
        graphs = {"cs-like": gen.triangle_rich(1200, 40, seed=1),
                  "wk-like": gen.erdos_renyi(1500, 14.0, seed=2)}
        kernel_ns = [512, 1024, 2048]
    pats = {"4-chain": chain(4), "tailed-tri": tailed_triangle(),
            "5-cycle": cycle(5)}
    for gname, g in graphs.items():
        for pname, p in pats.items():
            _cell(g, gname, p, pname)
    for n in kernel_ns:
        _kernel_cell(n, 2)


def main():
    from benchmarks.common import RESULTS
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one tiny configuration (CI)")
    ap.add_argument("--scale", default="small")
    args = ap.parse_args()
    start = len(RESULTS)
    run("smoke" if args.smoke else args.scale)
    save_json("local", start)


if __name__ == "__main__":
    main()
