"""Mesh-execution tier: data-parallel request fan-out and block-sharded
CutJoin factors (``repro.distributed.cutjoin``).

Two layers, mirroring the tier's design:

* layer 1 — serving throughput: a batch of independent pair-join
  requests served one dispatch at a time (the single-device serving
  loop: one ``cutjoin_reduce`` call per request, each paying full
  dispatch overhead) vs ``MeshExecutor.join_batch`` (one fused
  ``shard_map`` dispatch, requests spread over the ``data`` axis).  On
  the CI host the devices are XLA-forced host platform devices — the
  win measured here is fused-dispatch amortisation, the same mechanism
  that becomes true parallel speedup on a real multi-chip mesh.  The
  derived ``scaling=`` field on the batched row is the acceptance
  number (>= 3x at 8 devices);
* layer 2 — one big join: ``sharded_cutjoin`` (factors block-sharded
  over cut axis 0, f32 chunk partials reduced with ``psum``) vs the
  single-device kernel at n >= 512, counts asserted bit-for-bit equal;
* contract — the factor-*building* tier (``distributed/contract``): a
  free-hom cut tensor contracted from the row-sharded adjacency via
  collective einsums vs the single-device engine, bit-for-bit asserted,
  with the sharded engine's lazy dense adjacency asserted never built.

Run:  PYTHONPATH=src python -m benchmarks.bench_mesh [--smoke]
``--smoke`` runs the tiny CI configuration; either way the rows land in
``benchmarks/results/BENCH_mesh.json`` for the trend renderer.  The
module forces 8 host devices when ``XLA_FLAGS`` is unset, so it
measures the same mesh standalone as under the CI mesh leg.
"""
from __future__ import annotations

import argparse
import os

# must precede the first jax import: host platform device count is fixed
# at backend initialisation
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np

from benchmarks.common import emit, save_json, timeit
from repro.distributed import cutjoin as dcj
from repro.distributed import meshes
from repro.kernels import ops


def _request_stacks(batch: int, n: int, k: int = 2, seed: int = 0):
    """(B, k, n, n) integer factor stacks — one pair-join per request."""
    rng = np.random.default_rng(seed)
    return rng.integers(0, 6, size=(batch, k, n, n)).astype(np.float64)


def bench_layer1(batch: int, n: int, repeat: int = 5):
    """Serving throughput: serial per-request kernel dispatch vs one
    fused mesh dispatch over the ``data`` axis."""
    import jax
    mesh = meshes.data_mesh()
    d = meshes.num_shards(mesh)
    stacks = _request_stacks(batch, n)

    # one guard certificate covering every request (min over the batch)
    block = min(b for b in (ops.cutjoin_exact_block(list(s))
                            for s in stacks) if b is not None)

    def serial():
        return np.asarray([ops.cutjoin_reduce(list(s), distinct=True,
                                              bm=block, bn=block)
                           for s in stacks])

    dt_s, got_s = timeit(serial, repeat=repeat, warmup=True)
    emit(f"mesh/serial/n={n}/B={batch}", dt_s / batch * 1e6)

    ex = dcj.MeshExecutor(mesh)
    dt_b, got_b = timeit(lambda: ex.join_batch(stacks),
                         repeat=repeat, warmup=True)
    scaling = dt_s / max(dt_b, 1e-12)
    emit(f"mesh/batched/n={n}/B={batch}/d={d}", dt_b / batch * 1e6,
         f"scaling={scaling:.1f}x")
    assert np.array_equal(got_s, got_b), "batched counts diverged"
    return scaling


def bench_layer2(n: int, cut: int, repeat: int = 3):
    """One big join, block-sharded over cut axis 0 vs single-device."""
    rng = np.random.default_rng(n + cut)
    mesh = meshes.data_mesh()
    d = meshes.num_shards(mesh)
    Ms = [rng.integers(0, 6, size=(n,) * cut).astype(np.float64)
          for _ in range(2)]
    block = ops.cutjoin_exact_block(Ms)
    assert block is not None

    dt_1, got_1 = timeit(lambda: ops.cutjoin_reduce(Ms, distinct=cut >= 2,
                                                    bm=block, bn=block),
                         repeat=repeat, warmup=True)
    emit(f"mesh/join-single/n={n}/cut={cut}", dt_1 * 1e6)

    dt_m, got_m = timeit(lambda: dcj.sharded_cutjoin(Ms, mesh=mesh,
                                                     distinct=cut >= 2,
                                                     block=block),
                         repeat=repeat, warmup=True)
    emit(f"mesh/join-sharded/n={n}/cut={cut}/d={d}", dt_m * 1e6,
         f"vs_single={dt_1 / max(dt_m, 1e-12):.2f}x")
    assert got_1 == got_m, (got_1, got_m)


def bench_layer2_tri(n: int, repeat: int = 2):
    """|cut| = 3 with axis-subset factors, sharded over axis 0."""
    rng = np.random.default_rng(n)
    mesh = meshes.data_mesh()
    d = meshes.num_shards(mesh)
    axes = [(0, 1), (1, 2), (0, 2)]
    Ms = [rng.integers(0, 5, size=(n, n)).astype(np.float64) for _ in axes]
    block = ops.cutjoin_exact_block(Ms)
    assert block is not None

    dt_1, got_1 = timeit(lambda: ops.cutjoin_reduce3(Ms, axes, n=n,
                                                     block=block),
                         repeat=repeat, warmup=True)
    emit(f"mesh/join3-single/n={n}", dt_1 * 1e6)

    dt_m, got_m = timeit(lambda: dcj.sharded_cutjoin3(Ms, axes, n=n,
                                                      mesh=mesh,
                                                      block=block),
                         repeat=repeat, warmup=True)
    emit(f"mesh/join3-sharded/n={n}/d={d}", dt_m * 1e6,
         f"vs_single={dt_1 / max(dt_m, 1e-12):.2f}x")
    assert got_1 == got_m, (got_1, got_m)


def bench_contract(n: int, repeat: int = 3):
    """The adjacency-sharded contract regime: a 4-cycle cut tensor
    (free = (0, 1)) contracted from the row-sharded adjacency via
    collective einsums vs the single-device dense-adjacency engine.
    Counts asserted bit-for-bit equal; the sharded engine's lazy dense
    adjacency asserted never built (no unsharded n x n anywhere)."""
    from repro.core.counting import CountingEngine
    from repro.core.pattern import cycle
    from repro.graph.generators import erdos_renyi

    mesh = meshes.data_mesh()
    d = meshes.num_shards(mesh)
    g = erdos_renyi(n, avg_degree=8.0, seed=7)
    p, free = cycle(4), (0, 1)

    single = CountingEngine(g)
    sharded = CountingEngine(g, mesh=mesh)

    def run_single():
        single.hom_free_memo.clear()
        return single.hom_free_tensor(p, free)

    def run_sharded():
        sharded.hom_free_memo.clear()
        return np.asarray(sharded.hom_free_tensor(p, free))

    dt_1, got_1 = timeit(run_single, repeat=repeat, warmup=True)
    emit(f"mesh/contract-single/n={n}", dt_1 * 1e6)
    dt_m, got_m = timeit(run_sharded, repeat=repeat, warmup=True)
    emit(f"mesh/contract-sharded/n={n}/d={d}", dt_m * 1e6,
         f"vs_single={dt_1 / max(dt_m, 1e-12):.2f}x")
    assert np.array_equal(np.asarray(got_1), got_m), \
        "sharded contraction diverged"
    assert sharded._A_dense is None, \
        "sharded engine materialised the dense adjacency"


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI configuration")
    args = ap.parse_args(argv)

    if args.smoke:
        batch, bn, join_n, tri_n, con_n = 64, 64, 512, 160, 192
    else:
        batch, bn, join_n, tri_n, con_n = 128, 96, 1024, 256, 512

    scaling = bench_layer1(batch, bn)
    bench_layer2(join_n, cut=2)
    bench_layer2_tri(tri_n)
    bench_contract(con_n)
    path = save_json("mesh")
    if scaling < 3.0:
        print(f"WARNING: layer-1 scaling {scaling:.1f}x below the 3x "
              f"acceptance bar", flush=True)
    return path


if __name__ == "__main__":
    main()
