"""Pattern-morphing count algebra: motif families served off the store.

Workload (the morphing steady state): warm a ``CountStore`` with <= 3
compiled 5-vertex plans, then serve the whole size-4 connected-motif
family (6 members) through ``compiler.compile(..., morph=)``:

* members whose inclusion–exclusion identity closes over held counts
  take the compile fast path — no candidate search, no contraction,
  every hom read answered from the store (route ``morph-derive``,
  counter ``morph.hits``) — and their derived counts are asserted
  integer-equal to fresh direct compiles;
* members that don't close fall back to search (``morph.missing_compiles``)
  with held homs priced ~0.

Headline numbers (also in the JSON extras): ``fraction`` = share of the
family served algebraically with zero per-member compiles (acceptance
bar: >= 0.5), and ``speedup`` = compiling + executing every member
directly vs serving the family off the warm store.  A size-5 coverage
row reports how much of the 21-member family the same store already
determines (derivation only, no compiles).

Run:  PYTHONPATH=src python -m benchmarks.bench_morph [--smoke]
Rows land in ``benchmarks/results/BENCH_morph.json`` for the trend
renderer (``fraction``/``speedup`` fold in as pseudo-rows).
"""
from __future__ import annotations

import argparse

from benchmarks.common import emit, save_json, timeit
from repro import compiler, obs
from repro.compiler import morph as morphlib
from repro.compiler.cache import graph_signature
from repro.core.pattern import Pattern, chain
from repro.graph import generators as gen


def _warm_patterns():
    """Three 5-vertex patterns whose compiled plans' scalar homs and
    shrinkage injs close 5 of the 6 size-4 motifs: the 5-path (claw,
    tailed triangle, P3, K2), the gem (diamond, K4) and the tailed
    4-cycle (C4).  Only the 4-path stays missing — no 5-vertex
    decomposition materialises its hom."""
    gem = Pattern(5, [(0, 1), (1, 2), (2, 3), (0, 4), (1, 4), (2, 4),
                      (3, 4)])
    tailed_c4 = Pattern(5, [(0, 1), (1, 2), (2, 3), (0, 3), (3, 4)])
    return (chain(5), gem, tailed_c4)


def bench_family(n: int, seed: int = 3):
    g = gen.erdos_renyi(n, 6.0, seed=seed)
    gsig = graph_signature(g)
    store = morphlib.CountStore()
    warm = _warm_patterns()

    def do_warm():
        for p in warm:
            compiler.compile((p,), g, cache=False, morph=store).count(p)

    dt_warm, _ = timeit(do_warm)
    emit(f"morph/warm/n={n}", dt_warm / len(warm) * 1e6,
         f"plans={len(warm)}")

    family = morphlib.motif_family(4)
    hits0 = int(obs.get("morph.hits", 0.0))

    def serve():
        out = {}
        for p in family:
            cp = compiler.compile((p,), g, cache=False, morph=store)
            out[p] = (cp.count(p), bool(cp.plan.meta.get("morph")))
        return out

    dt_serve, served_counts = timeit(serve)
    served = int(obs.get("morph.hits", 0.0)) - hits0
    assert served == sum(1 for _, m in served_counts.values() if m)
    emit(f"morph/serve-family/k=4/n={n}", dt_serve / len(family) * 1e6,
         f"served={served}/{len(family)}")

    # ground truth: compile + execute every member directly, morph off
    def direct_all():
        return {p: compiler.compile((p,), g, cache=False).count(p)
                for p in family}

    dt_direct, truth = timeit(direct_all)
    emit(f"morph/compile-every-member/k=4/n={n}",
         dt_direct / len(family) * 1e6)

    for p, (v, _) in served_counts.items():
        assert int(round(v)) == int(round(truth[p])), \
            (sorted(p.edges), v, truth[p])

    # size-5 coverage off the same store: derivation only, no compiles
    fam5 = morphlib.motif_family(5)
    served5 = sum(1 for p in fam5
                  if morphlib.derive(p, store, gsig).complete)
    emit(f"morph/derive-family/k=5/n={n}", 0.0,
         f"served={served5}/{len(fam5)}")

    fraction = served / len(family)
    speedup = dt_direct / max(dt_warm + dt_serve, 1e-12)
    return {"family_size": len(family), "served_algebraically": served,
            "fraction": fraction, "speedup": speedup,
            "family5_size": len(fam5), "served5": served5}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI configuration")
    args = ap.parse_args(argv)

    extra = bench_family(96 if args.smoke else 256)
    path = save_json("morph", extra=extra)
    if extra["fraction"] < 0.5:
        print(f"WARNING: {extra['served_algebraically']}/"
              f"{extra['family_size']} of the size-4 family served "
              f"algebraically — below the 1/2 acceptance bar", flush=True)
    return path


if __name__ == "__main__":
    main()
