"""Observability layer: tracing overhead and cost-model drift accounting.

Two regimes:

* overhead — the same compiled count evaluated with the tracer detached
  (the default: one is-None check per node eval) vs attached (span
  machinery + ``block_until_ready`` fencing).  The detached row is the
  acceptance gate: tracing off must cost nothing measurable over the
  PR-5 baseline, and the attached row prices what ``--trace`` buys.
* drift — traced executions over a pattern sweep chosen to cover every
  node class the compiler emits (Contract, Intersect, MobiusCombine,
  CutJoin at |cut| in {2, 3}, LocalCount, ShrinkageCorrect): the 4-cycle
  (2-cut join), 5-clique minus an edge (the tri-join tier), a chain
  (Möbius route), and partial-embedding plans.  Each trace must explain
  >= 95% of its end-to-end wall time through per-node spans (the
  coverage acceptance bar); the (predicted, measured) pairs aggregate
  into the calibration report embedded in ``BENCH_obs.json`` under
  ``drift``/``drift_pairs``, which ``render_trend`` folds into the
  cross-commit table and ``python -m repro.obs.drift`` renders.

* precert — serving off a plan the static verifier precertified for
  ``exact_block``: the trace must contain zero ``guard-scan`` spans
  (the per-eval device->host factor-max reduction is gone) and the
  count must match the guard-scan path bit-for-bit.

One representative span tree is also written to
``benchmarks/results/trace_sample.json`` so every CI artifact carries a
loadable trace.

Run:  PYTHONPATH=src python -m benchmarks.bench_obs [--smoke]
"""
from __future__ import annotations

import argparse
import json
import pathlib

from benchmarks.common import emit, save_json, timeit
from repro import compiler, obs
from repro.core.counting import CountingEngine
from repro.core.pattern import Pattern, chain, cycle
from repro.graph import generators as gen

K5_MINUS_EDGE = Pattern(5, [(u, v) for u in range(5)
                            for v in range(u + 1, 5) if (u, v) != (3, 4)])

MIN_COVERAGE = 0.95


def _fresh_eval(cp, p):
    """One full re-evaluation of the plan (memo dropped): the unit whose
    traced-vs-untraced delta is the tracing overhead."""
    cp._values.clear()
    return cp.count(p)


def bench_overhead(n: int, repeat: int = 5):
    g = gen.erdos_renyi(n, 8.0, seed=11)
    p = cycle(4)
    cp = compiler.compile(p, g, counter=CountingEngine(g), cache=False)
    cp.count(p)                             # warm: jit + factor tensors

    dt_off, got_off = timeit(lambda: _fresh_eval(cp, p), repeat=repeat,
                             warmup=True)
    emit(f"obs/untraced/n={n}", dt_off * 1e6)

    cp.tracer = obs.Tracer()
    dt_on, got_on = timeit(lambda: _fresh_eval(cp, p), repeat=repeat,
                           warmup=True)
    cov = cp.tracer.coverage()
    emit(f"obs/traced/n={n}", dt_on * 1e6,
         f"overhead={dt_on / max(dt_off, 1e-12):.2f}x,"
         f"coverage={cov:.3f}" if cov is not None else "")
    cp.tracer = None
    assert got_on == got_off, (got_on, got_off)
    return dt_off, dt_on


def _traced_counts(patterns, g, *, local=False, label=""):
    """Compile + execute one pattern set under a fresh tracer; returns
    (tracer, compiled plan).  Every trace must clear the coverage bar —
    per-node spans explaining >= 95% of the measured end-to-end read."""
    tr = obs.Tracer(meta={"run": label})
    cp = compiler.compile(patterns, g, counter=CountingEngine(g),
                          cache=False, local=local)
    cp.tracer = tr
    for p in patterns:
        cp.count(p)
        if local:
            for orbit in p.vertex_orbits():
                if cp.has_local(p, orbit[0]):
                    cp.local_counts(p, orbit[0])
            cp.exists(p)
    cov = tr.coverage()
    assert cov is not None and cov >= MIN_COVERAGE, \
        f"{label}: trace coverage {cov} below {MIN_COVERAGE}"
    return tr, cp


def bench_drift(n: int):
    """The drift sweep: traces covering every node class × cut size the
    smoke suite exercises, aggregated into the calibration report."""
    g = gen.erdos_renyi(n, 8.0, seed=7)
    runs = [
        (( cycle(4),), dict(local=False), "cycle4-2cut"),
        ((K5_MINUS_EDGE,), dict(local=False), "k5me-3cut"),
        ((chain(5),), dict(local=False), "chain5-mobius"),
        (( cycle(4), chain(4)), dict(local=True), "local-anchored"),
    ]
    pairs, sample = [], None
    for pats, kw, label in runs:
        dt, (tr, cp) = timeit(lambda: _traced_counts(pats, g, label=label,
                                                     **kw), repeat=1)
        emit(f"obs/drift-run/{label}/n={n}", dt * 1e6,
             f"coverage={tr.coverage():.3f}")
        pairs.extend(obs.drift.pairs_from_trace(tr.to_dict()))
        if label == "k5me-3cut":
            sample = tr                     # the 3-cut tri-join trace
    report = obs.drift.aggregate(pairs)
    covered = sorted(report["groups"])
    print(f"drift: {report['n_pairs']} pairs, "
          f"{len(covered)} groups: {covered}", flush=True)
    # every node class the sweep's plans executed must appear in the
    # report — a class whose spans carry no prediction would silently
    # drop out of calibration
    for cls in ("Contract", "CutJoin", "MobiusCombine", "ShrinkageCorrect",
                "LocalCount", "Intersect"):
        assert any(k.startswith(cls) for k in covered), \
            f"drift report missing node class {cls}: {covered}"
    assert any("cut=2" in k for k in covered) \
        and any("cut=3" in k for k in covered), covered
    return report, pairs, sample


def bench_precert(n: int):
    """Precertified serving: the static verifier's degree-bound
    certificate must make the per-eval device->host guard scan
    disappear from the trace, with the served count bit-for-bit equal
    to the guard-scan path (the certificate only ever *under*-promises
    the block the runtime guard would grant)."""
    g = gen.erdos_renyi(n, 8.0, seed=13)
    p = cycle(4)
    cp = compiler.compile(p, g, counter=CountingEngine(g), cache=False)
    pre = cp.plan.meta.get("precert") or {}
    assert pre, "2-cut join on a sparse graph must precertify"

    cp.count(p)                             # warm
    tr = obs.Tracer()
    cp.tracer = tr
    dt, got = timeit(lambda: _fresh_eval(cp, p), repeat=3, warmup=True)
    scans = [s for s in tr.walk() if s.kind == "guard-scan"]
    assert not scans, \
        f"precertified plan still guard-scanned: {[s.name for s in scans]}"
    joins = [s for s in tr.walk() if s.kind == "CutJoin"]
    assert joins and all(s.attrs.get("precertified") for s in joins), joins
    cp.tracer = None

    oracle = compiler.compile(p, g, counter=CountingEngine(g), cache=False,
                              cutjoin_kernel=False)
    assert got == oracle.count(p), (got, oracle.count(p))
    emit(f"obs/precert-serve/n={n}", dt * 1e6,
         f"certified={len(pre)},guard_scans=0")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI configuration")
    args = ap.parse_args(argv)

    n = 128 if args.smoke else 400
    bench_overhead(n if args.smoke else 256)
    bench_precert(n)
    report, pairs, sample = bench_drift(n)

    results = pathlib.Path(__file__).parent / "results"
    results.mkdir(exist_ok=True)
    if sample is not None:
        sample.save(str(results / "trace_sample.json"))
        print(f"wrote trace sample to {results / 'trace_sample.json'}",
              flush=True)
    save_json("obs", extra={"drift": obs.drift.bench_summary(report),
                            "drift_pairs": pairs,
                            "metrics": obs.snapshot()})
    print(obs.drift.render(report), end="", flush=True)


if __name__ == "__main__":
    main()
