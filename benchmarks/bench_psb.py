"""Fig 28: piecewise contribution of decomposition and partial symmetry
breaking.  Versions: Baseline (direct greedy plan), +DECOM (cost-model
cut), +DECOM+PSB (oriented orbit contraction where an interchangeable
orbit exists).  Run over the size-5 patterns except the 5-clique."""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_graphs, emit
from repro.core import homomorphism as H
from repro.core import symmetry as SYM
from repro.core.apct import APCT
from repro.core.counting import CountingEngine
from repro.core.engine import MiningEngine
from repro.core.motifs import motif_patterns
from repro.core.pattern import clique
from repro.core.quotient import quotient_terms


def _time_inj(eng, p, cut):
    eng.hom_memo.clear()
    t0 = time.perf_counter()
    eng.inj(p, cut=cut)
    return time.perf_counter() - t0


def _time_inj_psb(eng, A, p, cut):
    """inj with the dominant quotient's top-level contraction oriented."""
    eng.hom_memo.clear()
    t0 = time.perf_counter()
    total = 0.0
    for coeff, q in quotient_terms(p):
        orbs = [o for o in SYM.interchangeable_orbits(q)
                if all(q.has_edge(a, b) for i, a in enumerate(o)
                       for b in o[i + 1:])]
        if q.n == p.n and orbs:
            val = float(SYM.hom_oriented(q, A, orbs[0]))
        else:
            val = eng.hom(q)
        total += coeff * val
    dt = time.perf_counter() - t0
    return dt, total / p.aut_order()


def run(scale: str = "small"):
    g = bench_graphs("micro")["wk-like"]
    A = jnp.asarray(g.dense_adjacency(np.float64, pad=False))
    eng = CountingEngine(g)
    miner = MiningEngine(g, apct=APCT(g, num_samples=4096))
    pats = [p for p in motif_patterns(5) if p != clique(5).canonical()]
    for i, p in enumerate(pats):
        cut = miner.choose_cut(p)
        t_base = _time_inj(eng, p, None)
        t_dec = _time_inj(eng, p, cut)
        t_psb, val = _time_inj_psb(eng, A, p, cut)
        want = eng.edge_induced(p)
        assert abs(val - want) < 1e-6 * max(1.0, want), (p, val, want)
        emit(f"psb/p{i}/baseline", t_base * 1e6, "")
        emit(f"psb/p{i}/+decom", t_dec * 1e6, "")
        emit(f"psb/p{i}/+decom+psb", t_psb * 1e6,
             f"m={p.m} aut={p.aut_order()}")


if __name__ == "__main__":
    run()
