"""Fig 31: scalability.  The container has one physical core, so strong
scaling cannot be *measured* here; instead we (a) verify work-partitioned
execution (block-cyclic units) has low partitioning overhead — the
property that yields the paper's near-linear scaling when units run on
independent workers — and (b) run the sharded-einsum path on forced host
devices in a subprocess to confirm multi-device execution."""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.core import homomorphism as H
from repro.core.distributed import blockwise_hom_count
from repro.core.pattern import chain
from repro.graph import generators as gen

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run(scale: str = "small"):
    g = gen.erdos_renyi(2000, 10.0, seed=1)
    A = jnp.asarray(g.dense_adjacency(np.float64, pad=False))
    p = chain(5)
    t1, base = timeit(lambda: float(H.hom_count(p, A)))
    emit("scaling/blocks/1", t1 * 1e6, "")
    for nb in (2, 4, 8, 16):
        t, v = timeit(blockwise_hom_count, p, A, None, nb)
        assert abs(v - base) < 1e-6 * max(1.0, base)
        emit(f"scaling/blocks/{nb}", t * 1e6,
             f"overhead={t / t1:.2f}x")
    # sharded execution across forced host devices (subprocess)
    code = textwrap.dedent("""
        import jax, numpy as np, time
        from repro.graph.generators import erdos_renyi
        from repro.core.pattern import chain
        from repro.core.distributed import shard_adjacency, sharded_hom_count
        g = erdos_renyi(2000, 10.0, seed=1)
        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        A = shard_adjacency(g.dense_adjacency(np.float64, pad=False), mesh)
        t0 = time.perf_counter(); v = sharded_hom_count(chain(5), A, mesh)
        print(f"SHARDED_OK {time.perf_counter()-t0:.3f}")
    """)
    env = dict(os.environ, PYTHONPATH=SRC,
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=560)
    ok = "SHARDED_OK" in r.stdout
    emit("scaling/sharded_8dev", 0.0 if not ok else float(
        r.stdout.split()[-1]) * 1e6, f"ok={ok}")


if __name__ == "__main__":
    run()
