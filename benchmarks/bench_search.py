"""Table 6 / Fig 24: decomposition-space search methods — runtime of the
generated application (RT) and searching time (ST) for random / separate /
circulant tuning (+ simulated annealing)."""
from __future__ import annotations

import random
import time

from benchmarks.common import bench_graphs, emit, timeit
from repro.core import search as S
from repro.core.apct import APCT
from repro.core.counting import CountingEngine
from repro.core.decomposition import candidates
from repro.core.motifs import motif_patterns


def _app_runtime(g, pats, cuts):
    eng = CountingEngine(g)
    t0 = time.perf_counter()
    for p, cut in zip(pats, cuts):
        eng.edge_induced(p, cut=cut)
    return time.perf_counter() - t0


def run(scale: str = "small", k: int = 5, seed: int = 0):
    g = bench_graphs("micro")["cs-like"]
    apct = APCT(g, num_samples=8192)
    pats = motif_patterns(k)
    rng = random.Random(seed)

    # random baseline: mean over a few random assignments
    rts = []
    for s in range(4):
        cuts = [rng.choice(candidates(p)) for p in pats]
        rts.append(_app_runtime(g, pats, cuts))
    emit(f"search/{k}-MC/random/RT", sum(rts) / len(rts) * 1e6, "")

    for name in ("separate", "circulant", "annealing"):
        res = S.METHODS[name](pats, apct, g.n)
        rt = _app_runtime(g, pats, res.cuts)
        emit(f"search/{k}-MC/{name}/RT", rt * 1e6,
             f"ST={res.search_time_s:.2f}s cost={res.cost:.2e}")
    return True


if __name__ == "__main__":
    run()
