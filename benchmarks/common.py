"""Shared benchmark utilities: timing, CSV/JSON emission, standard
graphs."""
from __future__ import annotations

import json
import pathlib
import time

from repro.graph import generators as gen

RESULTS = []


def emit(name: str, us_per_call: float, derived: str = ""):
    line = f"{name},{us_per_call:.1f},{derived}"
    RESULTS.append(line)
    print(line, flush=True)


def save_json(suite: str, start_index: int = 0,
              extra: dict = None) -> pathlib.Path:
    """Write rows emitted since ``start_index`` to
    ``benchmarks/results/BENCH_<suite>.json`` (the machine-readable perf
    trajectory the CI workflow uploads as a build artifact).  ``extra``
    merges additional top-level keys into the JSON (e.g. bench_obs's
    ``drift``/``drift_pairs`` tables) without disturbing the row schema
    ``render_trend`` reads."""
    rows = []
    for line in RESULTS[start_index:]:
        name, us, derived = line.split(",", 2)
        rows.append({"name": name, "us_per_call": float(us),
                     "derived": derived})
    out = pathlib.Path(__file__).parent / "results" / f"BENCH_{suite}.json"
    out.parent.mkdir(exist_ok=True)
    doc = {"suite": suite, "rows": rows}
    doc.update(extra or {})
    out.write_text(json.dumps(doc, indent=1) + "\n")
    print(f"wrote {len(rows)} rows to {out}", flush=True)
    return out


def timeit(fn, *args, repeat: int = 1, warmup: bool = False, **kw):
    if warmup:
        fn(*args, **kw)                   # compile/warm caches
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeat
    return dt, out


def bench_graphs(scale: str = "small"):
    """Stand-ins for the paper's datasets (CPU container => synthetic):
    citeseer-like (clustered, sparse), wiki-like (denser ER), patents-like
    (larger, sparse).  'micro' (256 vertices) keeps width-3 contractions
    cheap for the per-decomposition sweeps (cost model / search / PSB)."""
    if scale == "micro":
        return {
            "cs-like": gen.triangle_rich(256, 12, seed=1),
            "wk-like": gen.erdos_renyi(256, 10.0, seed=2),
        }
    if scale == "tiny":
        return {
            "cs-like": gen.triangle_rich(400, 16, seed=1),
            "wk-like": gen.erdos_renyi(400, 10.0, seed=2),
        }
    return {
        "cs-like": gen.triangle_rich(1200, 40, seed=1),
        "wk-like": gen.erdos_renyi(1500, 14.0, seed=2),
        "pt-like": gen.small_world(4000, 8, 0.2, seed=3),
    }
