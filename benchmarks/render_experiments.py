"""Render EXPERIMENTS.md from cached results (dry-run grids, baseline
snapshot, hillclimb logs, bench CSV).

  PYTHONPATH=src python -m benchmarks.render_experiments
"""
from __future__ import annotations

import json
import pathlib

R = pathlib.Path(__file__).resolve().parent / "results"
ROOT = pathlib.Path(__file__).resolve().parents[1]

HW = ("TPU v5e-class chip: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link "
      "ICI (assignment constants)")


def load_grid(mesh, base=False):
    d = R / ("dryrun_baseline" if base else "dryrun") / mesh
    recs = {}
    if not d.exists():
        return recs
    for f in sorted(d.glob("*.json")):
        rec = json.loads(f.read_text())
        if rec.get("tag"):
            continue
        if "skipped" in rec:
            continue
        recs[(rec["arch"], rec["shape"])] = rec
    return recs


def t(rec, k):
    return f"{rec[k] * 1e3:,.1f}"


def roofline_table(recs):
    rows = ["| arch | shape | t_comp ms | t_mem ms | t_mem(kernel) ms | "
            "t_coll ms | dominant | useful | HBM/dev GiB | frac |",
            "|---|---|---|---|---|---|---|---|---|---|"]
    for (a, s), r in sorted(recs.items()):
        bound = max(r["t_compute"], r["t_memory"], r["t_collective"])
        boundk = max(r["t_compute"], r.get("t_memory_kernelized",
                                           r["t_memory"]),
                     r["t_collective"])
        frac = r["t_compute"] / boundk if boundk else 0
        rows.append(
            f"| {a} | {s} | {t(r,'t_compute')} | {t(r,'t_memory')} | "
            f"{r.get('t_memory_kernelized', r['t_memory'])*1e3:,.1f} | "
            f"{t(r,'t_collective')} | {r['dominant']} | "
            f"{r['useful_flops_ratio']:.0%} | "
            f"{r['memory']['peak_est_bytes']/2**30:.1f} | {frac:.1%} |")
    return "\n".join(rows)


def dryrun_section(single, multi):
    lines = ["Every applicable (arch × shape) cell lowers AND compiles on "
             "both production meshes — 16×16 = 256 chips single-pod and "
             "2×16×16 = 512 chips multi-pod.  `long_500k` runs only for "
             "sub-quadratic archs (jamba, mamba2) per the assignment; "
             "decode shapes lower `decode_step`, prefill shapes "
             "`prefill_step`, train shapes `train_step` (microbatched "
             "AdamW).\n",
             f"* single-pod cells compiled: **{len(single)}**",
             f"* multi-pod cells compiled: **{len(multi)}**",
             "",
             "| arch | shape | mesh | HBM/dev GiB | #collectives | "
             "compile s |", "|---|---|---|---|---|---|"]
    for mesh_name, recs in (("single", single), ("multi", multi)):
        for (a, s), r in sorted(recs.items()):
            lines.append(
                f"| {a} | {s} | {mesh_name} | "
                f"{r['memory']['peak_est_bytes']/2**30:.1f} | "
                f"{r['num_collectives']} | {r['compile_s']} |")
    return "\n".join(lines)


def perf_section():
    out = []
    for f in sorted(R.glob("hillclimb_*.json")):
        h = json.loads(f.read_text())
        out.append(f"#### autoshard search: {h['arch']} / {h['shape']} "
                   f"({h['mesh']} pod)")
        out.append("")
        out.append("| step | assignment | bound (s) |")
        out.append("|---|---|---|")
        for i, (a, c) in enumerate(h["history"]):
            short = {k: ("/".join(v) if isinstance(v, list) else v)
                     for k, v in a.items()}
            out.append(f"| {i} | `{short}` | {c:.2f} |")
        out.append("")
    return "\n".join(out)


def main():
    single = load_grid("single")
    multi = load_grid("multi")
    base_single = load_grid("single", base=True)

    bench_csv = (R / "bench.csv").read_text() if (R / "bench.csv").exists() \
        else "(run benchmarks first)"

    doc = TEMPLATE.format(
        hw=HW,
        dryrun=dryrun_section(single, multi),
        roof_single=roofline_table(single),
        roof_multi=roofline_table(multi),
        roof_baseline=roofline_table(base_single),
        perf_searches=perf_section(),
        n_single=len(single), n_multi=len(multi),
    )
    (ROOT / "EXPERIMENTS.md").write_text(doc)
    print(f"wrote EXPERIMENTS.md ({len(single)} single + {len(multi)} "
          f"multi cells)")


TEMPLATE = """# EXPERIMENTS

Hardware model: {hw}.  This container is CPU-only; kernels validate in
Pallas interpret mode and all TPU numbers are derived from compiled HLO
per the roofline method below.

## §Dry-run

{dryrun}

## §Roofline

Method: per-device FLOPs / HBM bytes / collective link traffic parsed
from the optimized post-SPMD HLO with **trip-count-aware accounting**
(XLA's `cost_analysis()` counts scan bodies once — `repro/distributed/
hlo_parse.py` walks the call graph multiplying while-bodies by their trip
counts; validated against `cost_analysis` on scan-free programs in
`tests/test_hlo_parse.py`).  Collective traffic uses the ring model
(all-reduce 2x(g-1)/g etc.).  Known approximations: (1) operand bytes are
counted per consumer (double-reads are intentional), (2) XLA-CPU converts
bf16 dots to f32, so some gathered weights appear at 4 B/elem that would
be 2 B/elem on TPU — collective terms for those patterns are ~2x
pessimistic, (3) `t_mem(kernel)` subtracts attention-score-shaped traffic
(one axis == seq, one == flash block), i.e. the HBM round-trips
`kernels/flashattn.py` keeps in VMEM; its own tile IO is O(q+k+v+o) < 2%
of that.

MODEL_FLOPS = 6·N_active·tokens (train, fwd+bwd) or 2·N_active·tokens
(serve).  `useful` = MODEL_FLOPS / (HLO FLOPs × chips): train cells sit at
45-75% because full rematerialisation re-runs the forward (8·N·D
effective) plus attention/SSD mixing FLOPs — expected, not waste.
`frac` = t_comp / max(t_comp, t_mem(kernel), t_coll) — the roofline
fraction with the attention kernel modeled.

### Optimized grid — single pod (16×16), {n_single} cells

{roof_single}

### Optimized grid — multi-pod (2×16×16), {n_multi} cells

Multi-pod halves per-replica batch (DP over pod×data): compute and
memory terms scale ~1/2 while cross-pod gradient reduction joins the
collective term — exactly the regime gradient compression
(`train/compression.py`, int8 + error feedback, 2x wire bytes vs bf16)
targets.

{roof_multi}

### Paper-faithful baseline grid (pre-optimization snapshot)

The baseline numbers below were measured on the same cells **before** the
§Perf iterations (naive decode cache handling, einsum-dispatch MoE, no
layout search) — kept verbatim as the reproduction baseline.  (Parser
refinements for HBM-byte attribution landed between the snapshots, so
collective and compute columns are like-for-like while memory columns are
comparable only in order of magnitude; the §Perf log cites only
same-parser measurements.)

{roof_baseline}

## §Perf — hypothesis → change → measure → validate

The three hillclimbed cells (worst roofline fraction; most
collective-bound; most representative): **command-r-35b/decode_32k**,
**deepseek-v3-671b/train_4k**, **qwen3-4b/train_4k**.  The search engine
is the paper's own circulant tuning (Fig 23) applied to sharding layouts
(`repro/distributed/autoshard.py`) with the roofline bound as cost model —
the DwarvesGraph technique reused as a first-class framework feature.

### Iteration log (summary)

| # | cell | hypothesis | change | before → after (bound) | verdict |
|---|---|---|---|---|---|
| 1 | command-r decode | TP/DP layout is wrong | circulant autoshard over (heads,kv,kv_seq,batch) | 1.72 s → 1.50 s | partially confirmed: layout helps 13%, but giant cache all-gathers persist — layout is not the root cause |
| 2 | command-r decode | `vmap(dynamic_update_slice)` + KV->H expansion force GSPMD to all-gather the 43 GiB cache | masked-`where` cache update + grouped GQA decode (no expansion) | 1.50 s → 1.50 s | refuted: gathers persisted — they were loop-boundary reshards, not update artifacts |
| 3 | command-r decode | the (KV=8, hd=128) cache split cannot express the 16-way sharding of the K/V projections, so the scan-carried cache is re-sharded (in f32!) every step | **flattened (B,S,KV·hd) cache layout** + f32-accumulate-in-bf16 einsums + pinned cache sharding | collective 1 719 → **58 ms**; memory 899 → 386 ms; HBM/dev 124 → 19 GiB | **confirmed** — 30× collective, 4.5× bound |
| 4 | qwen3 train | 4 B params over 256 chips is over-tensor-parallel; per-layer Megatron all-reduces dominate | autoshard: batch over (data,model) = 256-way DP, embed FSDP, microbatches=1 | 12.85 s → **8.24 s** (coll 7.6 → 1.34 s) | confirmed; residual bound = attention-score HBM traffic |
| 5 | qwen3 train | score traffic is removable only by a fused attention kernel | `kernels/flashattn.py` (measured via score-shaped-traffic subtraction) | t_mem 8.2 s → t_mem(kernel) — see table | confirmed by construction (kernel validated vs oracle; BlockSpec IO counted in bench_kernels) |
| 6 | deepseek-v3 train | MoE einsum dispatch makes GSPMD all-reduce the full (B,E,C,d) buffer (28 GiB × 58 layers) | **shard_map expert parallelism with explicit all_to_all** | coll 225 s → 123 s | confirmed direction, but FSDP-gathered expert weights became the new bottleneck (6 × 380 GiB/step) |
| 7 | deepseek-v3 train | token replication over the model axis makes EP compute redundant ×16 | shard the sequence dim over 'model' inside the MoE body | useful 5.8% → 49.7% | confirmed |
| 8 | deepseek-v3 train | 256 experts divide the full 256-chip mesh — experts can live whole on one device each, eliminating ALL weight movement | full-mesh EP (experts over data×model), all_to_all over both axes | coll 123 s → **50 s** | confirmed (remaining collective = a2a token traffic + grad reduce; memory now dominates via attention scores -> kernel term) |
| 9 | jamba/dbrx MoE (16 experts) | stationary 2-D-sharded expert weights + moving activations beats per-step weight gathers | expert-TP: co-locate the expert's tokens via all_gather over its data group, psum d-partials, slice own tokens back (first attempt psum'd *different* tokens' partials — caught by tests/test_moe_ep.py) | jamba decode coll 1 752 → **156 ms**, mem 848 → 247 ms; jamba TRAIN 122 → 161 s | confirmed for serving, **refuted for small-E training** (token traffic > weight traffic at 1M tokens/step) — EP is now gated: full-mesh EP always, expert-TP for <=65k-token steps, einsum dispatch otherwise |
| 10 | all decode cells | the flattened-cache + grouped-GQA fixes generalise | applied fleet-wide | e.g. qwen3 decode coll 1 546 → 50 ms; llama-vision 1 390 → 45 ms; dbrx 2 314 → 693 ms; v3 decode HBM/dev 89 → 30 GiB (with latent `lora`->model sharding) | confirmed — see optimized vs baseline tables |
| 11 | dbrx train (post-EP-gating) | the qwen finding (batch over data×model) transfers to MoE training | fresh autoshard round on final code | 61.2 s → **41.9 s** (batch=(pod,data,model), microbatches=1) | confirmed — further microbatch increases regress (weight re-gather scaling, as in change 6) |

Stopping criterion: three further candidate changes (kv_seq/model decode
sharding, batch-over-model decode, microbatch sweeps 2-16) each moved the
dominant term < 5%.

### Search traces

{perf_searches}

### Beyond-paper items implemented and measured

* flattened KV-cache layout + pinned scan-carried shardings (change 3);
* shard_map full-mesh expert parallelism (changes 6-8);
* Pallas kernels: flashattn (score traffic), sddmm/matreduce (pattern-
  counting contraction without materialising the product — triangle-count
  HBM saving quantified in `bench_kernels`), bitset intersect;
* autoshard — the paper's circulant tuning as the layout search engine;
* gradient compression (int8 + error feedback) available for cross-pod
  all-reduce: wire bytes 4x less than f32, validated in
  `tests/test_train_substrate.py`.

### Mining-side §Perf (the paper's own workload)

Headline (Table 4 analogue, `counting/vs-loops/*` in bench.csv): the
tensorised engine beats host nested-loop enumeration (the AutoMine-style
baseline) by **~127x on 3-MC and ~406x on 4-MC**, with the gap growing in
pattern size exactly as the paper reports.  Decomposed-vs-direct *within*
the tensor engine is a further 0.95-1.42x (cut choice tunes contraction
order; the engine's canonical-quotient memoisation already delivers the
paper's cross-pattern reuse unconditionally — see the search-methods
finding below).

`benchmarks/bench_psb.py` reproduces Fig 28 (baseline / +DECOM /
+DECOM+PSB): decomposition helps most 5-vertex patterns; PSB helps when
the oriented orbit contraction dominates and can hurt on tiny graphs
(transpose-compensation overhead) — matching the paper's own observation
that some patterns don't benefit (their p10) and motivating the 1% cost-
model gate.  `bench_counting.py` shows the decomposed+reused engine vs
direct per-pattern contraction (Tables 4/5 analogue);
`bench_cost_model.py` reproduces Fig 22 (the APCT model correlates with
runtime far better than the random-graph model).

**Search-methods finding (Table 6 analogue, `bench_search.py`):** the
cost-model ordering matches the paper (circulant <= separate <= random on
*estimated* cost, pinned by `test_circulant_no_worse_than_separate`), but
the measured *runtime* spread between methods is much smaller than the
paper's — an architectural consequence of the tensorised adaptation:
quotient hom contractions are memoised by canonical form, so the cutting
set changes only the contraction *order*, never *what* gets computed.
The paper's loop-compiled engine recomputes subpattern tables per choice,
which is exactly why its joint search matters more.  Our engine gets the
paper's cross-pattern reuse unconditionally; the search still pays off on
large graphs where order determines intermediate widths (N^2 vs N^3).

## Benchmark CSV

See `benchmarks/results/bench.csv` (`name,us_per_call,derived`), one
suite per paper table/figure; regenerate with
`PYTHONPATH=src python -m benchmarks.run`.
"""


if __name__ == "__main__":
    main()
