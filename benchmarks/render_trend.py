"""Render the perf trajectory across commits from BENCH_*.json files.

CI uploads ``benchmarks/results/BENCH_<suite>.json`` per commit as a
build artifact; download a few artifact directories next to each other
(or point ``--root`` at any tree containing them) and this renders one
markdown table per suite — rows are benchmark names, columns are
snapshots in commit/mtime order, cells are µs/call — plus an ASCII
sparkline and the delta between the first and last snapshot, so a
regression reads directly off the table.

    python -m benchmarks.render_trend                      # results/ only
    python -m benchmarks.render_trend --root artifacts/    # many commits
    python -m benchmarks.render_trend --out TREND.md

Snapshots are grouped by the directory that holds them (one directory =
one commit's artifact) and ordered by file mtime; dependency-free on
purpose — it must run in CI and on laptops alike.
"""
from __future__ import annotations

import argparse
import json
import pathlib

SPARK = "▁▂▃▄▅▆▇█"


def load_snapshots(root: pathlib.Path):
    """{suite: [(snapshot label, {name: us_per_call})]} — one snapshot
    per (directory, suite) file, ordered oldest first by mtime."""
    files = sorted(root.rglob("BENCH_*.json"),
                   key=lambda f: f.stat().st_mtime)
    suites: dict = {}
    for f in files:
        try:
            d = json.loads(f.read_text())
            rows = {r["name"]: float(r["us_per_call"])
                    for r in d["rows"]}
            suite = d.get("suite", f.stem.replace("BENCH_", ""))
        except (json.JSONDecodeError, KeyError, TypeError, ValueError):
            continue                        # torn/foreign file: skip
        # cost-model calibration rides the same table: bench_obs embeds
        # a per-group drift summary, whose ratio spread (max/min of
        # measured/predicted — 1.0 is a perfectly scalable model) trends
        # across commits exactly like a latency row
        for key, g in (d.get("drift") or {}).items():
            if isinstance(g, dict) and g.get("ratio_spread") is not None:
                # group keys use "|" separators — swap for "/" so the
                # name survives a markdown table cell
                rows["drift-spread " + key.replace("|", "/")] = \
                    float(g["ratio_spread"])
        # ratio-style derived annotations — "scaling=4.4x" /
        # "vs_single=0.09x" (bench_mesh), "speedup=6279x"
        # (bench_compiler) — become their own trend rows, so a
        # sharded-speedup regression reads off the table exactly like a
        # latency regression.  Only "<key>=<number>x" folds: plain
        # counts ("q=5", "plans=3") and display-only fractions
        # ("served=5/6") stay in the derived column of their suite.
        for r in d.get("rows") or []:
            for part in (r.get("derived") or "").split(","):
                k, _, v = part.partition("=")
                v = v.strip()
                if not v.endswith("x"):
                    continue
                try:
                    val = float(v[:-1])
                except ValueError:
                    continue
                rows[f"{k.strip()} {r['name']}"] = val
        # bench_morph's headline extras live top-level: the fraction of
        # the motif family served algebraically (higher is better —
        # read the delta sign accordingly) and end-to-end speedup vs
        # compiling every member
        for k in ("fraction", "speedup"):
            if isinstance(d.get(k), (int, float)):
                rows[f"{suite}-{k}"] = float(d[k])
        label = f.parent.name if f.parent != root else "results"
        suites.setdefault(suite, []).append((label, rows))
    return suites


def sparkline(values) -> str:
    vals = [v for v in values if v is not None]
    if len(vals) < 2:
        return ""
    lo, hi = min(vals), max(vals)
    span = (hi - lo) or 1.0
    return "".join(" " if v is None else
                   SPARK[int((v - lo) / span * (len(SPARK) - 1))]
                   for v in values)


def render_suite(suite: str, snapshots) -> list:
    labels = [lab for lab, _ in snapshots]
    names: list = []
    for _, rows in snapshots:
        for n in rows:
            if n not in names:
                names.append(n)
    out = [f"## {suite}", ""]
    out.append("| name | " + " | ".join(labels) + " | trend | Δ |")
    out.append("|" + "---|" * (len(labels) + 3))
    for n in names:
        vals = [rows.get(n) for _, rows in snapshots]
        cells = ["" if v is None else f"{v:,.1f}" for v in vals]
        present = [v for v in vals if v is not None]
        delta = ""
        if len(present) >= 2 and present[0]:
            delta = f"{(present[-1] / present[0] - 1) * 100:+.0f}%"
        out.append(f"| {n} | " + " | ".join(cells) +
                   f" | {sparkline(vals)} | {delta} |")
    out.append("")
    return out


def render(root: pathlib.Path) -> str:
    suites = load_snapshots(root)
    lines = ["# Benchmark trend (µs/call, lower is better)", ""]
    if not suites:
        lines.append(f"_no BENCH_*.json found under {root}_")
    for suite in sorted(suites):
        lines.extend(render_suite(suite, suites[suite]))
    return "\n".join(lines) + "\n"


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--root", default=None,
                    help="tree to scan for BENCH_*.json "
                    "(default: benchmarks/results)")
    ap.add_argument("--out", default=None, metavar="FILE",
                    help="also write the markdown to FILE")
    args = ap.parse_args(argv)
    root = pathlib.Path(args.root) if args.root else \
        pathlib.Path(__file__).parent / "results"
    text = render(root)
    print(text, end="")
    if args.out:
        pathlib.Path(args.out).write_text(text)
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
