"""§Roofline: render the (arch x shape) table from the cached dry-run
JSONs (benchmarks/results/dryrun/<mesh>/).  Run the grids first:

  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh multi
"""
from __future__ import annotations

import json
import pathlib

RESULTS = pathlib.Path(__file__).resolve().parent / "results" / "dryrun"


def load(mesh: str = "single", tag: str = "") -> list:
    d = RESULTS / mesh
    out = []
    for f in sorted(d.glob("*.json")):
        rec = json.loads(f.read_text())
        if rec.get("tag", "") != tag or "skipped" in rec:
            continue
        out.append(rec)
    return out


def as_markdown(recs: list) -> str:
    head = ("| arch | shape | t_comp (ms) | t_mem (ms) | t_coll (ms) | "
            "dominant | useful FLOPs | HBM/dev (GiB) | roofline frac |\n"
            "|---|---|---|---|---|---|---|---|---|")
    rows = [head]
    for r in recs:
        bound = max(r["t_compute"], r["t_memory"], r["t_collective"])
        frac = r["t_compute"] / bound if bound else 0.0
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute']*1e3:.2f} | "
            f"{r['t_memory']*1e3:.2f} | {r['t_collective']*1e3:.2f} | "
            f"{r['dominant']} | {r['useful_flops_ratio']:.1%} | "
            f"{r['memory']['peak_est_bytes']/2**30:.1f} | {frac:.1%} |")
    return "\n".join(rows)


def run(scale: str = "small"):
    for mesh in ("single", "multi"):
        recs = load(mesh)
        if not recs:
            print(f"(no cached dry-run results for mesh={mesh})")
            continue
        print(f"\n### Roofline — {mesh} pod ({len(recs)} cells)\n")
        print(as_markdown(recs))
        from benchmarks.common import emit
        for r in recs:
            bound = max(r["t_compute"], r["t_memory"], r["t_collective"])
            emit(f"roofline/{mesh}/{r['arch']}/{r['shape']}",
                 bound * 1e6,
                 f"dom={r['dominant']} frac={r['t_compute']/bound:.3f}")


if __name__ == "__main__":
    run()
