"""Benchmark harness: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--scale tiny|small] [--only X]

Emits ``name,us_per_call,derived`` CSV lines (also collected in
benchmarks/results/bench.csv).
"""
from __future__ import annotations

import argparse
import pathlib
import sys
import time
import traceback

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

from benchmarks import (bench_apct, bench_chains, bench_cost_model,
                        bench_counting, bench_fsm, bench_kernels, bench_psb,
                        bench_scaling, bench_search, roofline)
from benchmarks.common import RESULTS

SUITES = {
    "counting": bench_counting.run,       # Tables 4/5
    "cost_model": bench_cost_model.run,   # Fig 22
    "search": bench_search.run,           # Table 6 / Fig 24
    "psb": bench_psb.run,                 # Fig 28
    "chains": bench_chains.run,           # Fig 29 / Table 7
    "fsm": bench_fsm.run,                 # Fig 30
    "apct": bench_apct.run,               # Table 1
    "scaling": bench_scaling.run,         # Fig 31
    "kernels": bench_kernels.run,         # §Perf kernel deltas
    "roofline": roofline.run,             # §Roofline table
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="small", choices=["tiny", "small"])
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    failures = []
    for name, fn in SUITES.items():
        if args.only and name != args.only:
            continue
        print(f"=== {name} ===", flush=True)
        t0 = time.perf_counter()
        try:
            fn(args.scale)
        except Exception:                  # noqa: BLE001
            failures.append(name)
            traceback.print_exc()
        print(f"=== {name} done in {time.perf_counter() - t0:.1f}s ===", flush=True)

    out = pathlib.Path(__file__).parent / "results" / "bench.csv"
    out.parent.mkdir(exist_ok=True)
    out.write_text("\n".join(RESULTS) + "\n")
    print(f"\nwrote {len(RESULTS)} rows to {out}")
    if failures:
        print(f"FAILED suites: {failures}")
        sys.exit(1)


if __name__ == "__main__":
    main()
