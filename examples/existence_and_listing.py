"""Pattern existence query (Fig 14) and counting with bounded embedding
listing (Fig 13).

    PYTHONPATH=src python examples/existence_and_listing.py
"""
import sys
sys.path.insert(0, "src")

from repro.api import exists
from repro.core.engine import MiningEngine
from repro.core.pattern import Pattern, chain, clique, cycle
from repro.graph.generators import small_world

graph = small_world(500, 6, 0.2, seed=3)
app = MiningEngine(graph)

# --- existence queries (partial-embedding fast path) ----------------------
# api.exists evaluates the decomposition factors one subpattern at a
# time: an all-zero factor decides False before the join or any
# shrinkage correction runs (the early exit); a positive local entry
# decides True.
for p, name in [(clique(3), "triangle"), (clique(5), "K5"),
                (cycle(5), "C5"), (chain(6), "6-chain")]:
    print(f"{name} exists: {exists(p, graph, counter=app.counter)}")

# --- Fig 13: count everything, materialise only the first 100 -----------
pattern = Pattern(4, [(0, 1), (1, 2), (2, 3)])    # 4-chain
num_to_list = 100
listed, total = [], [0]


def process_partial_embedding(pe, count):
    if pe.subpattern_id == 0:
        remained = num_to_list - len(listed)
        if remained > 0:
            listed.extend(app.materialize(pattern, pe,
                                          min(remained, count)))
        total[0] += count


app.run_partial_embeddings(pattern, process_partial_embedding)
print(f"4-chain embedding tuples: {total[0]:,} "
      f"(= {total[0] // pattern.aut_order():,} embeddings)")
print(f"materialised first {len(listed)}; e.g. {listed[:3]}")
check = app.get_pattern_count(pattern) * pattern.aut_order()
print(f"cross-check vs get_pattern_count: {int(check) == total[0]}")
