"""FSM with MINI support on a labelled graph (paper §3 Fig 15/16).

    PYTHONPATH=src python examples/fsm_mining.py
"""
import sys
sys.path.insert(0, "src")

import numpy as np

from repro.core.counting import CountingEngine
from repro.core.engine import MiningEngine
from repro.core.fsm import fsm, mini_support
from repro.core.pattern import Pattern
from repro.graph.generators import triangle_rich

graph = triangle_rich(600, 20, seed=7, num_labels=4)
print(f"labelled input graph: {graph}")

for support in (200, 60, 20):
    r = fsm(graph, min_support=support, max_vertices=3)
    print(f"support >= {support}: {len(r.frequent)} frequent patterns "
          f"({r.evaluated} evaluated, {r.pruned} pruned by downward closure)")
for p, s in sorted(r.frequent.items(), key=lambda t: -t[1])[:6]:
    print(f"  support {s}: edges={sorted(p.edges)} labels={p.labels}")

# the Fig 15 UDF path computes the same MINI support through the
# partial-embedding programming model:
p = sorted(r.frequent, key=lambda q: (-q.n, sorted(q.edges)))[0]
eng = MiningEngine(graph)
domains = [set() for _ in range(p.n)]


def udf(pe, count):
    if count > 0:
        for i, v in pe.determined:
            domains[i].add(v)


eng.run_partial_embeddings(p, udf)
udf_support = min(len(d) for d in domains)
tensor_support = mini_support(CountingEngine(graph), p)
print(f"UDF-path MINI support = {udf_support}, "
      f"tensor-path = {tensor_support} (must match: "
      f"{udf_support == tensor_support})")
