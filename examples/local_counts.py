"""Partial-embedding API walkthrough: pseudo-clique hotspots and
per-vertex motif significance without materialising a single embedding.

    PYTHONPATH=src python examples/local_counts.py

Both applications read their answers off the decomposition join's cut
tensors — the factor product *before* the final reduce — so the cost is
the same contractions the global count already pays, not an enumeration
of embeddings (the price Peregrine-style systems pay for these apps).
"""
import sys
sys.path.insert(0, "src")

import numpy as np

from repro.api import exists, local_counts, vertex_counts
from repro.core.counting import CountingEngine
from repro.core.pattern import chain, cycle, tailed_triangle
from repro.core.search import mine_pseudo_cliques
from repro.graph.generators import triangle_rich

graph = triangle_rich(400, 16, seed=7)
engine = CountingEngine(graph)              # shared memo across queries

# --- anchored local counts ------------------------------------------------
# completion counts of the tailed triangle with its tail vertex pinned:
# lc.counts[u] = how many embeddings put the tail at graph vertex u
p = tailed_triangle()
lc = local_counts(p, graph, anchor=3, counter=engine)
print(f"tailed-triangle tails: {int(lc.total()):,} injective maps, "
      f"{np.count_nonzero(lc.counts)} distinct tail vertices "
      f"(route: {lc.style})")

# the full local tensor over the chosen cutting set
lt = local_counts(p, graph, counter=engine)
print(f"local tensor over cut {lt.axes}: shape {lt.counts.shape}, "
      f"sum == inj == {int(lt.total()):,}")

# --- pseudo-clique mining (paper §3's PC application) ---------------------
r = mine_pseudo_cliques(graph, 4, missing=1, counter=engine)
total = sum(r.totals.values())
print(f"\n4-pseudo-cliques (one edge short of K4): {total:,.0f}")
print("hotspot vertices (embeddings containing v):")
for u in r.hotspots[:5]:
    print(f"  v{u}: {r.per_vertex[u]:,.0f}")

# --- per-vertex motif significance ----------------------------------------
# which vertices sit in unusually many 4-cycles relative to 4-chains?
# (a per-vertex "clustering" significance — the classic advanced app)
vc_cycle = vertex_counts(cycle(4), graph, counter=engine)
vc_chain = vertex_counts(chain(4), graph, counter=engine)
sig = vc_cycle / np.maximum(vc_chain, 1.0)
top = sorted(range(graph.n), key=lambda u: -sig[u])[:5]
print("\n4-cycle significance (cycles per chain) leaders:")
for u in top:
    print(f"  v{u}: {sig[u]:.3f} "
          f"({vc_cycle[u]:,.0f} cycles / {vc_chain[u]:,.0f} chains)")

# --- early-exit existence -------------------------------------------------
for q, name in [(cycle(5), "C5"), (tailed_triangle(), "tailed tri")]:
    print(f"{name} exists: {exists(q, graph, counter=engine)}")
