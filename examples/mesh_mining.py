"""Mesh-sharded mining: the decomposition join spread over a device mesh.

    PYTHONPATH=src python examples/mesh_mining.py

Three layers ride the same 1-D ``("data",)`` mesh:

* sharded adjacency — an engine bound with ``mesh=`` keeps the graph's
  adjacency *row-sharded* across the devices
  (``repro.distributed.contract``): Contract nodes run as collective
  einsums (local slice contraction + ``psum``), the dense n x n
  adjacency never materialises anywhere, and the cut tensors a join
  consumes are born already sliced along cut axis 0;
* block-sharded joins — a plan compiled with ``mesh=`` routes its
  CutJoin/LocalCount nodes through ``repro.distributed.cutjoin``: every
  factor is sliced along cut axis 0, each device reduces its block rows
  with the same guarded f32 kernels, and the f64 partials meet in a
  ``psum``.  Counts are bit-for-bit identical to single-device — the
  exactness guard makes every partial an exact integer, and f64 integer
  addition is associative below 2^53;
* data-parallel serving — ``PatternQueryBatcher(mesh=...)`` fans a
  step's requests over device slots, and ``MeshExecutor.join_batch``
  fuses a homogeneous batch of joins into one dispatch.

This example forces 8 host devices so it runs anywhere; on real
hardware, drop the XLA_FLAGS line and the same code shards over the
chips that are present.
"""
import os
import sys

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")
sys.path.insert(0, "src")

from repro import compiler, obs
from repro.core.counting import CountingEngine
from repro.core.motifs import motif_patterns
from repro.core.pattern import cycle
from repro.distributed import meshes
from repro.graph.generators import erdos_renyi
from repro.serve.batching import PatternQueryBatcher, PatternRequest

graph = erdos_renyi(400, 8.0, seed=1)
mesh = meshes.data_mesh()                 # all local devices on "data"
print(f"graph: {graph}; mesh: {meshes.num_shards(mesh)} device(s)")

# --- layer 3: the adjacency itself sharded over the mesh ------------------
shard_engine = CountingEngine(graph, mesh=mesh)   # adjacency row-sharded
t = shard_engine.hom_free_tensor(cycle(4), free=(0, 1))
assert shard_engine._A_dense is None      # no unsharded n x n, ever
print(f"C4 cut tensor contracted sharded: shape {tuple(t.shape)}, "
      f"sharding {t.sharding.spec} (n divisible by the mesh -> the "
      f"tensor stays sliced on cut axis 0)")

# --- layer 2: one plan, contractions + joins sharded over the mesh --------
patterns = motif_patterns(4)
tracer = obs.Tracer()
cp = compiler.compile(patterns, graph, counter=shard_engine,
                      cache=False, mesh=mesh)
cp.tracer = tracer
single = compiler.compile(patterns, graph, counter=CountingEngine(graph),
                          cache=False)
for p in patterns:
    got, ref = cp.count(p), single.count(p)
    assert got == ref, (p, got, ref)      # bit-for-bit, not approximately
print(f"{len(patterns)} motif counts match single-device bit-for-bit")

routes = {}


def _walk(span):
    r = span.attrs.get("route")
    if r:
        routes[r] = routes.get(r, 0) + 1
    for c in span.children:
        _walk(c)


for root in tracer.roots:
    _walk(root)
print(f"routes taken: {routes}")          # kernel-sharded where granted

# --- layer 1: serving requests fanned over device slots -------------------
batcher = PatternQueryBatcher(graph, mesh=mesh)
for uid in range(8):
    batcher.submit(PatternRequest(uid=uid, patterns=(cycle(4),)))
batcher.run_to_completion()
counts = {req.uid: next(iter(req.counts.values()))
          for req in batcher.finished}
assert len(set(counts.values())) == 1     # same graph, same answer
print(f"served {len(counts)} requests; C4 count {counts[0]:,.0f}")
print(f"batcher stats: steps={batcher.stats['steps']} "
      f"compiles={batcher.stats['compiles']} "
      f"cache_hits={batcher.stats['cache_hits']}")
