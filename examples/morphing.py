"""Pattern-morphing count algebra: serve a motif family from the store.

Walks ``compiler.morph`` end to end: warm a ``CountStore`` with a few
compiled plans (every ``CompiledPlan.count`` read harvests the scalar
homs and injective counts its plan materialised), then ask for every
size-4 connected motif.  Members whose inclusion–exclusion identity
closes over the held counts are served *algebraically* — the compile
fast path skips decomposition search and contraction entirely and the
count is a few integer multiply-adds — while the rest fall back to a
normal search with held homs priced ~0 by the cost model.

    PYTHONPATH=src python examples/morphing.py
"""
import sys
sys.path.insert(0, "src")

from repro import analysis, compiler, obs
from repro.compiler import morph
from repro.compiler.cache import graph_signature
from repro.core.pattern import Pattern, chain
from repro.graph.generators import erdos_renyi

graph = erdos_renyi(200, 6.0, seed=1)
gsig = graph_signature(graph)
store = morph.CountStore()          # in-memory; pass a path to persist

# --- 1. warm the store with three 5-vertex plans --------------------------
# Their decomposed plans materialise scalar homs of their quotients plus
# shrinkage injective counts; the harvest after each .count() read
# deposits every one of them into the store.
gem = Pattern(5, [(0, 1), (1, 2), (2, 3), (0, 4), (1, 4), (2, 4), (3, 4)])
tailed_c4 = Pattern(5, [(0, 1), (1, 2), (2, 3), (0, 3), (3, 4)])
for p in (chain(5), gem, tailed_c4):
    cp = compiler.compile((p,), graph, cache=False, morph=store)
    print(f"warm  {p!r:48s} count = {cp.count(p):,.0f}")
print(f"store now holds {len(store)} exact counts "
      f"({sorted(store.held_hom_keys(gsig))})")

# --- 2. serve the whole size-4 motif family -------------------------------
# morph=store makes compile() try the algebra first: derive() walks the
# inclusion–exclusion identity (inj via Möbius over quotients, homs via
# the inverse expansion) and only falls back to search when a term is
# genuinely missing from the store.
print(f"\n{'pattern':14s} {'count':>14s}  route")
for p in morph.motif_family(4):
    cp = compiler.compile((p,), graph, cache=False, morph=store)
    route = ("algebraic (no search, no contraction)"
             if cp.plan.meta.get("morph") else "compiled (fell back)")
    name = f"{p.n}v/{p.m}e"
    print(f"{name:14s} {cp.count(p):14,.0f}  {route}")

print(f"\nmorph.hits = {int(obs.get('morph.hits', 0.0))}, "
      f"morph.derivations = {int(obs.get('morph.derivations', 0.0))}, "
      f"morph.missing_compiles = "
      f"{int(obs.get('morph.missing_compiles', 0.0))}")

# --- 3. what a derivation looks like --------------------------------------
# derive() exposes the identity itself: signed hom terms over the
# quotient lattice, divided by the automorphism order.  morph_check
# validates the committed identity on the lattice endpoints (empty and
# complete graphs) by brute force — cheap, and independent of the store.
wedge = chain(3)
cand = morph.derive(wedge, store, gsig)
terms = " ".join(f"{c:+d}*hom({q.n}v/{q.m}e)" for c, q in cand.terms)
print(f"\ninj(wedge) = {terms};  count = inj / {cand.divisor} "
      f"= {cand.value:,d}")
print(f"morph_check: ok = {analysis.morph_check(cand).ok}")

# --- 4. coverage frontier -------------------------------------------------
# The lattice explorer enumerates edge-add/remove neighbours — the
# natural "which motifs are one morph away" workload.  How much of the
# 21-member size-5 family does the same store already determine?
fam5 = morph.motif_family(5)
served = [p for p in fam5 if morph.derive(p, store, gsig).complete]
print(f"\nsize-5 family determined by the same store: "
      f"{len(served)}/{len(fam5)}")
