"""Quickstart: pattern counting with the DwarvesGraph engine (paper Fig 10).

    PYTHONPATH=src python examples/quickstart.py
"""
import sys
sys.path.insert(0, "src")

from repro.core.engine import MiningEngine
from repro.core.pattern import Pattern, chain, clique
from repro.graph.generators import erdos_renyi

# load the input graph && other initialisations
graph = erdos_renyi(1000, 8.0, seed=0)
print(f"input graph: {graph}")

# the compilation step of the paper: the engine profiles the dataset
# (APCT) and will choose a decomposition per pattern via the cost model
app = MiningEngine(graph)

# --- "three_chain.cc": get_pattern_count --------------------------------
p = chain(3)                                     # construct the 3-chain
print(f"three-chain-count: {app.get_pattern_count(p):,.0f}")

cut = app.choose_cut(p)
print(f"  chosen cutting set: {sorted(cut) if cut else 'direct (fallback)'}")

# a bigger pattern: decomposition beats direct enumeration here
p5 = Pattern(5, [(0, 1), (0, 2), (1, 2), (1, 3), (2, 4)])
print(f"custom 5-pattern count: {app.get_pattern_count(p5):,.0f} "
      f"(cut={sorted(app.choose_cut(p5) or [])})")

# vertex-induced counts via the same-size overlay transform (paper §2.1)
print(f"vertex-induced 3-chain: "
      f"{app.get_pattern_count(p, induced='vertex'):,.0f}")
print(f"triangles: {app.get_pattern_count(clique(3)):,.0f}")

# 4-motif table in one call (cross-pattern computation reuse)
table = app.counter.motif_table(4)
print("4-motif table:")
for q, v in sorted(table.items(), key=lambda t: t[0].m):
    print(f"  m={q.m}: {v:,.0f}")
print(f"hom contractions evaluated: {app.counter.stats['hom_evals']}, "
      f"reused: {app.counter.stats['hom_hits']}")
