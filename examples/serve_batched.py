"""Batched serving with continuous batching (slot reuse, per-request
prefill + shared decode steps).

    PYTHONPATH=src python examples/serve_batched.py
"""
import sys
sys.path.insert(0, "src")

from repro.launch.serve import main as serve_main

serve_main(["--arch", "qwen3-4b", "--requests", "10", "--slots", "4",
            "--max-new", "8"])
