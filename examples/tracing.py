"""Plan-execution tracing and the cost-model drift report.

Walks the observability layer end to end: attach a tracer to a compiled
plan, read the span tree it records (one span per IR node evaluation,
nested exactly as the evaluation recursion nests), export it for
chrome://tracing, and aggregate the (predicted cost, measured time)
pairs into the calibration report that tells you where the APCT cost
model drifts from reality.

    PYTHONPATH=src python examples/tracing.py
"""
import sys
sys.path.insert(0, "src")

from repro import compiler, obs
from repro.core.pattern import Pattern
from repro.graph.generators import erdos_renyi

graph = erdos_renyi(300, 8.0, seed=1)

# 5-clique minus one edge: its only cutting set has three vertices, so
# the compiler commits a |cut| = 3 decomposition join — the tri-join
# kernel tier, the most interesting thing to watch execute.
p = Pattern(5, [(u, v) for u in range(5) for v in range(u + 1, 5)
                if (u, v) != (3, 4)])

# --- 1. attach a tracer and execute ---------------------------------------
# Tracing is off by default (one is-None check per node eval); attaching
# a Tracer records a root "execute" span per public read with one node
# span per IR evaluation beneath it.  Values are fenced
# (jax.block_until_ready) before each span closes, so spans time the
# work, not the async enqueue.
tracer = obs.Tracer()
cp = compiler.compile(p, graph, cache=False)
cp.tracer = tracer
count = cp.count(p)
print(f"count = {count:,.0f} on {graph}")

# --- 2. read the span tree ------------------------------------------------
# Each span carries the node key, node class, cut size, the route the
# node actually took (kernel vs xla-dense, einsum vs enumeration), the
# exact_block guard outcome, and factor shapes.
for span in tracer.walk():
    route = span.attrs.get("route", "")
    print(f"  {span.kind:16s} {span.name:28s} {route:12s} "
          f"{span.duration_s * 1e3:8.2f} ms (self {span.self_s * 1e3:.2f})")

# Coverage: how much of the end-to-end read the per-node spans explain.
print(f"node coverage of wall time: {tracer.coverage():.1%}")

# --- 3. export ------------------------------------------------------------
# Span-tree JSON for tooling; *.chrome.json writes the Chrome
# "traceEvents" format — open chrome://tracing (or Perfetto) and load it
# to see the plan execute on a timeline.  `mine.py --trace=FILE` does
# exactly this for full workloads.
tracer.save("/tmp/k5me_trace.json")
tracer.save("/tmp/k5me_trace.chrome.json")
print("wrote /tmp/k5me_trace.json and /tmp/k5me_trace.chrome.json")

# --- 4. the drift report --------------------------------------------------
# Compilation stored each committed node's predicted APCT cost in
# plan.meta["node_costs"]; the trace measured each node's self time.
# The report groups pairs by node class x cut size x route: rank
# correlation says whether the model *orders* nodes correctly (all the
# plan picker needs), ratio spread says whether one per-class scale
# factor would calibrate absolute costs (the autotune on-ramp).
pairs = obs.drift.pairs_from_trace(tracer.to_dict())
report = obs.drift.aggregate(pairs)
print()
print(obs.drift.render(report))

# --- 5. the metrics registry ----------------------------------------------
# Counters accumulated process-wide while the plan ran: kernel-tier
# calls, exact_block guard outcomes, plan node evals/memo hits.  The
# .stats dicts on PlanCache / CompiledPlan / PatternQueryBatcher are
# live views over the same registry.
print("metrics registry:")
print(obs.dump(indent=2))
