"""End-to-end training: ~130M-parameter decoder on the synthetic pipeline
with checkpoint/resume.  (Use --steps 200+ for a real run; the default is
sized for a quick demonstration on one CPU.)

    PYTHONPATH=src python examples/train_lm.py [--steps N]
"""
import sys
sys.path.insert(0, "src")

import argparse
import tempfile

from repro.launch.train import main as train_main

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=30)
ap.add_argument("--full", action="store_true",
                help="full repro-100m config (default: reduced width)")
args = ap.parse_args()

ckpt = tempfile.mkdtemp(prefix="repro100m_")
argv = ["--arch", "repro-100m", "--steps", str(args.steps),
        "--batch", "8", "--seq", "128", "--ckpt-dir", ckpt,
        "--ckpt-every", "10", "--log-every", "5"]
if not args.full:
    argv.append("--reduced")

losses = train_main(argv)
print(f"\nloss: {losses[0]:.3f} -> {losses[-1]:.3f} over {len(losses)} steps")
print(f"checkpoints in {ckpt} — rerun with the same dir to resume")
