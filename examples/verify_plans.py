"""Static plan verification and exact_block precertification.

Two things the analysis layer buys, end to end:

1. a corrupted cache entry — one flipped bit that still parses as valid
   JSON — is rejected by the structural verifier at load time instead of
   lowering and serving a wrong count;
2. plans whose factor magnitudes the degree-bound abstract interpreter
   can certify at compile time skip the per-evaluation device->host
   guard scan entirely (visible in the trace), bit-for-bit with the
   guarded path.

    PYTHONPATH=src python examples/verify_plans.py
"""
import json
import pathlib
import sys
import tempfile

sys.path.insert(0, "src")

from repro import analysis, compiler, obs
from repro.compiler.cache import PlanCache
from repro.compiler.ir import Plan
from repro.core.counting import CountingEngine
from repro.core.pattern import cycle
from repro.graph.generators import erdos_renyi

graph = erdos_renyi(200, 8.0, seed=5)
pattern = cycle(4)

# --- compile; the verifier runs before the plan is committed --------------
cp = compiler.compile(pattern, graph, counter=CountingEngine(graph),
                      cache=False)
result = analysis.verify(cp.plan)           # meta carries graph + budget
print(f"plan: {len(cp.plan.nodes)} nodes, verify "
      f"{'OK' if result.ok else 'FAILED'} "
      f"({len(result.errors)} errors, {len(result.warnings)} warnings)")

# --- precertification: which joins never need the runtime guard ----------
pre = cp.plan.meta["precert"]
print(f"precertified joins: {pre or '(none)'}")

tracer = obs.Tracer()
cp.tracer = tracer
count = cp.count(pattern)
scans = [s for s in tracer.walk() if s.kind == "guard-scan"]
print(f"count = {count:,.0f}; guard-scan spans in trace: {len(scans)}")

oracle = compiler.compile(pattern, graph, counter=CountingEngine(graph),
                          cache=False, cutjoin_kernel=False)
print(f"bit-for-bit with the XLA (guarded) path: "
      f"{count == oracle.count(pattern)}")

# --- cache corruption: a bit-flip the schema cannot see ------------------
with tempfile.TemporaryDirectory() as d:
    cache = PlanCache(d)
    cache.put("demo", cp.plan)
    (entry,) = list(pathlib.Path(d).glob("plan-*"))

    data = bytearray(entry.read_bytes())
    i = bytes(data).index(b'"cut_size": 2') + len(b'"cut_size": ')
    data[i] ^= 0x01                          # '2' -> '3': still valid JSON
    entry.write_bytes(bytes(data))
    json.loads(entry.read_text())            # parses fine...

    fresh = PlanCache(d)                     # ...but the verifier catches it
    assert fresh.get("demo") is None
    print(f"corrupted entry: clean miss "
          f"(verify_rejects={fresh.verify_rejects}, "
          f"format_misses={fresh.format_misses})")

    # what the verifier actually saw
    bad = analysis.verify(Plan.from_json(entry.read_text()))
    for diag in bad.errors[:3]:
        print(f"  {diag}")
