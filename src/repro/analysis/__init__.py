"""Static analysis over the plan IR.

``verify``  — structural verifier + abstract interpreter: DAG/ref/output
              integrity, shape and tier-matrix legality, budget checks,
              and ``exact_block`` precertification (see
              ``analysis.verify``).  ``morph_check`` validates a
              committed morph identity on the pattern-lattice endpoints.
``lint``    — AST-level repo-invariant lint with a CLI
              (``python -m repro.analysis.lint``); imported lazily — the
              serving path never pays for it.
"""
from repro.analysis.verify import (Diagnostic, GraphInfo, PlanVerifyError,
                                   VerifyResult, infer_shapes, morph_check,
                                   precertify, refusal_flags, shard_check,
                                   verify)

__all__ = ["Diagnostic", "GraphInfo", "PlanVerifyError", "VerifyResult",
           "infer_shapes", "morph_check", "precertify", "refusal_flags",
           "shard_check", "verify"]
