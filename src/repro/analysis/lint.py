"""AST-level repo-invariant lint (stdlib ``ast``, no dependencies).

Tests exercise behaviour; these rules enforce conventions behaviour
can't catch — violations that pass every test but rot the codebase:

``no-time-time``        ``time.time()`` in timed paths.  Wall clock is
                        not monotonic and jumps under NTP; every timer
                        must use ``time.perf_counter()``.  Genuine
                        wall-clock uses (file mtimes) waive the rule
                        with an inline ``lint: allow=no-time-time``.
``kernel-guard``        a ``cutjoin_reduce*`` kernel-wrapper call whose
                        enclosing function/class never consults the
                        ``exact_block`` guard or a precertification
                        certificate.  The f32-chunk kernels are only
                        exact under the guard's block bound — an
                        unguarded call site silently returns wrong
                        counts on large-magnitude factors.
``ir-dict-complete``    an IR dataclass (frozen, with ``to_dict`` and
                        ``refs``) whose declared fields are not all
                        serialised by ``to_dict`` and read back by the
                        module's ``*from_dict``.  A field dropped from
                        either side round-trips plans lossily — the
                        cache serves a different plan than was compiled.
``no-mutable-default``  mutable default argument values (list/dict/set
                        literals or constructors) — shared across calls,
                        a classic aliasing bug.
``mesh-guard``          a ``shard_map`` call whose enclosing function
                        never enters ``meshes.sharding_ctx``.  Sharded
                        code that bypasses the context executes against
                        whatever mesh happens to be ambient, and
                        logical-axis ``constrain`` calls inside the
                        region silently no-op or resolve against the
                        wrong mesh.

Suppress any rule on one line with a ``lint: allow=<rule>`` comment on
that line.  CLI::

    python -m repro.analysis.lint [path ...]     # default: src/repro

Exit status 1 when findings remain — CI runs this as a blocking step.
"""
from __future__ import annotations

import ast
import sys
from dataclasses import dataclass
from pathlib import Path
from typing import List, Optional

RULES = ("no-time-time", "kernel-guard", "ir-dict-complete",
         "no-mutable-default", "mesh-guard")

# the public kernel wrappers whose exactness depends on the block bound
# (single-device tier and its mesh-sharded analogues alike)
_KERNEL_WRAPPERS = {"cutjoin_reduce", "cutjoin_reduce_keep",
                    "cutjoin_reduce3", "cutjoin_reduce3_keep",
                    "sharded_cutjoin", "sharded_cutjoin_keep",
                    "sharded_cutjoin3", "sharded_cutjoin3_keep"}
# calls that consult the guard / certificate and so satisfy the protocol
_GUARD_CALLS = {"cutjoin_exact_block", "exact_block", "precertify",
                "runtime_block", "_guard_block"}

_MUTABLE_CTORS = {"list", "dict", "set", "defaultdict", "OrderedDict",
                  "deque", "Counter"}


@dataclass(frozen=True)
class Finding:
    rule: str
    path: str
    line: int
    message: str

    def __str__(self):
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


def _call_name(func) -> Optional[str]:
    if isinstance(func, ast.Name):
        return func.id
    if isinstance(func, ast.Attribute):
        return func.attr
    return None


def _suppressed(source_lines, lineno: int, rule: str) -> bool:
    if not (1 <= lineno <= len(source_lines)):
        return False
    return f"lint: allow={rule}" in source_lines[lineno - 1]


def _calls_in(tree) -> list:
    return [n for n in ast.walk(tree) if isinstance(n, ast.Call)]


def _is_dataclass_decorated(cls: ast.ClassDef) -> bool:
    for dec in cls.decorator_list:
        target = dec.func if isinstance(dec, ast.Call) else dec
        name = _call_name(target) if isinstance(target, (ast.Name,
                                                         ast.Attribute)) \
            else None
        if name == "dataclass":
            return True
    return False


def lint_source(source: str, path: str = "<string>") -> List[Finding]:
    """Lint one module's source; returns findings (suppressions already
    applied)."""
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as exc:
        return [Finding("syntax", path, exc.lineno or 0, str(exc.msg))]
    lines = source.splitlines()
    out: List[Finding] = []
    out.extend(_rule_time_time(tree, path, lines))
    out.extend(_rule_mutable_default(tree, path, lines))
    out.extend(_rule_kernel_guard(tree, path, lines))
    out.extend(_rule_mesh_guard(tree, path, lines))
    out.extend(_rule_ir_dict_complete(tree, path, lines))
    out.sort(key=lambda f: (f.line, f.rule))
    return out


def _rule_time_time(tree, path, lines):
    out = []
    for call in _calls_in(tree):
        f = call.func
        if isinstance(f, ast.Attribute) and f.attr == "time" and \
                isinstance(f.value, ast.Name) and f.value.id == "time":
            if not _suppressed(lines, call.lineno, "no-time-time"):
                out.append(Finding(
                    "no-time-time", path, call.lineno,
                    "time.time() is not monotonic — use "
                    "time.perf_counter() for timing"))
    return out


def _rule_mutable_default(tree, path, lines):
    out = []
    for fn in ast.walk(tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        defaults = list(fn.args.defaults) + \
            [d for d in fn.args.kw_defaults if d is not None]
        for d in defaults:
            bad = isinstance(d, (ast.List, ast.Dict, ast.Set,
                                 ast.ListComp, ast.DictComp, ast.SetComp))
            if not bad and isinstance(d, ast.Call):
                bad = _call_name(d.func) in _MUTABLE_CTORS
            if bad and not _suppressed(lines, d.lineno,
                                       "no-mutable-default"):
                out.append(Finding(
                    "no-mutable-default", path, d.lineno,
                    f"mutable default argument in {fn.name}() is shared "
                    f"across calls"))
    return out


def _rule_kernel_guard(tree, path, lines):
    """Every ``cutjoin_reduce*`` call must sit in a function (or method
    of a class) that also consults the exactness guard.  The wrappers'
    own definitions (kernels/ops.py) contain no wrapper *calls*, so the
    rule needs no module exemptions."""
    out = []

    def guard_present(scope) -> bool:
        return any(_call_name(c.func) in _GUARD_CALLS
                   for c in _calls_in(scope))

    def walk(node, scopes):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.ClassDef)):
                walk(child, scopes + [child])
                continue
            if isinstance(child, ast.Call):
                name = _call_name(child.func)
                if name in _KERNEL_WRAPPERS and \
                        not any(guard_present(s) for s in scopes) and \
                        not _suppressed(lines, child.lineno, "kernel-guard"):
                    out.append(Finding(
                        "kernel-guard", path, child.lineno,
                        f"{name}() called without consulting the "
                        f"exact_block guard in the enclosing scope — f32 "
                        f"chunks are only exact under the guard's bound"))
            walk(child, scopes)

    walk(tree, [])
    return out


def _rule_mesh_guard(tree, path, lines):
    """Every call named exactly ``shard_map`` must sit in a function (or
    class) that also enters ``meshes.sharding_ctx`` — the mesh-tier
    contract (``distributed/cutjoin.py`` keeps it by construction).
    Deliberately name-based: an aliased import (``from ... import
    shard_map as _sm``) is the escape hatch for non-GPM users with their
    own context discipline (e.g. ``models/moe.py``)."""
    out = []

    def ctx_present(scope) -> bool:
        return any(_call_name(c.func) == "sharding_ctx"
                   for c in _calls_in(scope))

    def walk(node, scopes):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.ClassDef)):
                walk(child, scopes + [child])
                continue
            if isinstance(child, ast.Call):
                name = _call_name(child.func)
                if name == "shard_map" and \
                        not any(ctx_present(s) for s in scopes) and \
                        not _suppressed(lines, child.lineno, "mesh-guard"):
                    out.append(Finding(
                        "mesh-guard", path, child.lineno,
                        "shard_map() called without entering "
                        "meshes.sharding_ctx in the enclosing scope — "
                        "sharded code must pin the mesh it executes "
                        "against"))
            walk(child, scopes)

    walk(tree, [])
    return out


def _rule_ir_dict_complete(tree, path, lines):
    """Serialisation completeness by reflection: for every dataclass
    that has both ``to_dict`` and ``refs`` methods (the IR-op shape),
    each declared field must appear as ``self.<field>`` inside
    ``to_dict`` and as a ``"<field>"`` string constant inside one of the
    module's ``*from_dict`` functions.  Mirrors what
    ``dataclasses.fields`` would report at runtime, but at the AST layer
    so the gate needs no imports."""
    from_dict_strings = set()
    has_from_dict = False
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) and \
                node.name.endswith("from_dict"):
            has_from_dict = True
            for c in ast.walk(node):
                if isinstance(c, ast.Constant) and isinstance(c.value, str):
                    from_dict_strings.add(c.value)

    out = []
    for cls in ast.walk(tree):
        if not isinstance(cls, ast.ClassDef) or \
                not _is_dataclass_decorated(cls):
            continue
        methods = {m.name: m for m in cls.body
                   if isinstance(m, (ast.FunctionDef, ast.AsyncFunctionDef))}
        if "to_dict" not in methods or "refs" not in methods:
            continue
        fields = [stmt.target.id for stmt in cls.body
                  if isinstance(stmt, ast.AnnAssign) and
                  isinstance(stmt.target, ast.Name)]
        to_dict = methods["to_dict"]
        serialised = {n.attr for n in ast.walk(to_dict)
                      if isinstance(n, ast.Attribute) and
                      isinstance(n.value, ast.Name) and n.value.id == "self"}
        for f in fields:
            if f in serialised:
                continue
            if _suppressed(lines, cls.lineno, "ir-dict-complete"):
                continue
            out.append(Finding(
                "ir-dict-complete", path, to_dict.lineno,
                f"{cls.name}.{f} never serialised in to_dict() — cached "
                f"plans would drop it"))
        if has_from_dict:
            for f in fields:
                if f in from_dict_strings:
                    continue
                if _suppressed(lines, cls.lineno, "ir-dict-complete"):
                    continue
                out.append(Finding(
                    "ir-dict-complete", path, cls.lineno,
                    f"{cls.name}.{f} never read back by a *from_dict() "
                    f"in this module"))
    return out


def lint_paths(paths) -> List[Finding]:
    findings: List[Finding] = []
    for path in paths:
        p = Path(path)
        files = sorted(p.rglob("*.py")) if p.is_dir() else [p]
        for f in files:
            findings.extend(lint_source(f.read_text(), str(f)))
    return findings


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if "--list-rules" in argv:
        for r in RULES:
            print(r)
        return 0
    paths = argv or ["src/repro"]
    findings = lint_paths(paths)
    for f in findings:
        print(f)
    if findings:
        print(f"{len(findings)} finding(s)", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
