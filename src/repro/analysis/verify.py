"""Static verification of plan IR: structural checks, abstract shape
interpretation, and `exact_block` precertification.

Nothing in the serving path validates a plan between ``Plan.from_dict``'s
version check and execution — a corrupted cache entry, a frontend bug,
or a hand-edited plan is only caught (if at all) when the runtime oracle
disagrees.  ``verify`` closes that gap with two passes that never touch
the graph data:

**Structural pass.**  Every node is a known IR op whose dict key matches
its own ``key``, every ``refs()`` target resolves, the DAG is acyclic,
every output points at a real node, and everything unreachable from an
output (or a ``dom:`` domain vector) is flagged.

**Abstract interpretation.**  Each node's tensor rank (and, given the
graph size, its concrete shape/dtype) is inferred from the IR alone:
Contract free-axis arity, CutJoin/LocalCount axis-subset annotations,
Möbius/shrinkage scalar algebra.  On top of the shapes it checks the
tier matrix (``lowering._eval`` implements exactly: keep-axis reduces
for one surviving axis at |cut| <= 3, dense product otherwise), the
LABEL_STRIDE marker encoding of free-hom patterns (must decode under
``free_skeleton``), factor-element totals against the plan budget, and
— the serving-path win — a conservative degree-bound on factor
magnitudes that *precertifies* the kernel tier's ``exact_block`` guard:
a precertified join provably never refuses the f32-chunk kernel, so
execution skips the device→host factor scan entirely.  Joins whose
factors provably always blow the exactness limit are flagged at verify
time instead of silently falling back on every query.

Diagnostics carry stable ``code`` strings (one per failure class) so
tests and callers can assert *which* invariant broke, not just that one
did.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.compiler.ir import (Contract, CutJoin, Intersect, LocalCount,
                               MobiusCombine, Plan, ShrinkageCorrect,
                               is_local_output)
from repro.core import homomorphism as _H
from repro.core.pattern import LABEL_STRIDE, free_skeleton
from repro.kernels.matreduce import EXACT_LIMIT
from repro.kernels import matreduce as _mr

_NODE_CLASSES = (Contract, Intersect, MobiusCombine, CutJoin,
                 ShrinkageCorrect, LocalCount)

# mirrors ``matreduce.exact_block``'s floor: a join whose factor-
# magnitude *lower* bound already blows EXACT_LIMIT at the smallest
# chunk can never take the kernel route
MIN_BLOCK = 8


# -- results ---------------------------------------------------------------------

@dataclass(frozen=True)
class Diagnostic:
    """One verifier finding.  ``code`` is the stable failure class,
    ``node`` the offending node key (or output name), ``severity`` is
    "error" (plan must not execute) or "warning" (advisory)."""
    code: str
    node: str
    message: str
    severity: str = "error"

    def __str__(self):
        return f"{self.severity}[{self.code}] {self.node}: {self.message}"


@dataclass
class VerifyResult:
    diagnostics: List[Diagnostic] = field(default_factory=list)
    # node key -> statically certified exact_block chunk size: joins in
    # here provably never refuse the f32 kernel on the verified graph
    precert: Dict[str, int] = field(default_factory=dict)

    @property
    def errors(self) -> List[Diagnostic]:
        return [d for d in self.diagnostics if d.severity == "error"]

    @property
    def warnings(self) -> List[Diagnostic]:
        return [d for d in self.diagnostics if d.severity == "warning"]

    @property
    def ok(self) -> bool:
        return not self.errors

    def codes(self) -> Tuple[str, ...]:
        return tuple(d.code for d in self.diagnostics)

    def raise_if_failed(self):
        if not self.ok:
            raise PlanVerifyError(self.errors)
        return self

    def __str__(self):
        if not self.diagnostics:
            return "plan verifies clean"
        return "\n".join(str(d) for d in self.diagnostics)


class PlanVerifyError(ValueError):
    """A plan failed static verification.  ValueError subclass so the
    cache's clean-miss handler treats it like any other bad entry."""

    def __init__(self, diagnostics):
        self.diagnostics = list(diagnostics)
        super().__init__("; ".join(str(d) for d in self.diagnostics))


@dataclass(frozen=True)
class GraphInfo:
    """The few graph statistics static analysis needs — carried in plan
    meta so cached plans can re-verify and precertify without the graph
    they were compiled against."""
    n: int
    max_degree: int
    min_degree: int = 0

    @classmethod
    def from_graph(cls, graph) -> "GraphInfo":
        import numpy as np
        deg = np.asarray(graph.degrees)
        if deg.size == 0:
            return cls(int(graph.n), 0, 0)
        return cls(int(graph.n), int(deg.max()), int(deg.min()))

    def to_dict(self) -> dict:
        return {"n": self.n, "max_degree": self.max_degree,
                "min_degree": self.min_degree}

    @classmethod
    def from_dict(cls, d: dict) -> "GraphInfo":
        return cls(int(d["n"]), int(d["max_degree"]),
                   int(d.get("min_degree", 0)))


# -- entry point -----------------------------------------------------------------

def verify(plan: Plan, *, graph_info: Optional[GraphInfo] = None,
           budget: Optional[int] = None,
           precertify_joins: bool = True) -> VerifyResult:
    """Statically verify one plan.  ``graph_info``/``budget`` default to
    the values recorded in ``plan.meta`` (compiles since the analysis
    layer landed record both); without them the budget and
    precertification passes are skipped — structure and shapes are still
    fully checked."""
    if graph_info is None and isinstance(plan.meta.get("graph_info"), dict):
        try:
            graph_info = GraphInfo.from_dict(plan.meta["graph_info"])
        except (KeyError, TypeError, ValueError):
            graph_info = None
    if budget is None:
        b = plan.meta.get("budget")
        budget = int(b) if isinstance(b, (int, float)) else None

    res = VerifyResult()
    _structural(plan, res.diagnostics)
    if res.errors:
        # shape inference assumes resolvable, acyclic refs
        return res
    ndims: Dict[str, int] = {}
    for key in plan.nodes:
        _ndim_of(key, plan, ndims)
    for key, node in plan.nodes.items():
        _check_node(key, node, plan, ndims, res.diagnostics)
    _check_outputs(plan, ndims, res.diagnostics)
    if graph_info is not None and budget is not None:
        _check_budget(plan, graph_info, budget, res.diagnostics)
    if graph_info is not None and precertify_joins and not res.errors:
        res.precert = precertify(plan, graph_info)
        res.diagnostics.extend(refusal_flags(plan, graph_info))
    return res


def infer_shapes(plan: Plan, n: int) -> Dict[str, tuple]:
    """Abstract value of every node without executing: key ->
    (shape, dtype-name).  Scalars are shape (); every tensor axis ranges
    over graph vertices, and all node values combine on the host in f64
    (the kernel tier's f32 chunks are internal)."""
    ndims: Dict[str, int] = {}
    for key in plan.nodes:
        _ndim_of(key, plan, ndims)
    return {key: ((n,) * nd, "float64") for key, nd in ndims.items()}


# -- pass 1: structure -----------------------------------------------------------

def _err(code, node, msg):
    return Diagnostic(code, node, msg)


def _warn(code, node, msg):
    return Diagnostic(code, node, msg, severity="warning")


def _structural(plan: Plan, diags: List[Diagnostic]):
    nodes = plan.nodes
    valid = {}
    for key, node in nodes.items():
        if not isinstance(node, _NODE_CLASSES):
            diags.append(_err("unknown-node-class", key,
                              f"{type(node).__name__} is not a plan IR op"))
            continue
        valid[key] = node
        if node.key != key:
            diags.append(_err("key-mismatch", key,
                              f"node carries key {node.key!r}"))
        for r in node.refs():
            if r not in nodes:
                diags.append(_err("dangling-ref", key,
                                  f"references missing node {r!r}"))

    # cycle detection: iterative 3-colour DFS over resolvable refs
    WHITE, GREY, BLACK = 0, 1, 2
    colour = {k: WHITE for k in valid}
    for start in valid:
        if colour[start] != WHITE:
            continue
        stack = [(start, iter([r for r in valid[start].refs()
                               if r in valid]))]
        colour[start] = GREY
        while stack:
            key, it = stack[-1]
            advanced = False
            for r in it:
                if colour.get(r, BLACK) == GREY:
                    diags.append(_err(
                        "cycle", key, f"ref cycle through {r!r}"))
                elif colour.get(r) == WHITE:
                    colour[r] = GREY
                    stack.append((r, iter([x for x in valid[r].refs()
                                           if x in valid])))
                    advanced = True
                    break
            if not advanced:
                colour[key] = BLACK
                stack.pop()

    # outputs resolve; everything else must be reachable from an output
    # or a domain vector ("dom:" nodes are looked up by key, not via
    # Plan.outputs — see ir.domain_keys)
    roots = set()
    for name, target in plan.outputs.items():
        if target not in nodes:
            diags.append(_err("output-missing", name,
                              f"output points at missing node {target!r}"))
        else:
            roots.add(target)
    roots.update(k for k in valid if k.startswith("dom:"))
    reached = set()
    frontier = [r for r in roots if r in valid]
    while frontier:
        key = frontier.pop()
        if key in reached:
            continue
        reached.add(key)
        frontier.extend(r for r in valid[key].refs()
                        if r in valid and r not in reached)
    for key in valid:
        if key not in reached:
            diags.append(_warn("orphan-node", key,
                               "unreachable from any output"))


# -- pass 2: abstract interpretation ---------------------------------------------

def _ndim_of(key: str, plan: Plan, memo: Dict[str, int]) -> int:
    """Tensor rank of one node's value (0 = host scalar).  Pass 1
    guarantees refs resolve and the DAG is acyclic, so the recursion
    terminates."""
    if key in memo:
        return memo[key]
    node = plan.nodes[key]
    if isinstance(node, Contract):
        nd = len(node.free)
    elif isinstance(node, (Intersect, CutJoin, ShrinkageCorrect)):
        nd = 0
    elif isinstance(node, MobiusCombine):
        nd = _ndim_of(node.terms[0][1], plan, memo) if node.terms else 0
    else:                                   # LocalCount
        nd = len(node.keep)
    memo[key] = nd
    return nd


def _check_node(key, node, plan, ndims, diags):
    if isinstance(node, Contract):
        _check_contract(key, node, diags)
    elif isinstance(node, Intersect):
        if node.k < 3:
            diags.append(_err("bad-intersect", key,
                              f"clique enumeration needs k >= 3, got "
                              f"{node.k}"))
    elif isinstance(node, MobiusCombine):
        _check_divisor(key, node.divisor, diags)
        _check_terms(key, node.terms, None, plan, ndims, diags)
        arities = {ndims[r] for _, r in node.terms}
        if len(arities) > 1:
            diags.append(_err("shape-mismatch", key,
                              f"Möbius terms mix tensor ranks {sorted(arities)}"))
    elif isinstance(node, CutJoin):
        _check_join(key, node, plan, ndims, diags)
    elif isinstance(node, ShrinkageCorrect):
        _check_divisor(key, node.divisor, diags)
        base = plan.nodes[node.base]
        if not isinstance(base, (CutJoin, MobiusCombine)) or \
                ndims[node.base] != 0:
            diags.append(_err("bad-shrinkage-base", key,
                              f"base {node.base!r} is a "
                              f"{type(base).__name__} of rank "
                              f"{ndims[node.base]}, not a scalar join"))
        _check_terms(key, node.corrections, 0, plan, ndims, diags)
    elif isinstance(node, LocalCount):
        _check_join(key, node, plan, ndims, diags)
        _check_keep(key, node, diags)
        _check_terms(key, node.corrections, len(node.keep), plan, ndims,
                     diags)


def _check_contract(key, node, diags):
    p = node.pattern
    if any(not (0 <= v < p.n) for v in node.free) or \
            len(set(node.free)) != len(node.free):
        diags.append(_err("bad-free", key,
                          f"free vertices {node.free} invalid for an "
                          f"{p.n}-vertex pattern"))
        return
    bound = set(range(p.n)) - set(node.free)
    # order () is legal (lowering falls back to the greedy elimination
    # order).  A non-empty order eliminates the bound vertices; free
    # vertices may trail as output axes (``greedy_plan`` appends them),
    # so both the bound-only and the full-permutation spelling pass —
    # but every bound vertex must appear exactly once, before any free
    if node.order:
        nb = len(bound)
        head, tail = node.order[:nb], node.order[nb:]
        if sorted(head) != sorted(bound) or \
                (tail and sorted(tail) != sorted(node.free)):
            diags.append(_err("bad-order", key,
                              f"order {node.order} does not eliminate "
                              f"the bound vertices {sorted(bound)} "
                              f"(free {node.free} may only trail)"))
    if node.free:
        _check_marker_labels(key, node, diags)


def _check_marker_labels(key, node, diags):
    """Free-hom Contract patterns carry LABEL_STRIDE-packed labels: the
    cut-rank marker (free vertex of rank r gets marker r+1, bound
    vertices 0), optionally offset by the real vertex label.  The
    executor decodes with ``free_skeleton``, which keys off
    max(label) >= LABEL_STRIDE — so a mixed encoding, a missing marker,
    or a marker clash decodes to the wrong pattern silently."""
    p = node.pattern
    if p.labels is None:
        diags.append(_err("bad-label-encoding", key,
                          "free-hom pattern has no marker labels"))
        return
    labelled = [l >= LABEL_STRIDE for l in p.labels]
    if any(labelled) and not all(labelled):
        diags.append(_err("bad-label-encoding", key,
                          f"labels {p.labels} mix the labelled "
                          f"(>= {LABEL_STRIDE}) and unlabelled regimes — "
                          f"free_skeleton cannot decode them"))
        return
    markers = [l % LABEL_STRIDE if all(labelled) else l for l in p.labels]
    want = [0] * p.n
    for rank, v in enumerate(node.free):
        want[v] = rank + 1
    if markers != want:
        diags.append(_err("bad-label-encoding", key,
                          f"markers {markers} do not pin free vertices "
                          f"{node.free} (expected {want})"))


def _check_divisor(key, divisor, diags):
    if not isinstance(divisor, (int, float)) or divisor < 1 or \
            divisor != int(divisor):
        diags.append(_err("bad-divisor", key,
                          f"divisor {divisor!r} must be a positive "
                          f"integer (an automorphism-group order)"))


def _check_terms(key, terms, want_ndim, plan, ndims, diags):
    for coeff, ref in terms:
        if not isinstance(coeff, (int, float)) or not math.isfinite(coeff):
            diags.append(_err("bad-coefficient", key,
                              f"non-finite coefficient {coeff!r} on "
                              f"{ref!r}"))
        if want_ndim is not None and ndims[ref] != want_ndim:
            diags.append(_err("shape-mismatch", key,
                              f"term {ref!r} has rank {ndims[ref]}, "
                              f"expected {want_ndim}"))


def _check_join(key, node, plan, ndims, diags):
    """CutJoin / LocalCount factor structure: cut size sane, per-factor
    axis subsets well-formed and jointly covering the cut, factor
    tensors ranked to their subsets, subset factors only where the
    executor broadcasts them (the |cut| >= 3 tier)."""
    k = node.cut_size
    if not isinstance(k, int) or k < 1:
        diags.append(_err("bad-cut-size", key,
                          f"cut_size {k!r} must be a positive integer"))
        return
    if not node.factors:
        diags.append(_err("empty-join", key, "join has no factors"))
        return
    if node.axes is not None and len(node.axes) != len(node.factors):
        diags.append(_err("axes-arity", key,
                          f"{len(node.axes)} axis subsets for "
                          f"{len(node.factors)} factors"))
        return
    covered = set()
    for i, (terms, ax) in enumerate(zip(node.factors, node.factor_axes())):
        if not terms:
            diags.append(_err("empty-join", key, f"factor {i} has no terms"))
            continue
        if not ax or list(ax) != sorted(set(ax)) or \
                any(not (0 <= a < k) for a in ax):
            diags.append(_err("axis-out-of-range", key,
                              f"factor {i} axes {ax} not a sorted subset "
                              f"of cut ranks 0..{k - 1}"))
            continue
        if len(ax) < k and k < 3:
            # the legacy |cut| <= 2 kernels take equal-shape factors
            # only; axis-subset broadcasting is the |cut| >= 3 tier
            diags.append(_err("illegal-subset-axes", key,
                              f"factor {i} spans axes {ax} but the "
                              f"|cut| = {k} tier has no axis-subset "
                              f"broadcasting"))
        covered.update(ax)
        _check_terms(key, terms, len(ax), plan, ndims, diags)
    missing = set(range(k)) - covered
    if missing:
        diags.append(_err("cut-uncovered", key,
                          f"no factor spans cut rank(s) {sorted(missing)} "
                          f"— the join would sum a free axis unmasked"))


def _check_keep(key, node, diags):
    k = node.cut_size
    if not isinstance(k, int) or k < 1:
        return                               # bad-cut-size already flagged
    keep = node.keep
    if not keep or list(keep) != sorted(set(keep)) or \
            any(not (0 <= a < k) for a in keep):
        diags.append(_err("keep-outside-cut", key,
                          f"keep {keep} is not a non-empty sorted subset "
                          f"of cut ranks 0..{k - 1}"))
        return
    if 1 < len(keep) < k:
        diags.append(_err("illegal-keep", key,
                          f"keep {keep}: the executor reduces to a single "
                          f"surviving axis or none — partial multi-axis "
                          f"keeps have no route"))
    elif len(keep) < k and k > 3:
        diags.append(_err("illegal-route", key,
                          f"keep-axis reduce at |cut| = {k} has no "
                          f"implementation (kernel and XLA tiers stop at "
                          f"|cut| = 3)"))


def _check_outputs(plan, ndims, diags):
    for name, target in plan.outputs.items():
        nd = ndims[target]
        node = plan.nodes[target]
        if is_local_output(name):
            want_vec = name.startswith("loca:")
            if nd == 0 or (want_vec and nd != 1):
                diags.append(_err("output-shape", name,
                                  f"local output needs a "
                                  f"{'vector' if want_vec else 'tensor'}, "
                                  f"node {target!r} has rank {nd}"))
            else:
                # anchored vectors may come off the keep-axis join OR
                # the flat Möbius fallback (anchored_direct_candidate's
                # ``locd:`` node); unanchored tensors only off the join
                legal = (LocalCount, MobiusCombine) if want_vec \
                    else (LocalCount,)
                if not isinstance(node, legal):
                    diags.append(_err("output-shape", name,
                                      f"local output served by a "
                                      f"{type(node).__name__}"))
        elif nd != 0:
            diags.append(_err("output-shape", name,
                              f"count output needs a scalar, node "
                              f"{target!r} has rank {nd}"))


# -- budget ----------------------------------------------------------------------

def _join_elements(node, n: int) -> int:
    return sum(n ** len(ax) for ax in node.factor_axes())


def _check_budget(plan, info, budget, diags):
    """Factor-element totals vs the plan budget, mirroring what costing
    admits: |cut| >= 3 joins are priced by their summed factor sizes and
    refused past 4x budget (``costing._kernel_join_cost``), and the
    dense fallback hard-fails there too (``lowering._dense_expand``).  A
    committed CutJoin over the line is a plan that could never have been
    selected — an error.  LocalCount outputs can be legitimately
    over-budget: the frontend keeps an *uncommitted* local fallback when
    no priced candidate fits, so those only warn."""
    cap = 4 * budget
    n = info.n
    for key, node in plan.nodes.items():
        if not isinstance(node, (CutJoin, LocalCount)):
            continue
        if not isinstance(node.cut_size, int) or node.cut_size < 3:
            continue
        elems = _join_elements(node, n)
        if elems <= cap:
            continue
        msg = (f"factor tensors total {elems:.3e} elements, over 4x the "
               f"plan budget ({cap:.3e})")
        if isinstance(node, CutJoin):
            diags.append(_err("budget-overflow", key, msg))
        else:
            diags.append(_warn("budget-overflow", key,
                               msg + " (uncommitted local fallback)"))


# -- exact_block precertification ------------------------------------------------

def _hom_free_bound(pattern, free, info: GraphInfo) -> float:
    """Worst-case upper bound on any entry of hom_free(pattern, free):
    grow the pattern from the pinned free set; a vertex adjacent to an
    already-placed one has at most max_degree images, an unreachable one
    at most n.  Sound for any graph with those statistics — entries
    count homomorphisms extending the pinned assignment, and every
    extension is built by such a placement sequence."""
    skel = free_skeleton(pattern)
    adj = skel.adj()
    placed = set(free)
    remaining = set(range(skel.n)) - placed
    bound = 1.0
    while remaining:
        attached = [v for v in sorted(remaining) if adj[v] & placed]
        if attached:
            v = attached[0]
            bound *= max(1, info.max_degree)
        else:
            v = min(remaining)
            bound *= max(1, info.n)
        placed.add(v)
        remaining.remove(v)
    return bound


def _factor_bound(plan, terms, info: GraphInfo) -> Optional[float]:
    """Upper bound on max|M| for one Möbius factor M = Σ coeff · hom —
    the triangle inequality over per-term hom bounds.  None when a term
    is not a free-hom Contract (no static bound available)."""
    total = 0.0
    for coeff, ref in terms:
        node = plan.nodes.get(ref)
        if not isinstance(node, Contract) or not node.free:
            return None
        total += abs(coeff) * _hom_free_bound(node.pattern, node.free, info)
    return total


def _guarded_nodes(plan):
    """(key, node) of every join the kernel tier guards with
    ``exact_block`` at execution time: scalar CutJoins at |cut| <= 3 and
    single-surviving-axis LocalCounts at |cut| in {2, 3} (everything
    else takes a dense or XLA route with no guard)."""
    for key, node in plan.nodes.items():
        if isinstance(node, CutJoin):
            if isinstance(node.cut_size, int) and 1 <= node.cut_size <= 3:
                yield key, node
        elif isinstance(node, LocalCount):
            if isinstance(node.cut_size, int) and \
                    node.cut_size in (2, 3) and len(node.keep) == 1:
                yield key, node


def precertify(plan: Plan, info: GraphInfo, *, max_block: int = 1024,
               num_shards: int = 1) -> Dict[str, int]:
    """Statically certify ``exact_block`` for every guarded join whose
    factor magnitudes are boundable: node key -> chunk size for which
    the f32-chunk kernel is provably exact on *any* graph matching
    ``info``.  Execution trusts the certificate instead of scanning
    factor tensors device→host per query (see
    ``lowering.CompiledPlan._guard_block``).  The bound is conservative
    (degree-product worst case), so a certificate is always sound; its
    absence just means the runtime scan decides.

    ``num_shards`` extends the certificate to the block-sharded tier
    (``distributed/cutjoin``): each shard's chunks accumulate products
    of *slices* of the same factors, and a slice's max magnitude never
    exceeds the global max the bound dominates — so the single-device
    certificate certifies every per-shard block as-is, for any shard
    count.  The parameter exists so callers state the mesh they verify
    against (and so a future tier with shard-dependent chunking has a
    seam); it cannot change the result, by the argument above."""
    assert num_shards >= 1, num_shards
    out: Dict[str, int] = {}
    for key, node in _guarded_nodes(plan):
        bounds = [_factor_bound(plan, terms, info) for terms in node.factors]
        if any(b is None for b in bounds):
            continue
        block = _mr.exact_block((), max_block=max_block, maxes=bounds)
        if block is not None:
            out[key] = int(block)
    return out


def shard_check(plan: Plan, info: GraphInfo, num_shards: int, *,
                budget: Optional[int] = None) -> VerifyResult:
    """Shard-legality of one plan on a ``num_shards``-way data mesh —
    advisory diagnostics layered over ``verify`` (run that first for
    structure/shapes):

    ``shard-small-graph``      n < shards: the executor falls back to
                               single-device wholesale
                               (``lowering._mesh_shards``) — a mesh that
                               size buys nothing on this graph.
    ``shard-indivisible``      cut axis 0 does not divide evenly: legal
                               (the sharded tier zero-pads axis-0
                               carriers to the shard x tile multiple,
                               which is value-preserving), but the last
                               shard streams padding — noted so sizing
                               is a conscious choice.
    ``shard-budget-overflow``  a join's *per-shard* resident factor
                               elements (axis-0 carriers at n/shards
                               rows, the rest replicated) still exceed
                               4x budget — sharding did not buy the
                               memory headroom the budget models.  The
                               same code covers Contract nodes on the
                               collective-einsum route
                               (``distributed/contract``): per-shard
                               residency there is the adjacency row
                               block plus the widest post-psum
                               *replicated* intermediate plus the
                               free-output row slice.

    All warnings: none makes a sharded execution incorrect — per-shard
    blocks stay certified (see ``precertify``) and padding preserves
    values — they flag mesh/graph pairings that waste the mesh."""
    assert num_shards >= 1, num_shards
    res = VerifyResult()
    if num_shards <= 1:
        return res
    n = info.n
    if n < num_shards:
        res.diagnostics.append(_warn(
            "shard-small-graph", "*",
            f"graph has {n} vertices but the mesh {num_shards} shards — "
            f"execution falls back to single-device"))
        return res
    if n % num_shards:
        res.diagnostics.append(_warn(
            "shard-indivisible", "*",
            f"n = {n} does not divide over {num_shards} shards — the "
            f"padding path runs (correct, but the last shard streams "
            f"{(-n) % num_shards} zero rows)"))
    if budget is None:
        b = plan.meta.get("budget")
        budget = int(b) if isinstance(b, (int, float)) else None
    if budget is not None:
        cap = 4 * budget
        rows = -(-n // num_shards)
        for key, node in _guarded_nodes(plan):
            elems = sum(
                rows * n ** (len(ax) - 1) if 0 in ax else n ** len(ax)
                for ax in node.factor_axes())
            if elems > cap:
                res.diagnostics.append(_warn(
                    "shard-budget-overflow", key,
                    f"per-shard factor residency {elems:.3e} elements "
                    f"still over 4x budget ({cap:.3e}) at "
                    f"{num_shards} shards"))
        # Contract nodes on the collective-einsum route: each shard
        # holds its adjacency row block, every elimination step's
        # intermediate comes back *replicated* from the psum (only the
        # free-output step stays sharded), so the widest replicated
        # intermediate dominates per-shard residency alongside the row
        # block and the output row slice.
        for key, node in plan.nodes.items():
            if not isinstance(node, Contract):
                continue
            free = tuple(node.free)
            q = free_skeleton(node.pattern) if free else node.pattern
            order = tuple(node.order) if node.order else \
                _H.greedy_plan(q, free)
            try:
                widths = _H.elimination_widths(q, order, free=free)
            except Exception:
                continue              # malformed order — verify() flags it
            inter = max((n ** w for _, w in widths), default=1)
            out_slice = rows * n ** (len(free) - 1) if free else 1
            elems = rows * n + inter + out_slice
            if elems > cap:
                res.diagnostics.append(_warn(
                    "shard-budget-overflow", key,
                    f"per-shard contraction residency {elems:.3e} "
                    f"elements (row block + widest replicated "
                    f"intermediate) still over 4x budget ({cap:.3e}) "
                    f"at {num_shards} shards"))
    return res


def refusal_flags(plan: Plan, info: GraphInfo) -> List[Diagnostic]:
    """Joins that can *never* take the kernel route: if a lower bound on
    the factor-magnitude product already exceeds EXACT_LIMIT at the
    smallest chunk, every serving query pays the guard scan and falls
    back to the dense f64 join.  The lower bound uses the factor's
    identity term (the largest free-hom pattern in its Möbius family,
    whose entries dominate the alternating sum for frontend-shaped
    families): for a tree skeleton on k vertices, greedy extension gives
    inj >= n · max(0, min_degree − k + 2)^(k−1) embeddings spread over
    at most n^rank entries.  Advisory only — compile-time signal to
    re-plan (a wider budget, a different cut) rather than refuse."""
    out: List[Diagnostic] = []
    for key, node in _guarded_nodes(plan):
        prod = 1.0
        for terms, ax in zip(node.factors, node.factor_axes()):
            lb = _factor_floor(plan, terms, len(ax), info)
            if lb is None or lb <= 0.0:
                prod = 0.0
                break
            prod *= lb
        if prod * MIN_BLOCK > EXACT_LIMIT:
            out.append(_warn(
                "always-refused", key,
                f"factor magnitude floor {prod:.3e} blows the exactness "
                f"limit ({EXACT_LIMIT:.3e}) at the minimum chunk — every "
                f"query will guard-scan and fall back to the dense f64 "
                f"join"))
    return out


def _factor_floor(plan, terms, rank, info: GraphInfo) -> Optional[float]:
    """Lower bound on max|M| for one factor, via its identity term only
    (sound for frontend Möbius families, where the combined entries are
    injective counts >= 0 and the identity hom dominates).  Tree
    skeletons only — their injective-embedding floor is closed-form."""
    best = None
    for _, ref in terms:
        node = plan.nodes.get(ref)
        if not isinstance(node, Contract) or not node.free:
            return None
        if best is None or node.pattern.n > best.pattern.n:
            best = node
    skel = free_skeleton(best.pattern)
    k = skel.n
    if not (skel.is_connected() and len(skel.edges) == k - 1):
        return None
    if k == 1:
        inj_floor = float(info.n)
    else:
        inj_floor = float(info.n) * \
            float(max(0, info.min_degree - k + 2)) ** (k - 1)
    return inj_floor / float(info.n) ** rank


# -- morph identity validation ----------------------------------------------------

def _km_labels(p, m: int) -> Optional[tuple]:
    """Vertex labels for the labelled complete graph K_m: cycle the
    pattern's own alphabet, so every pattern label is realised."""
    if p.labels is None:
        return None
    alphabet = sorted(set(p.labels))
    return tuple(alphabet[i % len(alphabet)] for i in range(m))


def _brute_hom_km(q, m: int, glabels: Optional[tuple]) -> int:
    """hom(q, K_m) by enumeration: maps sending every pattern edge to
    distinct endpoints (all distinct pairs are K_m edges), respecting
    labels when both sides carry them."""
    import itertools
    total = 0
    for f in itertools.product(range(m), repeat=q.n):
        if glabels is not None and q.labels is not None and any(
                glabels[f[v]] != q.labels[v] for v in range(q.n)):
            continue
        if all(f[u] != f[v] for u, v in q.edges):
            total += 1
    return total


def _brute_inj_km(p, m: int, glabels: Optional[tuple]) -> int:
    """inj(p, K_m) by enumeration: every injective (label-respecting)
    map embeds, since all distinct pairs are adjacent in K_m."""
    import itertools
    total = 0
    for f in itertools.permutations(range(m), p.n):
        if glabels is not None and p.labels is not None and any(
                glabels[f[v]] != p.labels[v] for v in range(p.n)):
            continue
        total += 1
    return total


def morph_check(candidate) -> VerifyResult:
    """Validate one committed morph identity (``morph.MorphCandidate``)
    on the pattern-lattice endpoints, graph-free:

    * empty graph: every edged hom/inj vanishes, so the identity
      degenerates to 0 = 0 — a nonzero coefficient on an edge*less*
      quotient would break it (quotients of an edged pattern always
      keep an edge);
    * complete graphs K_m, m in {n, n+1, n+2} (label-cycled when the
      pattern is labelled): both sides brute-forced by enumeration and
      compared as exact integers — wrong Möbius coefficients, a missing
      quotient, or a wrong automorphism divisor all surface here.

    Diagnostics: ``morph-endpoint-empty``, ``morph-endpoint-complete``,
    ``morph-divisor``; ``ok`` means the identity is safe to serve."""
    res = VerifyResult()
    p = candidate.pattern
    pk = f"morph:{p.n}v{p.m}e"
    if p.m:
        for coeff, q in candidate.terms:
            if coeff and not q.m:
                res.diagnostics.append(_err(
                    "morph-endpoint-empty", pk,
                    f"coefficient {coeff} on edgeless quotient breaks "
                    f"the empty-graph endpoint (lhs 0, rhs "
                    f"{coeff} * hom(edgeless) != 0)"))
    divisor = getattr(candidate, "divisor", None)
    if divisor is not None and divisor != p.aut_order():
        res.diagnostics.append(_err(
            "morph-divisor", pk,
            f"divisor {divisor} != |Aut| = {p.aut_order()}"))
    for m in range(p.n, p.n + 3):
        glabels = _km_labels(p, m)
        lhs = _brute_inj_km(p, m, glabels)
        rhs = sum(coeff * _brute_hom_km(q, m, glabels)
                  for coeff, q in candidate.terms)
        if lhs != rhs:
            res.diagnostics.append(_err(
                "morph-endpoint-complete", pk,
                f"identity fails on K_{m}: brute inj {lhs} != "
                f"expanded sum {rhs}"))
    return res
