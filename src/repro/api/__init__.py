"""Partial-embedding API (paper §5): local counts, anchored vectors,
early-exit existence, and per-vertex counts read off the decomposition
join's cut tensors — see ``repro.api.local`` for the full story."""
from repro.api.local import (LocalCounts, exists, local_counts,
                             pattern_domains, vertex_counts)

__all__ = ["LocalCounts", "local_counts", "exists", "vertex_counts",
           "pattern_domains"]
