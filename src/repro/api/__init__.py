"""Partial-embedding API (paper §5): local counts, anchored vectors,
early-exit existence, and per-vertex counts read off the decomposition
join's cut tensors — see ``repro.api.local`` for the full story."""
from repro.api.local import (LocalCounts, exists, local_counts,
                             pattern_domains, plan_vertex_counts,
                             top_vertices, vertex_counts)

__all__ = ["LocalCounts", "local_counts", "exists", "vertex_counts",
           "plan_vertex_counts", "top_vertices", "pattern_domains"]
