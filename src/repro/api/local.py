"""Partial-embedding API: local counts off the decomposition join.

The paper's second headline contribution (§5) is an API that exposes
*per-partial-embedding* information while preserving the advantages of
pattern decomposition: systems that materialise full embeddings pay the
whole enumeration to answer any localised question, whereas the
decomposition join already holds every answer in its cut tensors — the
factor product *before* the final Σ_{e_c} reduce is exactly the table of
completion counts per cut-vertex assignment.  This module reads that
table instead of rebuilding it:

``local_counts(p, g)``            the local tensor over the chosen
                                  cutting set: entry e_c = # injective
                                  maps of ``p`` pinning the cut to e_c.
``local_counts(p, g, anchor=v)``  the (N,) anchored vector: completion
                                  counts with pattern vertex v pinned to
                                  each graph vertex (v is forced into
                                  the cutting set when one contains it;
                                  flat Möbius otherwise).
``exists(p, g)``                  early-exit existence: an all-zero
                                  factor tensor decides False before the
                                  join or shrinkage corrections run.
``vertex_counts(p, g)``           orbit-weighted per-vertex counts: entry
                                  u = # edge-induced embeddings of ``p``
                                  containing graph vertex u (Σ over
                                  orbits of |orbit| · anchored / |Aut|).
``vertex_counts(p, g, top_k=K)``  the K hottest vertices only, as
                                  (value, vertex) pairs — serving hosts
                                  read hotspots without the (N,) vector.
``pattern_domains(counter, p)``   FSM MINI domains per orbit
                                  representative through the same route
                                  (the decomposed domain path the count
                                  plans' cut tensors already feed).

All entry points compile through ``repro.compiler`` (plan cache, CSE
with the count plans) and fall back to an uncached direct assembly over
a shared ``CountingEngine`` when compilation is unavailable or fails.
Counts are exact integers (f64 end to end, f32 kernel chunks only under
the proven-exact guard).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro import obs
from repro.core.counting import CountingEngine
from repro.core.pattern import Pattern
from repro.graph.storage import Graph


@dataclass
class LocalCounts:
    """One partial-embedding answer: ``counts[e_c]`` is the number of
    injective maps of ``pattern`` sending the cut vertices (``axes``, in
    ascending order) to e_c — or, when ``anchor`` is set, ``counts[u]``
    is the completion count with the anchor pinned to graph vertex u
    (then ``axes == (anchor,)``).  Unanchored tensors are computed on
    ``pattern.canonical()`` and ``axes`` name *canonical-form* vertices:
    the answer is shared across isomorphic renumberings, so it must be
    expressed in the one numbering every caller can reconstruct (map
    back through ``pattern.canonical_perm()``).  ``style`` records the
    route taken (``local`` = decomposition join, ``local-direct`` =
    flat Möbius fallback)."""
    pattern: Pattern
    anchor: Optional[int]
    axes: Optional[tuple]               # cut vertices backing each axis
    counts: np.ndarray
    style: str = "local"
    from_cache: bool = False

    def total(self) -> float:
        """Σ over assignments = inj(pattern) (injective tuple count)."""
        return float(self.counts.sum())


def _compile_local(pattern: Pattern, graph: Graph, *, counter, cache,
                   apct=None, budget: int = 1 << 27):
    from repro import compiler
    return compiler.compile((pattern,), graph, counter=counter,
                            cache=cache, apct=apct, budget=budget,
                            local=True)


def _direct_plan(pattern: Pattern, graph: Graph, anchor: Optional[int],
                 budget: int):
    """Uncompiled fallback: assemble the cheapest-by-construction local
    fragment directly (smallest eligible cutting set — containing the
    anchor when set — else the flat Möbius route for anchored queries).
    Returns (plan, out_key, cut, style) or None when no unanchored
    tensor exists (cliques).  Unanchored fragments build on the
    canonical form (same axis semantics as the compiled path)."""
    from repro.compiler import frontend
    from repro.compiler.ir import Plan
    from repro.core.decomposition import cutting_sets
    if anchor is None:
        pattern = pattern.canonical()
    cand = None
    for cut in sorted(cutting_sets(pattern), key=len):
        if anchor is not None and anchor not in cut:
            continue
        cand = frontend.local_candidate(pattern, cut, graph_n=graph.n,
                                        anchor=anchor, budget=budget)
        if cand is not None:
            break
    if cand is None:
        if anchor is None:
            return None
        cand = frontend.anchored_direct_candidate(pattern, anchor)
    plan = Plan()
    for node in cand.nodes:
        plan.add(node)
    return plan, cand.out_key, cand.cut, cand.style


def local_counts(pattern: Pattern, graph: Graph, *,
                 anchor: Optional[int] = None,
                 counter: Optional[CountingEngine] = None,
                 cache=None, apct=None, use_compiler: bool = True,
                 budget: int = 1 << 27) -> LocalCounts:
    """Per-partial-embedding completion counts (see module docstring).

    ``counter`` shares hom/free-hom memos with other queries; ``cache``
    follows ``compiler.compile`` semantics (None = process cache,
    False = uncached).  ``use_compiler=False`` — or any compile
    failure — takes the direct assembly path over the shared engine.
    Raises ``ValueError`` for an unanchored query on a pattern without
    an eligible cutting set (cliques: every vertex pair is adjacent, so
    no local tensor exists — anchored queries still work)."""
    if anchor is not None and not (0 <= anchor < pattern.n):
        raise ValueError(f"anchor {anchor} outside pattern vertices")
    counter = counter or CountingEngine(graph, budget=budget)
    if use_compiler:
        try:
            cp = _compile_local(pattern, graph, counter=counter,
                                cache=cache, apct=apct, budget=budget)
            from repro.compiler.ir import local_key
            key = local_key(pattern, anchor)
            if cp.has_local(pattern, anchor):
                cut = cp.plan.meta.get("local_cuts", {}).get(key)
                axes = ((anchor,) if anchor is not None
                        else tuple(cut) if cut else None)
                return LocalCounts(pattern, anchor, axes,
                                   cp.local_counts(pattern, anchor),
                                   style=("local" if cut
                                          else "local-direct"),
                                   from_cache=cp.from_cache)
            if anchor is None:
                raise ValueError(
                    f"{pattern!r} has no eligible cutting set: no "
                    f"unanchored local tensor (anchored queries work)")
        except ValueError:
            raise
        except Exception:               # direct assembly takes over
            obs.counter("api.compile_fallbacks", entry="local_counts")
    from repro.compiler import lowering
    built = _direct_plan(pattern, graph, anchor, budget)
    if built is None:
        raise ValueError(
            f"{pattern!r} has no eligible cutting set: no unanchored "
            f"local tensor (anchored queries work)")
    plan, out_key, cut, style = built
    cp = lowering.lower(plan, graph, counter=counter, budget=budget)
    arr = np.asarray(cp.value(out_key), np.float64)
    axes = ((anchor,) if anchor is not None
            else tuple(sorted(cut)) if cut else None)
    return LocalCounts(pattern, anchor, axes, arr, style=style)


def exists(pattern: Pattern, graph: Graph, *,
           counter: Optional[CountingEngine] = None, cache=None,
           apct=None, use_compiler: bool = True,
           budget: int = 1 << 27) -> bool:
    """Pattern existence with the partial-embedding early exit: factor
    tensors evaluate per subpattern, and any all-zero factor decides
    False before the join or shrinkage corrections run.  Falls back to
    the engine's scalar existence when no local plan is available."""
    counter = counter or CountingEngine(graph, budget=budget)
    if use_compiler:
        try:
            cp = _compile_local(pattern, graph, counter=counter,
                                cache=cache, apct=apct, budget=budget)
            return cp.exists(pattern)
        except Exception:
            obs.counter("api.compile_fallbacks", entry="exists")
    try:
        lc = local_counts(pattern, graph, counter=counter,
                          use_compiler=False, budget=budget)
        return bool(np.max(lc.counts) > 0.5)
    except ValueError:                  # no cutting set (cliques)
        return counter.existence(pattern)


def plan_vertex_counts(cp, pattern: Pattern) -> np.ndarray:
    """Orbit-weighted per-vertex embedding counts read off an
    already-compiled ``local=True`` plan: Σ over orbits of |orbit| ·
    anchored vector, / |Aut|.  The one home of the weighting formula —
    ``vertex_counts``, the serving batcher's hotspot reader, and
    ``mine.py`` all reduce through here, so the three routes cannot
    drift apart."""
    total = np.zeros(cp.graph.n)
    for orbit in pattern.vertex_orbits():
        total += len(orbit) * cp.local_counts(pattern, orbit[0])
    return total / pattern.aut_order()


def top_vertices(vec: np.ndarray, k: int) -> list:
    """The K hottest entries of a per-vertex vector as (value, vertex)
    pairs, hottest first (ties broken by vertex id, ascending, so the
    answer is deterministic).  ``argpartition`` selects in O(N), then
    only the K winners are sorted — the full vector is never ranked."""
    k = max(0, min(int(k), len(vec)))
    if k == 0:
        return []
    part = np.argpartition(vec, len(vec) - k)[len(vec) - k:]
    # widen to every vertex tied with the selection boundary, then rank
    # (value desc, vertex asc) — argpartition alone picks arbitrary
    # members among boundary ties, which would make the answer depend
    # on the partition's internal order
    cand = np.nonzero(vec >= vec[part].min())[0]
    cand = cand[np.lexsort((cand, -vec[cand]))][:k]
    return [(float(vec[i]), int(i)) for i in cand]


def vertex_counts(pattern: Pattern, graph: Graph, *,
                  counter: Optional[CountingEngine] = None, cache=None,
                  apct=None, use_compiler: bool = True,
                  budget: int = 1 << 27, top_k: Optional[int] = None):
    """Orbit-weighted per-vertex embedding counts: entry u is the number
    of edge-induced embeddings of ``pattern`` containing graph vertex u.
    One anchored vector per automorphism orbit suffices (orbit members
    share their vector); weighting by |orbit| counts each embedding once
    per pattern position it gives u, and /|Aut| collapses tuple
    multiplicity — so Σ_u vertex_counts[u] = n_p · inj(p) / |Aut|.

    ``top_k=K`` returns only the K hottest vertices as (value, vertex)
    pairs, hottest first — the streaming reader serving hosts want:
    orbit vectors accumulate internally, hotspots are selected in O(N)
    (``argpartition``), and the full (N,) vector never crosses the API.
    """
    counter = counter or CountingEngine(graph, budget=budget)
    total = np.zeros(graph.n)
    if use_compiler:
        try:
            # one compile serves every orbit: the plan registers all
            # anchored outputs, and its node-value/factor memos are
            # shared across the orbit reads
            cp = _compile_local(pattern, graph, counter=counter,
                                cache=cache, apct=apct, budget=budget)
            total = plan_vertex_counts(cp, pattern)
            return total if top_k is None else top_vertices(total, top_k)
        except Exception:               # per-orbit direct path takes over
            total[:] = 0.0
            obs.counter("api.compile_fallbacks", entry="vertex_counts")
    for orbit in pattern.vertex_orbits():
        lc = local_counts(pattern, graph, anchor=orbit[0],
                          counter=counter, cache=cache, apct=apct,
                          use_compiler=False, budget=budget)
        total += len(orbit) * lc.counts
    total /= pattern.aut_order()
    return total if top_k is None else top_vertices(total, top_k)


def pattern_domains(counter: CountingEngine, p: Pattern) -> dict:
    """FSM MINI domains {orbit representative -> (N,) vector} through
    the partial-embedding route: anchored local counts ride the
    decomposition join (reusing cut tensors the engine already holds)
    instead of the flat Möbius free-hom expansion; any failure falls
    back to the engine's vectorised ``inj_free_all``.  Values equal
    ``counter.inj_free(p, rep)`` exactly — the anchored vector *is* the
    domain."""
    reps = [o[0] for o in p.vertex_orbits()]
    try:
        return {rep: local_counts(p, counter.graph, anchor=rep,
                                  counter=counter,
                                  use_compiler=False).counts
                for rep in reps}
    except Exception:
        dom = counter.inj_free_all(p)
        return {rep: np.asarray(dom[rep]) for rep in reps}
