"""Pattern-to-plan compiler: DwarvesGraph's compilation tier.

The paper's headline design is *compilation-based* graph pattern mining:
generate candidate algorithms for every decomposition choice, cost them
with an accurate model, and ship the best one as an executable.  This
package is that tier, as a pipeline of five stages:

    pattern set ──frontend──► candidate plan IR fragments
                 (decomposition.candidates × homomorphism orders,
                  CutJoin/Shrinkage decomposition joins)
    fragments  ──costing───► winning joint plan
                 (APCT cost model, cross-pattern CSE: shared quotient
                  contractions scheduled once across the application)
    plan IR    ──lowering──► jitted executables
                 (CountingEngine einsum contractions, clique ordered
                  enumeration, Pallas triangle kernel)
    plan IR    ──cache─────► keyed by (canonical pattern set, graph
                  signature): compile once, execute many

Vertex labels are first-class through every stage: labelled patterns
generate the same candidate space (decomposition joins included — the
label mask lives inside each CutJoin factor, so the |cut| <= 2 Pallas
kernel tier runs unchanged), costing scales count bounds by label
selectivity, and lowering binds the pattern's label indices to the
bound graph's one-hot indicator rows at plan-bind time — one plan
serves any graph with a compatible label alphabet (out-of-alphabet
labels bind to the zero vector).

``compile(patterns, graph)`` is the single entry point; it returns a
``CompiledPlan`` whose ``.plan`` is the serializable IR (``to_json``)
and whose ``.count(p)`` / ``.counts()`` execute it.  With
``domains=True`` the plan additionally carries FSM MINI-domain nodes
(one vector per automorphism orbit) served by ``.domains(p)`` /
``.mini_support(p)`` — the level-wise FSM in ``core.fsm`` compiles each
candidate frontier jointly through this path.  ``MiningEngine``,
``launch.mine`` and ``serve.batching`` all route through here; the
legacy direct path in ``core.counting`` remains as the fallback.
"""
from __future__ import annotations

from typing import Iterable, Optional, Union

from repro.core.pattern import Pattern
from repro.graph.storage import Graph
from repro.compiler import cache as _cache_mod
from repro.compiler import costing, frontend
from repro.compiler.cache import PlanCache, plan_key
from repro.compiler.ir import Plan, pattern_key
from repro.compiler.lowering import CompiledPlan, lower

__all__ = ["compile", "Plan", "PlanCache", "CompiledPlan", "pattern_key",
           "plan_key", "default_cache"]

_DEFAULT_CACHE = PlanCache()


def default_cache() -> PlanCache:
    """The process-wide plan cache used when ``compile(cache=None)``."""
    return _DEFAULT_CACHE


def _label_fracs(patterns, graph):
    """label -> vertex fraction of the bound graph, for selectivity
    pricing; None unless a labelled pattern meets a labelled graph."""
    if graph.labels is None or all(p.labels is None for p in patterns):
        return None
    import numpy as np
    counts = np.bincount(graph.labels, minlength=graph.num_labels)
    return {l: counts[l] / max(graph.n, 1) for l in range(graph.num_labels)}


def compile(patterns: Union[Pattern, Iterable[Pattern]], graph: Graph, *,
            apct=None, counter=None, cache: Optional[PlanCache] = None,
            budget: int = 1 << 27, max_cutjoin_cut: int = 2,
            use_pallas: bool = False, cutjoin_kernel: bool = True,
            domains: bool = False) -> CompiledPlan:
    """Compile a pattern (or application pattern set) for one graph.

    Cache hit: deserialise the stored plan and lower it (no search).
    Cache miss: build candidates per pattern, pick the joint winner under
    the shared-pool cost model, store the plan, lower it.

    ``cache=False`` disables caching; ``cache=None`` uses the process
    cache.  ``apct``/``counter`` let callers (e.g. ``MiningEngine``)
    share their profiling table and hom memo with the compiled plan —
    the counter's materialised hom/free-hom memos also feed costing, so
    re-compiles against a warm engine prefer decompositions whose cut
    tensors already exist.  ``cutjoin_kernel=False`` keeps CutJoin on the
    XLA ``_join_reduce`` path (the kernel tier's oracle).

    ``domains=True`` additionally emits FSM MINI-domain nodes per
    pattern (one free-hom Möbius combination per automorphism orbit),
    served by ``CompiledPlan.domains`` / ``.mini_support``; their
    free-hom contractions CSE-merge with decomposition-join factors.  A
    cached plan without domain nodes misses a ``domains=True`` lookup
    (and recompiles); the converse hit is fine — domain nodes are lazy.
    """
    if isinstance(patterns, Pattern):
        patterns = (patterns,)
    patterns = tuple(patterns)
    if not patterns:
        raise ValueError("compile() needs at least one pattern")

    if counter is not None:
        budget = counter.budget              # cost exactly what will execute
    use_cache = cache is not False
    if cache is None:
        cache = _DEFAULT_CACHE
    key = plan_key(patterns, graph)
    if use_cache:
        plan = cache.get(key)
        # a stored plan is only valid under the compile configuration
        # that selected it: candidate eligibility depends on budget and
        # max_cutjoin_cut, so a cross-config hit could return a plan the
        # executor must refuse (PlanTooWide) — recompile instead.  A
        # domains=True request needs the domain nodes present; a plan
        # that has them serves domain-less requests unchanged.
        if plan is not None and plan.meta.get("budget") == budget \
                and plan.meta.get("max_cutjoin_cut") == max_cutjoin_cut \
                and (not domains or plan.meta.get("domains")):
            return lower(plan, graph, counter=counter,
                         use_pallas=use_pallas, from_cache=True,
                         budget=budget, cutjoin_kernel=cutjoin_kernel)

    if apct is None:
        from repro.core.apct import APCT
        apct = APCT(graph)
    per_pattern = [(p, frontend.pattern_candidates(
        p, graph_n=graph.n, budget=budget,
        max_cutjoin_cut=max_cutjoin_cut)) for p in patterns]
    selections, total_cost = costing.select_candidates(
        per_pattern, apct, graph.n, budget, counter=counter,
        label_fracs=_label_fracs(patterns, graph))
    plan = frontend.assemble(selections)
    if domains:
        for p in patterns:
            for node in frontend.domain_candidate(p).nodes:
                plan.add(node)
    plan.meta.update({
        "key": key,
        "budget": budget,
        "max_cutjoin_cut": max_cutjoin_cut,
        "domains": domains,
        "estimated_cost": total_cost,
        "styles": {pattern_key(p): cand.style for p, cand in selections},
        "cuts": {pattern_key(p): sorted(cand.cut) if cand.cut else None
                 for p, cand in selections},
    })
    if use_cache:
        cache.put(key, plan)
    return lower(plan, graph, counter=counter, use_pallas=use_pallas,
                 from_cache=False, budget=budget,
                 cutjoin_kernel=cutjoin_kernel)
