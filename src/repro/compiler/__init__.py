"""Pattern-to-plan compiler: DwarvesGraph's compilation tier.

The paper's headline design is *compilation-based* graph pattern mining:
generate candidate algorithms for every decomposition choice, cost them
with an accurate model, and ship the best one as an executable.  This
package is that tier, as a pipeline of five stages:

    pattern set ──frontend──► candidate plan IR fragments
                 (decomposition.candidates × homomorphism orders,
                  CutJoin/Shrinkage decomposition joins)
    fragments  ──costing───► winning joint plan
                 (APCT cost model, cross-pattern CSE: shared quotient
                  contractions scheduled once across the application)
    plan IR    ──lowering──► jitted executables
                 (CountingEngine einsum contractions, clique ordered
                  enumeration, Pallas triangle kernel)
    plan IR    ──cache─────► keyed by (canonical pattern set, graph
                  signature): compile once, execute many

``compile(patterns, graph)`` is the single entry point; it returns a
``CompiledPlan`` whose ``.plan`` is the serializable IR (``to_json``)
and whose ``.count(p)`` / ``.counts()`` execute it.  ``MiningEngine``,
``launch.mine`` and ``serve.batching`` all route through here; the
legacy direct path in ``core.counting`` remains as the fallback.
"""
from __future__ import annotations

from typing import Iterable, Optional, Union

from repro.core.pattern import Pattern
from repro.graph.storage import Graph
from repro.compiler import cache as _cache_mod
from repro.compiler import costing, frontend
from repro.compiler.cache import PlanCache, plan_key
from repro.compiler.ir import Plan, pattern_key
from repro.compiler.lowering import CompiledPlan, lower

__all__ = ["compile", "Plan", "PlanCache", "CompiledPlan", "pattern_key",
           "plan_key", "default_cache"]

_DEFAULT_CACHE = PlanCache()


def default_cache() -> PlanCache:
    """The process-wide plan cache used when ``compile(cache=None)``."""
    return _DEFAULT_CACHE


def compile(patterns: Union[Pattern, Iterable[Pattern]], graph: Graph, *,
            apct=None, counter=None, cache: Optional[PlanCache] = None,
            budget: int = 1 << 27, max_cutjoin_cut: int = 2,
            use_pallas: bool = False,
            cutjoin_kernel: bool = True) -> CompiledPlan:
    """Compile a pattern (or application pattern set) for one graph.

    Cache hit: deserialise the stored plan and lower it (no search).
    Cache miss: build candidates per pattern, pick the joint winner under
    the shared-pool cost model, store the plan, lower it.

    ``cache=False`` disables caching; ``cache=None`` uses the process
    cache.  ``apct``/``counter`` let callers (e.g. ``MiningEngine``)
    share their profiling table and hom memo with the compiled plan —
    the counter's materialised hom/free-hom memos also feed costing, so
    re-compiles against a warm engine prefer decompositions whose cut
    tensors already exist.  ``cutjoin_kernel=False`` keeps CutJoin on the
    XLA ``_join_reduce`` path (the kernel tier's oracle).
    """
    if isinstance(patterns, Pattern):
        patterns = (patterns,)
    patterns = tuple(patterns)
    if not patterns:
        raise ValueError("compile() needs at least one pattern")

    if counter is not None:
        budget = counter.budget              # cost exactly what will execute
    use_cache = cache is not False
    if cache is None:
        cache = _DEFAULT_CACHE
    key = plan_key(patterns, graph)
    if use_cache:
        plan = cache.get(key)
        # a stored plan is only valid under the compile configuration
        # that selected it: candidate eligibility depends on budget and
        # max_cutjoin_cut, so a cross-config hit could return a plan the
        # executor must refuse (PlanTooWide) — recompile instead
        if plan is not None and plan.meta.get("budget") == budget \
                and plan.meta.get("max_cutjoin_cut") == max_cutjoin_cut:
            return lower(plan, graph, counter=counter,
                         use_pallas=use_pallas, from_cache=True,
                         budget=budget, cutjoin_kernel=cutjoin_kernel)

    if apct is None:
        from repro.core.apct import APCT
        apct = APCT(graph)
    per_pattern = [(p, frontend.pattern_candidates(
        p, graph_n=graph.n, budget=budget,
        max_cutjoin_cut=max_cutjoin_cut)) for p in patterns]
    selections, total_cost = costing.select_candidates(
        per_pattern, apct, graph.n, budget, counter=counter)
    plan = frontend.assemble(selections)
    plan.meta.update({
        "key": key,
        "budget": budget,
        "max_cutjoin_cut": max_cutjoin_cut,
        "estimated_cost": total_cost,
        "styles": {pattern_key(p): cand.style for p, cand in selections},
        "cuts": {pattern_key(p): sorted(cand.cut) if cand.cut else None
                 for p, cand in selections},
    })
    if use_cache:
        cache.put(key, plan)
    return lower(plan, graph, counter=counter, use_pallas=use_pallas,
                 from_cache=False, budget=budget,
                 cutjoin_kernel=cutjoin_kernel)
