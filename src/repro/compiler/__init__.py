"""Pattern-to-plan compiler: DwarvesGraph's compilation tier.

The paper's headline design is *compilation-based* graph pattern mining:
generate candidate algorithms for every decomposition choice, cost them
with an accurate model, and ship the best one as an executable.  This
package is that tier, as a pipeline of five stages:

    pattern set ──frontend──► candidate plan IR fragments
                 (decomposition.candidates × homomorphism orders,
                  CutJoin/Shrinkage decomposition joins)
    fragments  ──costing───► winning joint plan
                 (APCT cost model, cross-pattern CSE: shared quotient
                  contractions scheduled once across the application)
    plan IR    ──lowering──► jitted executables
                 (CountingEngine einsum contractions, clique ordered
                  enumeration, Pallas triangle kernel)
    plan IR    ──cache─────► keyed by (canonical pattern set, graph
                  signature): compile once, execute many

Vertex labels are first-class through every stage: labelled patterns
generate the same candidate space (decomposition joins included — the
label mask lives inside each CutJoin factor, so the |cut| <= 3 Pallas
kernel tiers run unchanged), costing scales count bounds by label
selectivity, and lowering binds the pattern's label indices to the
bound graph's one-hot indicator rows at plan-bind time — one plan
serves any graph with a compatible label alphabet (out-of-alphabet
labels bind to the zero vector).

``compile(patterns, graph)`` is the single entry point; it returns a
``CompiledPlan`` whose ``.plan`` is the serializable IR (``to_json``)
and whose ``.count(p)`` / ``.counts()`` execute it.  With
``domains=True`` the plan additionally carries FSM MINI-domain nodes
(one vector per automorphism orbit) served by ``.domains(p)`` /
``.mini_support(p)`` — the level-wise FSM in ``core.fsm`` compiles each
candidate frontier jointly through this path.  ``MiningEngine``,
``launch.mine`` and ``serve.batching`` all route through here; the
legacy direct path in ``core.counting`` remains as the fallback.
"""
from __future__ import annotations

from typing import Iterable, Optional, Union

from repro.core.pattern import Pattern
from repro.graph.storage import Graph
from repro.compiler import cache as _cache_mod
from repro.compiler import costing, frontend
from repro.compiler import morph as _morph
from repro.compiler.cache import PlanCache, config_compatible, plan_key
from repro.compiler.ir import Plan, local_key, pattern_key
from repro.compiler.lowering import CompiledPlan, lower
from repro.compiler.morph import CountStore, default_store

__all__ = ["compile", "Plan", "PlanCache", "CompiledPlan", "CountStore",
           "pattern_key", "plan_key", "local_key", "default_cache",
           "default_store", "config_compatible"]

_DEFAULT_CACHE = PlanCache()


def default_cache() -> PlanCache:
    """The process-wide plan cache used when ``compile(cache=None)``."""
    return _DEFAULT_CACHE


def _label_fracs(patterns, graph):
    """label -> vertex fraction of the bound graph, for selectivity
    pricing; None unless a labelled pattern meets a labelled graph."""
    if graph.labels is None or all(p.labels is None for p in patterns):
        return None
    import numpy as np
    counts = np.bincount(graph.labels, minlength=graph.num_labels)
    return {l: counts[l] / max(graph.n, 1) for l in range(graph.num_labels)}


def _add_local_outputs(plan, patterns, graph, apct, budget, counter,
                       label_fracs, max_cutjoin_cut, node_costs=None):
    """Partial-embedding outputs for every pattern: the unanchored local
    tensor (cheapest eligible cutting set, absent for cliques) plus one
    anchored vector per automorphism orbit (decomposed when a cut
    contains the orbit, flat Möbius otherwise).  Candidates are priced
    against the committed count plan's node pool, so local plans
    preferentially ride the cut tensors the counts already materialise
    — partial embeddings off the decomposition join, not a second
    pipeline."""
    import math as _math
    from repro.compiler.ir import local_key as _lk
    shared = {k: 0.0 for k in plan.nodes}
    local_cuts = {}

    def pick(cands):
        best, bc = None, _math.inf
        for cand in cands:
            c = costing.candidate_cost(cand, apct, graph.n, shared, budget,
                                       counter, label_fracs)
            if c < bc:
                best, bc = cand, c
        if best is None and cands:
            # every candidate prices infinite (genuinely too wide for
            # the budget — the width estimate now threads actual
            # free-axis participation, so this is rare): keep the last
            # candidate (anchored: the flat Möbius fallback) so the
            # output exists, but do NOT commit its nodes to the shared
            # pool — mirroring select_candidates, execution chunks or
            # raises PlanTooWide and callers fall back.
            best = cands[-1]
            for node in best.nodes:
                plan.add(node)
            return best
        if best is not None:
            costing.commit(best, apct, graph.n, shared, budget, counter,
                           label_fracs)
            for node in best.nodes:
                plan.add(node)
            if node_costs is not None:
                # setdefault: the seeded 0.0 of already-committed count
                # nodes must not overwrite their real selection cost
                for node in best.nodes:
                    node_costs.setdefault(node.key, shared[node.key])
        return best

    for p in patterns:
        # every local candidate — unanchored AND anchored — is built on
        # the CANONICAL form.  Unanchored: its key collapses isomorphic
        # renumberings, so the axes must refer to a numbering every
        # caller can reconstruct (canonical vertices).  Anchored: node
        # keys embed cut/keep signatures in local vertex ids under the
        # canonical ``pattern_key`` namespace, so instance-numbered
        # nodes could collide with canonical-numbered ones (same key,
        # different content — first-wins ``Plan.add`` would then serve
        # one anchor another anchor's vector).  One numbering per plan
        # makes equal keys mean equal content; anchored *values* are
        # numbering-invariant (completion counts per graph vertex), so
        # serving the canonical rep's vector for the instance anchor is
        # exact.
        pc = p.canonical()
        perm = p.canonical_perm()            # old (instance) -> canonical
        cand = pick(frontend.local_candidates(pc, graph_n=graph.n,
                                              budget=budget,
                                              max_cut=max_cutjoin_cut))
        if cand is not None:
            plan.set_local_output(pc, cand.out_key)
            local_cuts[_lk(pc)] = sorted(cand.cut)
        for orbit in p.vertex_orbits():
            cand = pick(frontend.local_candidates(
                pc, graph_n=graph.n, anchor=perm[orbit[0]], budget=budget,
                max_cut=max_cutjoin_cut))
            plan.set_local_output(p, cand.out_key, anchor=orbit[0])
            local_cuts[_lk(p, orbit[0])] = (sorted(cand.cut)
                                            if cand.cut else None)
    plan.meta["local_cuts"] = local_cuts


def compile(patterns: Union[Pattern, Iterable[Pattern]], graph: Graph, *,
            apct=None, counter=None, cache: Optional[PlanCache] = None,
            budget: int = 1 << 27, max_cutjoin_cut: int = 3,
            use_pallas: bool = False, cutjoin_kernel: bool = True,
            domains: bool = False, local: bool = False,
            verify: bool = True, mesh=None,
            morph=False) -> CompiledPlan:
    """Compile a pattern (or application pattern set) for one graph.

    Cache hit: deserialise the stored plan and lower it (no search).
    Cache miss: build candidates per pattern, pick the joint winner under
    the shared-pool cost model, store the plan, lower it.

    ``max_cutjoin_cut=3`` (the default) emits decomposition-join
    candidates up to the tri-join kernel tier: |cut| = 3 joins use the
    axis-subset form (each factor spans only the cut vertices its
    subpattern touches) and the cost model's factor-tensor budget
    decides — per graph — whether a 3-D-factor formulation fits or the
    selection falls back to pair-only / |cut| <= 2 / dense candidates.

    ``cache=False`` disables caching; ``cache=None`` uses the process
    cache.  ``apct``/``counter`` let callers (e.g. ``MiningEngine``)
    share their profiling table and hom memo with the compiled plan —
    the counter's materialised hom/free-hom memos also feed costing, so
    re-compiles against a warm engine prefer decompositions whose cut
    tensors already exist.  ``cutjoin_kernel=False`` keeps CutJoin on the
    XLA ``_join_reduce`` path (the kernel tier's oracle).

    ``domains=True`` additionally emits FSM MINI-domain nodes per
    pattern (one free-hom Möbius combination per automorphism orbit),
    served by ``CompiledPlan.domains`` / ``.mini_support``; their
    free-hom contractions CSE-merge with decomposition-join factors.  A
    cached plan without domain nodes misses a ``domains=True`` lookup
    (and recompiles); the converse hit is fine — domain nodes are lazy.

    ``local=True`` additionally emits partial-embedding outputs (the
    paper's §5 API): per pattern, the unanchored local-count tensor over
    its cheapest eligible cutting set plus one anchored vector per
    automorphism orbit, served by ``CompiledPlan.local_counts`` /
    ``.exists``.  Local candidates are priced against the committed
    count plan, so they reuse its cut tensors; the same lazy-superset
    cache rule as ``domains`` applies.

    ``verify=True`` (the default) statically verifies every freshly
    assembled plan *before* it is cached or lowered
    (``repro.analysis.verify``): a frontend/costing bug that emits
    malformed IR raises ``PlanVerifyError`` at compile time instead of
    poisoning the cache, joins the degree bound precertifies skip the
    runtime ``exact_block`` guard scan (``plan.meta["precert"]``), and
    joins that could never take the kernel route are flagged to the
    metrics registry (``analysis.always_refused``).

    ``mesh`` (a 1-D ``("data",)`` jax Mesh, e.g. ``meshes.data_mesh()``)
    binds the plan to the sharded tier end to end: Contract nodes lower
    to collective einsums over the row-sharded adjacency
    (``distributed/contract.py`` — the n x n adjacency never
    materialises unsharded), guarded CutJoin/LocalCount nodes execute
    block-sharded over cut axis 0 (``distributed/cutjoin.py``), all
    bit-for-bit identical to single-device, and plan selection prices
    contractions and joins per-device with a collective surcharge
    (``costing``, ``devices=``).  The mesh does not enter the cache
    *key*, but its device count is part of the cross-config
    compatibility check on a hit (``cache.config_compatible``): a plan
    compiled against a mesh carries sharded route annotations and
    per-device cost estimates a meshless executor can't honour (and
    vice versa), so mismatched lookups recompile instead of serving it.

    ``morph`` turns the pattern-morphing count algebra on
    (``compiler.morph``): ``True`` uses the process-wide
    ``default_store()``, or pass a ``CountStore``.  Before searching,
    every query pattern is expanded over the store's held counts
    (inclusion–exclusion over the pattern lattice); when the whole
    query set closes algebraically the compiler skips candidate search
    entirely and serves a direct-shaped plan whose hom reads come back
    from the store (``plan.meta["morph"]``, route ``morph-derive``,
    ``obs`` counter ``morph.hits``) — zero contractions.  Partially
    closed queries still search, but held homs price at ~0
    (``costing.select_candidates(held=)``) and are served from the
    store at execution; fully-missing ones count
    ``morph.missing_compiles``.  Every count read of the returned plan
    harvests its exact scalars back into the store.  Morph-compiled
    plans are never written to the plan *cache* (their selection is
    store-biased; a later ``morph=False`` compile must behave exactly
    as if morphing never existed), and ``morph=False`` (the default)
    changes nothing anywhere.
    """
    if isinstance(patterns, Pattern):
        patterns = (patterns,)
    patterns = tuple(patterns)
    if not patterns:
        raise ValueError("compile() needs at least one pattern")

    if counter is not None:
        budget = counter.budget              # cost exactly what will execute
    use_cache = cache is not False
    if cache is None:
        cache = _DEFAULT_CACHE
    morph_store = None
    if morph is not False and morph is not None:
        morph_store = (morph if isinstance(morph, _morph.CountStore)
                       else _morph.default_store())
    from repro.distributed import meshes as _meshes
    mesh_devices = _meshes.num_shards(mesh)
    key = plan_key(patterns, graph)
    if use_cache:
        plan = cache.get(key)
        # a stored plan is only valid under the compile configuration
        # that selected it — budget, max_cutjoin_cut, and the execution
        # mesh's device count (see cache.config_compatible); a
        # cross-config hit recompiles instead of serving a plan the
        # executor must refuse or whose sharded routes it can't honour.
        # A domains=True request needs the domain nodes present; a plan
        # that has them serves domain-less requests unchanged.
        if plan is not None and config_compatible(
                plan, budget=budget, max_cutjoin_cut=max_cutjoin_cut,
                mesh_devices=mesh_devices):
            if (not domains or plan.meta.get("domains")) \
                    and (not local or plan.meta.get("local")):
                return lower(plan, graph, counter=counter,
                             use_pallas=use_pallas, from_cache=True,
                             budget=budget, cutjoin_kernel=cutjoin_kernel,
                             mesh=mesh, count_store=morph_store)
            # config matches but the stored plan lacks a requested
            # flavor: recompile with the UNION of requested and stored
            # flags, so the overwrite supersets the entry instead of
            # ping-ponging between domains-only and local-only plans on
            # alternating request kinds
            domains = domains or bool(plan.meta.get("domains"))
            local = local or bool(plan.meta.get("local"))

    held = None
    if morph_store is not None:
        from repro import obs as _obs
        gsig = _cache_mod.graph_signature(graph)
        derived = [_morph.derive(p, morph_store, gsig) for p in patterns]
        if all(d.complete for d in derived) and not domains and not local:
            # the whole query set closes algebraically over held counts:
            # skip candidate search entirely and serve the direct-shaped
            # plan — lowering answers every hom node from the store
            # (route "morph-derive"), so no contraction ever runs
            for _ in patterns:
                _obs.counter("morph.hits")
            plan = frontend.assemble(
                [(p, frontend.direct_candidate(p)) for p in patterns])
            plan.meta.update({
                "key": key, "budget": budget,
                "max_cutjoin_cut": max_cutjoin_cut,
                "mesh_devices": mesh_devices,
                "domains": False, "local": False,
                "estimated_cost": 0.0, "morph": True,
                "styles": {pattern_key(p): "morph" for p in patterns},
                "cuts": {pattern_key(p): None for p in patterns},
            })
            if verify:
                from repro import analysis
                ginfo = analysis.GraphInfo.from_graph(graph)
                plan.meta["graph_info"] = ginfo.to_dict()
                analysis.verify(plan, graph_info=ginfo,
                                budget=budget).raise_if_failed()
            return lower(plan, graph, counter=counter,
                         use_pallas=use_pallas, from_cache=False,
                         budget=budget, cutjoin_kernel=cutjoin_kernel,
                         mesh=mesh, count_store=morph_store)
        for d in derived:
            if d.missing:
                _obs.counter("morph.missing_compiles")
        # partial closure (or a domains/local request): fall through to
        # the search, but hand costing the held hom pool — held
        # contractions price at ~0 and execute from the store
        held = morph_store.held_hom_keys(gsig)

    if apct is None:
        from repro.core.apct import APCT
        apct = APCT(graph)
    per_pattern = [(p, frontend.pattern_candidates(
        p, graph_n=graph.n, budget=budget,
        max_cutjoin_cut=max_cutjoin_cut)) for p in patterns]
    label_fracs = _label_fracs(patterns, graph)
    node_costs: dict = {}
    selections, total_cost = costing.select_candidates(
        per_pattern, apct, graph.n, budget, counter=counter,
        label_fracs=label_fracs, node_costs=node_costs,
        devices=mesh_devices, held=held)
    plan = frontend.assemble(selections)
    if domains:
        for p in patterns:
            for node in frontend.domain_candidate(p).nodes:
                plan.add(node)
    if local:
        _add_local_outputs(plan, patterns, graph, apct, budget, counter,
                           label_fracs, max_cutjoin_cut,
                           node_costs=node_costs)
    import math as _math
    plan.meta.update({
        "key": key,
        "budget": budget,
        "max_cutjoin_cut": max_cutjoin_cut,
        "mesh_devices": mesh_devices,
        "domains": domains,
        "local": local,
        "estimated_cost": total_cost,
        # per-node APCT predictions for committed nodes — the predicted
        # side of obs.drift's calibration report (traced executions pair
        # these with measured self times); uncommitted fallback nodes
        # and inf-priced entries carry no prediction
        "node_costs": {k: v for k, v in node_costs.items()
                       if k in plan.nodes and _math.isfinite(v)},
        "styles": {pattern_key(p): cand.style for p, cand in selections},
        "cuts": {pattern_key(p): sorted(cand.cut) if cand.cut else None
                 for p, cand in selections},
    })
    if verify:
        from repro import analysis, obs
        ginfo = analysis.GraphInfo.from_graph(graph)
        # graph statistics ride in meta so cached plans re-verify their
        # budget pass without the graph; the precert copy is advisory
        # (observability/examples) — lowering recomputes the certificate
        # from the graph it actually binds, never trusting cached meta
        plan.meta["graph_info"] = ginfo.to_dict()
        result = analysis.verify(plan, graph_info=ginfo, budget=budget)
        result.raise_if_failed()
        plan.meta["precert"] = dict(result.precert)
        for diag in result.warnings:
            if diag.code == "always-refused":
                obs.counter("analysis.always_refused")
    if use_cache and morph_store is None:
        # morph-biased selections never enter the shared plan cache: a
        # later morph=False compile must see PR-9-identical behaviour
        cache.put(key, plan)
    return lower(plan, graph, counter=counter, use_pallas=use_pallas,
                 from_cache=False, budget=budget,
                 cutjoin_kernel=cutjoin_kernel, mesh=mesh,
                 count_store=morph_store)
