"""Plan cache: compile once, execute many.

Plans are keyed by (canonical pattern-set signature, graph signature):
the same application against the same graph — the serving steady state —
skips decomposition search and candidate costing entirely and goes
straight to lowering.  The cache is two-tier: a process-local dict plus
an optional on-disk directory of canonical-JSON plan files, so warmed
plans survive across processes (and can be shipped with a deployment).
"""
from __future__ import annotations

import hashlib
import json
import os
from typing import Iterable, Optional

from repro.core.pattern import Pattern
from repro.graph.storage import Graph
from repro.compiler.ir import Plan, pattern_key


def graph_signature(g: Graph) -> str:
    """Content hash of the graph (vertices, canonical edge list, labels).
    Memoised on the instance — edges are immutable after construction —
    so serving loops don't re-hash O(E) bytes per query."""
    sig = getattr(g, "_plan_signature", None)
    if sig is None:
        h = hashlib.sha256()
        h.update(str(g.n).encode())
        h.update(g.edges.tobytes())
        if g.labels is not None:
            h.update(g.labels.tobytes())
        sig = g._plan_signature = h.hexdigest()[:16]
    return sig


def patterns_signature(patterns: Iterable[Pattern]) -> str:
    """Order-insensitive hash of the canonical pattern set."""
    keys = sorted(pattern_key(p) for p in patterns)
    return hashlib.sha256("|".join(keys).encode()).hexdigest()[:16]


def plan_key(patterns: Iterable[Pattern], graph: Graph) -> str:
    return f"{patterns_signature(patterns)}-{graph_signature(graph)}"


class PlanCache:
    """In-memory plan store with optional directory persistence."""

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self._mem: dict = {}
        self.hits = 0
        self.misses = 0
        if path:
            os.makedirs(path, exist_ok=True)

    def _file(self, key: str) -> str:
        return os.path.join(self.path, f"plan-{key}.json")

    def get(self, key: str) -> Optional[Plan]:
        plan = self._mem.get(key)
        if plan is None and self.path:
            f = self._file(key)
            if os.path.exists(f):
                try:
                    with open(f) as fh:
                        plan = Plan.from_json(fh.read())
                    self._mem[key] = plan
                except (json.JSONDecodeError, KeyError, ValueError,
                        OSError):          # corrupt entry: recompile
                    plan = None
        if plan is None:
            self.misses += 1
            return None
        self.hits += 1
        return plan

    def put(self, key: str, plan: Plan):
        self._mem[key] = plan
        if self.path:
            with open(self._file(key), "w") as fh:
                fh.write(plan.to_json())

    def __contains__(self, key: str) -> bool:
        """Peek without touching hit/miss counters."""
        return key in self._mem or bool(
            self.path and os.path.exists(self._file(key)))

    def __len__(self):
        return len(self._mem)

    def clear(self):
        self._mem.clear()
        self.hits = self.misses = 0
