"""Plan cache: compile once, execute many.

Plans are keyed by (canonical pattern-set signature, graph signature):
the same application against the same graph — the serving steady state —
skips decomposition search and candidate costing entirely and goes
straight to lowering.  The cache is two-tier: a process-local dict plus
an optional on-disk directory of canonical-JSON plan files, so warmed
plans survive across processes (and can be shipped with a deployment).
The disk tier can be size-capped (``max_disk_entries``) with
LRU-by-mtime eviction for long-lived serving hosts.

Format note: plan files are stamped with ``ir.PLAN_FORMAT_VERSION`` and
drift is a clean miss (recompile + overwrite).  The morphing count
store (``compiler.morph.CountStore``) keeps its own per-graph files
(``counts-<graph signature>.json``) under the same discipline — atomic
tmp-write + ``os.replace``, ``morph.MORPH_FORMAT_VERSION``-stamped,
version drift a clean miss — so a deployment can ship both tiers
side by side and roll either format independently.
"""
from __future__ import annotations

import hashlib
import json
import os
import time
from typing import Iterable, Optional

from repro import obs
from repro.core.pattern import Pattern
from repro.graph.storage import Graph
from repro.compiler.ir import Plan, pattern_key


def graph_signature(g: Graph) -> str:
    """Content hash of the graph (vertices, canonical edge list, labels).
    Memoised on the instance so serving loops don't re-hash O(E) bytes
    per query.  Both the plan cache and the morph ``CountStore`` key
    exact results by this signature, so any caller that mutates a graph
    in place must call ``Graph.invalidate_signature()`` afterwards — a
    stale memo would serve the pre-mutation graph's plans and counts."""
    sig = getattr(g, "_plan_signature", None)
    if sig is None:
        h = hashlib.sha256()
        h.update(str(g.n).encode())
        h.update(g.edges.tobytes())
        if g.labels is not None:
            h.update(g.labels.tobytes())
        sig = g._plan_signature = h.hexdigest()[:16]
    return sig


def patterns_signature(patterns: Iterable[Pattern]) -> str:
    """Order-insensitive hash of the canonical pattern set."""
    keys = sorted(pattern_key(p) for p in patterns)
    return hashlib.sha256("|".join(keys).encode()).hexdigest()[:16]


def plan_key(patterns: Iterable[Pattern], graph: Graph) -> str:
    return f"{patterns_signature(patterns)}-{graph_signature(graph)}"


def config_compatible(plan: Plan, *, budget: int, max_cutjoin_cut: int,
                      mesh_devices: int = 1) -> bool:
    """True when a cached plan was selected under the caller's compile
    configuration.  A stored plan is only valid under the configuration
    that selected it: candidate eligibility depends on ``budget`` and
    ``max_cutjoin_cut`` (a cross-config hit could return a plan the
    executor must refuse), and route annotations baked at lowering
    depend on the execution mesh — a plan compiled against an 8-device
    mesh carries ``einsum-sharded``/``xla-sharded`` routes and per-device
    cost estimates a meshless executor can't honour, and vice versa, so
    the mesh *device count* is part of the compatibility check
    (``mesh_devices``; 1 means no mesh).  Entries written before the
    field existed default to 1 — compatible with meshless callers only."""
    meta = plan.meta
    return (meta.get("budget") == budget
            and meta.get("max_cutjoin_cut") == max_cutjoin_cut
            and int(meta.get("mesh_devices", 1)) == int(mesh_devices))


class PlanCache:
    """In-memory plan store with optional directory persistence.

    ``max_disk_entries`` caps the on-disk tier with LRU-by-mtime
    eviction: every successful disk read refreshes the entry's mtime,
    and every put that overflows the cap unlinks the stalest files
    (``evictions`` counts them).  The memory tier is never evicted —
    it lives only as long as the process."""

    def __init__(self, path: Optional[str] = None,
                 max_disk_entries: Optional[int] = None,
                 verify: bool = True):
        self.path = path
        self.max_disk_entries = max_disk_entries
        self.verify = verify
        self._mem: dict = {}
        # instance-exact counters that mirror into the process metrics
        # registry (``plancache.hits`` / ``.misses`` / ``.evictions`` /
        # ``.format_misses`` / ``.verify_rejects``); the attribute names
        # stay the public surface via properties below.  ``format_misses``
        # counts entries the parser rejected (truncated JSON, stale
        # version, dropped field), ``verify_rejects`` entries that parsed
        # but failed static verification (semantic corruption the version
        # check can't see) — both are clean misses on top of ``misses``.
        self.stats = obs.StatsView(
            "plancache", keys=("hits", "misses", "evictions",
                               "format_misses", "verify_rejects"),
            tier="disk" if path else "mem")
        if path:
            os.makedirs(path, exist_ok=True)

    @property
    def hits(self) -> int:
        return self.stats["hits"]

    @hits.setter
    def hits(self, v: int):
        self.stats["hits"] = v

    @property
    def misses(self) -> int:
        return self.stats["misses"]

    @misses.setter
    def misses(self, v: int):
        self.stats["misses"] = v

    @property
    def evictions(self) -> int:
        return self.stats["evictions"]

    @evictions.setter
    def evictions(self, v: int):
        self.stats["evictions"] = v

    @property
    def format_misses(self) -> int:
        return self.stats["format_misses"]

    @property
    def verify_rejects(self) -> int:
        return self.stats["verify_rejects"]

    def _file(self, key: str) -> str:
        return os.path.join(self.path, f"plan-{key}.json")

    def _load_disk(self, key: str) -> Optional[Plan]:
        """Parse and verify the on-disk entry into the memory tier, or
        None for a missing / truncated / stale-version / semantically
        corrupt file.  Parse failures (``PlanFormatError``, bad JSON,
        dropped fields) count as ``format_misses``; entries that parse
        but fail the static verifier — bit flips the schema can't see,
        like an out-of-range axis — count as ``verify_rejects``.  Either
        way the entry recompiles instead of half-loading.  A successful
        read refreshes the file's mtime (LRU recency for eviction)."""
        f = self._file(key)
        if not os.path.exists(f):
            return None
        try:
            with open(f) as fh:
                plan = Plan.from_json(fh.read())
        except (json.JSONDecodeError, KeyError, ValueError,
                OSError):                  # corrupt entry: recompile
            self.stats["format_misses"] += 1
            return None
        if self.verify:
            from repro import analysis
            if not analysis.verify(plan).ok:
                self.stats["verify_rejects"] += 1
                return None
        try:
            os.utime(f)                    # mark recently used
        except OSError:
            # read-only cache dir (the shipped-with-deployment case):
            # the read still serves, recency just can't refresh
            obs.counter("plancache.utime_failures")
        self._mem[key] = plan
        return plan

    def _evict(self):
        """Unlink the stalest on-disk entries beyond the cap (LRU by
        mtime).  Racing processes may unlink the same file — missing
        files are skipped, not errors.  Every eviction emits the evicted
        entry's age and size to the metrics registry (histograms
        ``plancache.eviction.age_s`` / ``.bytes``), so LRU pressure on a
        serving host is visible instead of silent."""
        if not self.path or self.max_disk_entries is None:
            return
        try:
            files = [os.path.join(self.path, f)
                     for f in os.listdir(self.path)
                     if f.startswith("plan-") and f.endswith(".json")]
        except OSError:
            return
        excess = len(files) - self.max_disk_entries
        if excess <= 0:
            return
        def _mtime(f):
            try:
                return os.path.getmtime(f)
            except OSError:
                return 0.0
        # eviction ages compare against file mtimes, which are wall time
        now = time.time()              # lint: allow=no-time-time
        for f in sorted(files, key=_mtime)[:excess]:
            try:
                st = os.stat(f)
                age_s, size = max(0.0, now - st.st_mtime), st.st_size
            except OSError:
                age_s = size = None
            try:
                os.unlink(f)
                self.evictions += 1
                if age_s is not None:
                    obs.observe("plancache.eviction.age_s", age_s)
                    obs.observe("plancache.eviction.bytes", size)
            except OSError:
                pass

    def get(self, key: str) -> Optional[Plan]:
        plan = self._mem.get(key)
        if plan is not None and self.path \
                and self.max_disk_entries is not None:
            try:
                # a memory-tier hit must still count as disk recency:
                # without this a long-lived host's hottest plans (read
                # from disk once, then served from _mem for hours) look
                # stalest to the LRU and get evicted first
                os.utime(self._file(key))
            except OSError:
                obs.counter("plancache.utime_failures")
        if plan is None and self.path:
            plan = self._load_disk(key)
        if plan is None:
            self.misses += 1
            return None
        self.hits += 1
        return plan

    def put(self, key: str, plan: Plan):
        self._mem[key] = plan
        if self.path:
            # write-temp + rename: a writer killed mid-write must never
            # leave a truncated JSON at the final path (readers would
            # re-parse and discard it on every lookup).  os.replace is
            # atomic within a directory.
            final = self._file(key)
            tmp = f"{final}.tmp.{os.getpid()}"
            try:
                with open(tmp, "w") as fh:
                    fh.write(plan.to_json())
                os.replace(tmp, final)
            finally:
                if os.path.exists(tmp):
                    os.unlink(tmp)
            self._evict()

    def __contains__(self, key: str) -> bool:
        """Peek without touching hit/miss counters.  On-disk entries are
        actually parsed (a truncated or stale-version file must not
        report present only for get() to miss); a valid parse lands in
        the memory tier, so the peek's work isn't repeated."""
        return key in self._mem or bool(
            self.path and self._load_disk(key) is not None)

    def __len__(self):
        return len(self._mem)

    def clear(self):
        self._mem.clear()
        self.hits = self.misses = self.evictions = 0
        self.stats["format_misses"] = self.stats["verify_rejects"] = 0
