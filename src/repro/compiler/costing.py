"""Costing: score candidate plan fragments with the APCT model and pick
winners under cross-pattern computation reuse.

Node costs reuse the existing DwarvesGraph model (``cost_model``): every
elimination step of a hom contraction costs the approximate count of the
subpattern processed so far (APCT query) plus a dense-tile floor.  The
``shared`` memo implements the paper's joint-search semantics: a node
already scheduled by an earlier pattern costs nothing again, so the
greedy selection naturally prefers candidates that reuse the pool —
exactly why the paper searches the joint space (§4.3).

Candidates whose contraction would materialise an intermediate beyond the
``PlanTooWide`` threshold get infinite cost, so the compiler avoids
emitting a plan the executor must refuse whenever a finite-cost
candidate exists; if *no* candidate is executable the direct plan is
kept (uncommitted, total cost inf) and the executor's ``PlanTooWide``
triggers the caller's fallback.

Two extensions of the shared pool:

* ``CutJoin`` with |cut| <= 3 is costed as the fused Pallas kernel tiers
  (``kernels.ops.cutjoin_reduce`` / ``cutjoin_reduce3``): per-tile
  streaming with the injectivity mask computed in-kernel, so it never
  pays (or gates on) an O(n^|cut|) mask materialisation — only wider
  cuts keep the dense-mask gate.  The tri tier's budget story gates on
  what it *does* materialise: Σ per-factor tensor elements (axis-subset
  factors at their own size) against the plan budget, refusing (inf)
  formulations whose 3-D factors would not fit and thereby preferring
  pair-tensor-only 3-cut joins on large graphs.
* when a ``CountingEngine`` is threaded in (``counter=``), hom scalars
  and free-hom tensors it has already materialised cost zero: its
  ``(pattern, free)``-keyed ``hom_free_memo`` (and canonical-pattern
  ``hom_memo``) extend the shared pool across cut choices *and* across
  compiles that reuse the engine (MiningEngine, the serving batcher), so
  costing prefers decompositions whose cut tensors already exist.

Labelled contractions are priced with label selectivity: the APCT only
profiles unlabelled skeletons (paper footnote 6), so the count-bound
term of a label-masked contraction is the skeleton estimate scaled by
the product of the pattern vertices' label frequencies (independence
assumption) — label masks shrink the effective match count, not the
dense-tile floor, which still streams full-N tiles.  ``label_fracs``
(label -> vertex fraction of the bound graph) is threaded from
``compile``.
"""
from __future__ import annotations

import math
from typing import Dict, List, Tuple

from repro.core import cost_model as CM
from repro.core import homomorphism as H
from repro.core.decomposition import candidates as cut_candidates
from repro.core.pattern import Pattern, clique
from repro.compiler.frontend import Candidate
from repro.compiler.ir import Contract, CutJoin, Intersect, LocalCount, \
    MobiusCombine, ShrinkageCorrect, free_skeleton

DENSE_TILE = CM.DENSE_TILE

# how much cheaper one streamed kernel-tier tile is than one dense f64
# gather-einsum tile: the CutJoin tiers run chunked f32 broadcast
# multiplies through the VPU (measured ~4-10x over the XLA dense join,
# see benchmarks/bench_cutjoin.py), while Contract floors model f64
# einsum contractions — without the discount a tri join prices like a
# fourth contraction and the model refuses decompositions that are
# measurably faster end-to-end
KERNEL_STREAM_DISCOUNT = 4.0


def tile_floor(n: int, width: int, tile: int = DENSE_TILE) -> float:
    """Dense-tile streaming floor of one ``width``-dim pass over an
    ``n``-extent grid, in tile units.

    For n >= tile this is the historical ``(n / tile) ** width``.  Below
    one tile the historical formula collapsed to a flat 1.0 for every
    width and every candidate — the ROADMAP "sharp edge": at n <= 128
    all floors tied, so plan selection between candidates was decided
    by count terms alone and tests at small n never exercised the floor
    side of the model.  Instead the leading axis now scales with the
    *actual* tile extent ``min(n, tile)`` the kernels stream (they clamp
    their block to n and pad to it — see ``kernels/matreduce``), so the
    floor stays proportional to n and two candidates with different
    factor counts price differently at any n.  Width <= 0 (scalar
    outputs) floors at 1.0 — reading a result is never free."""
    if width <= 0:
        return 1.0
    return (max(n, 1) / tile) * (max(n, tile) / tile) ** (width - 1)


def _label_selectivity(labels, label_fracs) -> float:
    """Fraction of vertex tuples surviving the label mask: Π over the
    (sub)pattern's vertices of their label's vertex frequency."""
    if labels is None or not label_fracs:
        return 1.0
    s = 1.0
    for l in labels:
        s *= label_fracs.get(l, 0.0)
    return s


def _contract_cost(node: Contract, apct, n_vertices: int,
                   budget: int, label_fracs=None,
                   devices: int = 1) -> float:
    # decode free-hom marker labels back to the real-labelled skeleton;
    # the APCT itself understands only unlabelled skeletons (it strips
    # labels on query), so labelled count bounds are the skeleton
    # estimate scaled by label selectivity
    q = free_skeleton(node.pattern) if node.free else node.pattern
    steps = H.frontier_sizes(q, node.order, free=node.free)
    # execution-faithful per-step widths: free axes count only once a
    # factor actually carries them (the engine's einsum never unions
    # untouched output axes into an intermediate), so anchored
    # flat-Möbius candidates on large graphs price by what they
    # materialise, not by a free-axes-everywhere upper bound.  The
    # memory gate tests the step's *output* width (what ``_contract``
    # holds / chunks); the dense floor charges the *compute* width
    # (output ∪ the eliminated vertex — the volume the einsum streams)
    widths = H.elimination_widths(q, node.order, free=node.free)
    # devices > 1 prices the collective route (distributed/contract):
    # each elimination step splits its eliminated-vertex extent across
    # the mesh, so step work divides by d, plus a log2(d) surcharge per
    # step for the tree-reduce behind its closing psum — mirroring
    # _kernel_join_cost so contract vs join selection stays coherent,
    # and a 1-device mesh prices identically to no mesh.
    d = max(int(devices), 1)
    total = 0.0
    done = set(node.free)
    for (v, front), (_, width) in zip(steps, widths):
        if n_vertices ** width > 4 * budget:
            return math.inf                  # PlanTooWide at execution
        done |= front
        sub = q.induced(sorted(done))
        cnt = (apct.query(sub) if sub.is_connected()
               else CM._disc(apct, q, done))
        cnt *= _label_selectivity(sub.labels, label_fracs)
        total += (cnt + tile_floor(n_vertices, width + 1)) / d
        if d > 1:
            total += math.log2(d)
    # free output tensor materialisation (sharded on cut axis 0)
    total += tile_floor(n_vertices, len(node.free)) / d
    return total


def _materialised(node: Contract, counter) -> bool:
    """True when the engine already holds this contraction's value: the
    hom scalar (canonical pattern) or the free-hom tensor under the
    engine's ``(skeleton pattern, free)`` memo key — exactly the key
    lowering evaluates with, so zero cost here is zero work there."""
    if counter is None:
        return False
    if node.free:
        return counter.has_free_tensor(free_skeleton(node.pattern),
                                       node.free)
    return counter.has_hom(node.pattern)


def _kernel_join_cost(cut_size: int, factor_axes, n_vertices: int,
                      budget: int, devices: int = 1):
    """Shared kernel-tier join pricing for CutJoin and LocalCount — the
    two must stay in lockstep for scalar-count vs keep-axis plan
    selection to be meaningful.  Returns inf when a |cut| >= 3 join's
    Σ factor elements (axis-subset factors at their own size) exceed
    the pool headroom; otherwise one pass over the tile grid plus
    per-factor read traffic at each factor's own width, at streamed-f32
    rates.

    ``devices > 1`` prices the sharded tier (``distributed/cutjoin``):
    the grid and the axis-0 factor traffic divide across the mesh
    (per-device APCT), plus a log2(d) collective surcharge for the
    tree-reduce behind the closing ``psum``/all-gather — so the model
    prefers sharded execution exactly where per-device savings beat the
    collective, and a 1-device mesh prices identically to no mesh."""
    if cut_size >= 3:
        factor_elems = sum(n_vertices ** len(ax) for ax in factor_axes)
        if factor_elems > 4 * budget:
            return math.inf
    tiles = tile_floor(n_vertices, cut_size)
    traffic = sum(tile_floor(n_vertices, len(ax)) for ax in factor_axes)
    d = max(int(devices), 1)
    cost = (tiles + traffic) / d / KERNEL_STREAM_DISCOUNT
    if d > 1:
        cost += math.log2(d)
    return cost


def node_cost(node, apct, n_vertices: int, budget: int = 1 << 27,
              counter=None, label_fracs=None, devices: int = 1,
              held=None) -> float:
    if isinstance(node, Contract):
        if _materialised(node, counter):
            return 0.0
        # the morph count store already holds this scalar hom: lowering
        # serves it without contracting (route "morph-derive"), so the
        # model prices it like a materialised engine memo
        if held and not node.free and node.key in held:
            return 0.0
        return _contract_cost(node, apct, n_vertices, budget, label_fracs,
                              devices)
    if isinstance(node, Intersect):
        if held and node.key in held:
            return 0.0
        # ordered enumeration: linear scan + one unit per (approximate)
        # clique tuple
        return apct.query(clique(node.k)) + n_vertices
    if isinstance(node, CutJoin):
        # |cut| <= 3 runs the fused kernel tiers: tiles stream through
        # VMEM with the injectivity mask computed in-kernel, so only
        # wider cuts gate on materialising the dense mask.  The tri tier
        # instead gates on its *factor* tensors — the only thing it
        # materialises: Σ factor elements (each n^|axes|, axis-subset
        # factors at their own size) must fit the plan budget, so a
        # pair-tensor-only 3-cut join stays eligible on graphs where a
        # 3-D-factor formulation prices infinite and the selection falls
        # back to |cut| <= 2 candidates or the dense Möbius route.
        if node.cut_size > 3:
            # dense-mask join beyond the kernel tiers (single-device:
            # the sharded tier stops at |cut| = 3, see lowering)
            if n_vertices ** node.cut_size > 4 * budget:
                return math.inf
            tiles = tile_floor(n_vertices, node.cut_size)
            return tiles * max(len(node.factors), 1)
        return _kernel_join_cost(node.cut_size, node.factor_axes(),
                                 n_vertices, budget, devices)
    if isinstance(node, ShrinkageCorrect):
        return float(len(node.corrections) + 1)
    if isinstance(node, LocalCount):
        # the partial-embedding join: the factor-product streaming cost
        # matches CutJoin's kernel tier (|cut| <= 3 by construction), but
        # the output is a tensor over the kept axes, not a scalar — a
        # reduce-free join (keep == all axes) pays its materialisation,
        # which is what steers anchored queries to keep-axis plans when
        # both exist.  Corrections add one streamed tensor each.  3-cut
        # local plans gate on their factor tensors like the tri-join
        # (full-cut factors, so anchored 3-cut vectors only commit where
        # three n³ factors genuinely fit the budget).
        out_elems = n_vertices ** len(node.keep)
        if out_elems > 4 * budget:
            return math.inf                  # output itself too wide
        join = _kernel_join_cost(node.cut_size, node.factor_axes(),
                                 n_vertices, budget, devices)
        out = tile_floor(n_vertices, len(node.keep))
        return join + out + float(len(node.corrections))
    if isinstance(node, MobiusCombine):
        return float(len(node.terms))
    raise TypeError(type(node))


def candidate_cost(cand: Candidate, apct, n_vertices: int,
                   shared: Dict[str, float], budget: int = 1 << 27,
                   counter=None, label_fracs=None,
                   devices: int = 1, held=None) -> float:
    """Cost of one candidate given already-scheduled nodes (cost 0)."""
    total = 0.0
    for node in cand.nodes:
        if node.key in shared:
            continue
        total += node_cost(node, apct, n_vertices, budget, counter,
                           label_fracs, devices, held)
        if total == math.inf:
            return math.inf
    return total


def commit(cand: Candidate, apct, n_vertices: int,
           shared: Dict[str, float], budget: int = 1 << 27, counter=None,
           label_fracs=None, devices: int = 1, held=None):
    for node in cand.nodes:
        if node.key not in shared:
            shared[node.key] = node_cost(node, apct, n_vertices, budget,
                                         counter, label_fracs, devices,
                                         held)


def select_candidates(per_pattern: List[Tuple[Pattern, List[Candidate]]],
                      apct, n_vertices: int,
                      budget: int = 1 << 27, counter=None,
                      label_fracs=None, node_costs: Dict[str, float] = None,
                      devices: int = 1, held=None):
    """Greedy joint selection over the application: for each pattern pick
    the cheapest candidate under the current shared pool, then commit its
    nodes.  Returns ([(pattern, winner)], total_cost).

    ``counter`` extends the pool with contractions the engine has already
    materialised (see ``_materialised``); ``label_fracs`` prices label
    masks (see ``_label_selectivity``).  ``node_costs`` (optional dict)
    receives the per-node APCT cost of every committed node — the
    *predicted* side of the observability layer's drift report, stored
    on the plan so traced executions can pair each node's prediction
    with its measured time.  ``devices`` is the execution mesh's shard
    count (1 without a mesh): joins price per-device plus a collective
    term (``_kernel_join_cost``), so selection sees the mesh.  ``held``
    (set of ``hom:`` node keys the morph count store already holds for
    this graph) prices those contractions at 0 — the morph-candidate
    costing hook: a direct plan whose homs the store holds beats a
    decomposition exactly when the algebra makes it free."""
    shared: Dict[str, float] = {}
    out = []
    total = 0.0
    for p, cands in per_pattern:
        best, bc = None, math.inf
        for cand in cands:
            c = candidate_cost(cand, apct, n_vertices, shared, budget,
                               counter, label_fracs, devices, held)
            if c < bc:
                best, bc = cand, c
        if best is None:
            # every candidate materialises a too-wide intermediate: keep
            # the direct plan so the output exists, but do NOT commit its
            # nodes (they must not look free to later patterns) — the
            # executor will raise PlanTooWide and callers fall back
            out.append((p, cands[0]))
            total = math.inf
            continue
        commit(best, apct, n_vertices, shared, budget, counter,
               label_fracs, devices, held)
        out.append((p, best))
        total += bc
    if node_costs is not None:
        node_costs.update(shared)
    return out, total


def choose_cut(p: Pattern, apct, n_vertices: int):
    """Cost-model-optimal cutting set for one pattern (None = direct
    fallback) — the compiler-side home of ``MiningEngine.choose_cut``."""
    best, bc = None, math.inf
    for cand in cut_candidates(p):
        c = CM.pattern_cost(p, cand, apct, n_vertices)
        if c < bc:
            best, bc = cand, c
    return best
