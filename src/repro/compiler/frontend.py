"""Frontend: pattern set -> candidate plan fragments.

For every pattern the frontend materialises the same search space
``MiningEngine.choose_cut`` walked implicitly — the direct plan plus one
candidate per cutting set — but as explicit IR fragments whose node keys
are canonical-pattern strings.  Assembling fragments into one ``Plan``
CSE-merges nodes by key, so quotient contractions shared across patterns
(the 112 6-motifs drawing from one quotient pool) appear exactly once in
the joint plan.

Two candidate styles exist per cutting set:

* ``cut-order``  — the Möbius-over-quotients plan with elimination orders
  that keep the cutting set as the separator (eliminated last);
* ``decomposed`` — the paper's decomposition join made explicit: per
  subpattern, a Möbius combination of free-cut-vertex hom tensors
  (``M_i(e_c)``), joined by ``CutJoin`` over injective cut tuples and
  corrected by ``ShrinkageCorrect`` over the shrinkage quotients.  Exact:
      inj(p) = Σ_{e_c} Π_i M_i(e_c) − Σ_σ mult(σ)·inj(p/σ)
  where σ ranges over cross-component merging partitions (§2.4).

|cut| >= 3 cutting sets emit a third style, ``decomposed-subset`` (the
tri-join kernel tier's form): each subpattern keeps only the cut
vertices adjacent to its component, so its factor tensor spans a
*subset* of the cut axes — recorded in ``CutJoin.axes`` — with cut-cut
edges as standalone pair factors and the weakened injectivity repaired
by the generalised shrinkage (``quotient.shrinkage_patterns_subset``).

Vertex labels are a constraint, not an eligibility gate: labelled
patterns generate the same candidate space.  Free-hom contractions pack
the real vertex label with the cut-rank marker into one
``LABEL_STRIDE``-encoded label (see ``core.pattern``), so the label mask
is enforced inside each ``M_i`` factor — the one-hot indicators are
idempotent under the CutJoin product — and quotients merging differently
labelled vertices vanish exactly (they are dropped with the self-loop
quotients).

``domain_candidate`` emits the FSM tier: per automorphism orbit of a
pattern, a vector-valued Möbius combination of single-free-vertex hom
tensors (the compiled form of ``CountingEngine.inj_free``), in the same
``homf:`` CSE namespace as the decomposition factors — sibling patterns
in an FSM lattice level share their quotient tensors through it.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Tuple

from repro.core import cost_model as CM
from repro.core import homomorphism as H
from repro.core.decomposition import cutting_sets, subpatterns
from repro.core.pattern import Pattern
from repro.core.quotient import (mobius, partitions, quotient_terms,
                                 shrinkage_patterns,
                                 shrinkage_quotients_with_maps)
from repro.compiler.ir import (Contract, CutJoin, Intersect, LocalCount,
                               MobiusCombine, Plan, ShrinkageCorrect,
                               domain_keys, mark_free, pattern_key)


def _is_complete(q: Pattern) -> bool:
    return (q.labels is None and q.n >= 3
            and q.m == q.n * (q.n - 1) // 2)


def _hom_node(q: Pattern, order: tuple):
    """Contract or Intersect node for one canonical quotient."""
    key = f"hom:{pattern_key(q)}"
    if _is_complete(q):
        return Intersect(key, q.n)
    return Contract(key, q, tuple(order))


@dataclass
class Candidate:
    """One way to compute a pattern's edge-induced count: a topologically
    ordered node fragment plus the key of its output node."""
    pattern: Pattern
    cut: Optional[frozenset]
    style: str                               # direct | cut-order | decomposed
    nodes: List[object] = field(default_factory=list)
    out_key: str = ""

    def _add(self, node):
        for have in self.nodes:
            if have.key == node.key:
                return node.key
        self.nodes.append(node)
        return node.key


# -- Möbius-over-quotients candidates --------------------------------------------

def direct_candidate(p: Pattern, cut: Optional[frozenset] = None) -> Candidate:
    """inj(p) = Σ μ·hom(p/σ) with greedy (cut=None) or separator-last
    elimination orders, then / |Aut|."""
    style = "cut-order" if cut else "direct"
    cand = Candidate(p, cut, style)
    terms = []
    for coeff, q in quotient_terms(p):
        if _is_complete(q):
            order = ()
        elif cut:
            order = H.plan_from_cut(q, CM._cut_image(p, cut, q))
        else:
            order = H.greedy_plan(q)
        key = cand._add(_hom_node(q, order))
        terms.append((float(coeff), key))
    out = MobiusCombine(f"cnt:{pattern_key(p)}", tuple(terms),
                        divisor=p.aut_order())
    cand.out_key = cand._add(out)
    return cand


def _inj_terms(cand: Candidate, q: Pattern) -> str:
    """Add an inj(q) combine (divisor 1, greedy orders) to ``cand``;
    returns its node key."""
    terms = []
    for coeff, r in quotient_terms(q):
        order = () if _is_complete(r) else H.greedy_plan(r)
        terms.append((float(coeff), cand._add(_hom_node(r, order))))
    return cand._add(MobiusCombine(f"inj:{pattern_key(q)}", tuple(terms),
                                   divisor=1))


# -- decomposition-join candidates ------------------------------------------------

def _free_hom_terms(cand: Candidate, sub: Pattern,
                    cutpos: Tuple[int, ...]) -> tuple:
    """Möbius terms of M(e_c) for one subpattern: injective embedding
    count of ``sub`` as a tensor over its cut vertices, expanded over the
    partitions of V(sub) keeping cut vertices in distinct blocks.  Real
    vertex labels ride along: ``mark_free`` packs them with the cut-rank
    markers, quotients merging differently labelled vertices are dropped
    (identically zero), and the surviving contractions enforce the label
    mask inside each factor."""
    cutset = set(cutpos)
    acc: dict = {}
    for sigma in partitions(tuple(range(sub.n))):
        if any(len(set(b) & cutset) > 1 for b in sigma):
            continue                        # would pin two cut values equal
        q, blk = sub.quotient_with_map(sigma)
        if q is None:
            continue                        # self-loop / label clash: zero
        free_raw = tuple(blk[c] for c in cutpos)
        _, qc, free_c = mark_free(q, free_raw)
        key = f"homf:{pattern_key(qc)}"
        order = H.greedy_plan(qc, free_c)
        node = Contract(key, qc, tuple(order), free_c)
        if key not in acc:
            acc[key] = [0.0, node]
        acc[key][0] += mobius(sigma)
    terms = []
    for key in sorted(acc):
        coeff, node = acc[key]
        if coeff == 0:
            continue
        cand._add(node)
        terms.append((float(coeff), key))
    return tuple(terms)


def decomposed_candidate(p: Pattern, cut: frozenset, *, graph_n: int,
                         budget: int = 1 << 27,
                         max_cut: int = 2) -> Optional[Candidate]:
    """CutJoin/ShrinkageCorrect plan for one cutting set, or None when
    ineligible (wide cut, or cut tensor over budget).  Labelled patterns
    decompose like unlabelled ones: labels live inside the factors.

    |cut| <= 2 keeps the legacy full-cut form (every factor spans the
    whole cut); |cut| >= 3 emits the axis-subset form — see
    ``_subset_decomposed_candidate`` — whose per-factor tensor widths
    the cost model prices against the plan budget (the frontend no
    longer hard-gates on ``graph_n ** k``: a 3-cut join whose factors
    are all pair tensors never materialises n³ anything)."""
    k = len(cut)
    if k > max_cut:
        return None
    if k >= 3:
        return _subset_decomposed_candidate(p, cut)
    if graph_n ** k > budget:
        return None
    cand = Candidate(p, cut, "decomposed")
    factors = []
    for sub, vmap in subpatterns(p, cut):
        cutpos = tuple(vmap[c] for c in sorted(cut))
        terms = _free_hom_terms(cand, sub, cutpos)
        if not terms:
            return None
        factors.append(terms)
    cut_sig = "-".join(map(str, sorted(cut)))
    join = CutJoin(f"cutjoin:{pattern_key(p)}:{cut_sig}", k, tuple(factors))
    join_key = cand._add(join)
    corrections = []
    for q, mult in shrinkage_patterns(p, cut):
        corrections.append((float(mult), _inj_terms(cand, q)))
    out = ShrinkageCorrect(f"cnt:{pattern_key(p)}:{cut_sig}", join_key,
                           tuple(corrections), divisor=p.aut_order())
    cand.out_key = cand._add(out)
    return cand


def _subset_decomposed_candidate(p: Pattern, cut: frozenset) \
        -> Optional[Candidate]:
    """The axis-subset decomposition join (the |cut| >= 3 tier).

    Each component's subpattern is the component plus only the cut
    vertices *adjacent* to it, so its free-hom factor spans just those
    cut axes — a pair tensor for a component wedged between two cut
    vertices, never an unnecessary n^|cut| expansion.  Edges between
    cut vertices become their own pair factors (the induced 2-vertex
    pattern with both vertices free: the label-masked adjacency), which
    also keeps every cut axis covered for connected patterns.  The two
    injectivity constraints this join no longer enforces — collisions
    across components and collisions of a component vertex with a
    *distant* (non-adjacent) cut vertex — are exactly the generalised
    shrinkage terms ``shrinkage_patterns_subset`` subtracts, so

        inj(p) = Σ_{e_c pairwise distinct} Π_i M_i(e_c)
                 − Σ_σ mult(σ) · inj(p/σ)

    holds exactly (multiplicity 1 per allowed collision partition).
    With every component adjacent to the whole cut this degenerates to
    the full-cut form (all factors |cut|-dimensional, classic
    shrinkage), which is what e.g. a 5-clique minus an edge needs."""
    from repro.core.quotient import shrinkage_patterns_subset
    k = len(cut)
    cut_list = sorted(cut)
    rank = {c: i for i, c in enumerate(cut_list)}
    adj = p.adj()
    cand = Candidate(p, cut, "decomposed-subset")
    factors, axes = [], []
    for comp in p.components_without(cut):
        adjc = sorted(c for c in cut if adj[c] & comp)
        vs = sorted(comp | set(adjc))
        vmap = {v: i for i, v in enumerate(vs)}
        sub = p.induced(vs)
        cutpos = tuple(vmap[c] for c in adjc)
        terms = _free_hom_terms(cand, sub, cutpos)
        if not terms:
            return None
        factors.append(terms)
        axes.append(tuple(rank[c] for c in adjc))
    for (u, v) in sorted(p.edges):
        if u in cut and v in cut:
            terms = _free_hom_terms(cand, p.induced((u, v)), (0, 1))
            if not terms:
                return None
            factors.append(terms)
            axes.append((rank[min(u, v)], rank[max(u, v)]))
    cut_sig = "-".join(map(str, cut_list))
    join = CutJoin(f"cutjoin:{pattern_key(p)}:{cut_sig}", k,
                   tuple(factors), tuple(axes))
    join_key = cand._add(join)
    corrections = []
    for q, mult in shrinkage_patterns_subset(p, cut):
        corrections.append((float(mult), _inj_terms(cand, q)))
    out = ShrinkageCorrect(f"cnt:{pattern_key(p)}:{cut_sig}", join_key,
                           tuple(corrections), divisor=p.aut_order())
    cand.out_key = cand._add(out)
    return cand


# -- partial-embedding (local-count) candidates ------------------------------------

def local_candidate(p: Pattern, cut: frozenset, *, graph_n: int,
                    anchor: Optional[int] = None, budget: int = 1 << 27,
                    max_cut: int = 2) -> Optional[Candidate]:
    """Partial-embedding plan for one cutting set: the decomposition join
    *without* the final reduce.  The output tensor's axis j indexes the
    assignment of the j-th smallest cut vertex; entry e_c is the exact
    number of injective maps of ``p`` pinning the cut to e_c.  With
    ``anchor`` (a cut vertex) only that axis survives — the other cut
    axes are summed away (the keep-axis kernel tier) and the shrinkage
    corrections are emitted anchored at the anchor alone, so they stay
    vector-sized.  None when ineligible (wide cut, over-budget tensor,
    or anchor outside the cut).  |cut| = 3 plans keep the full-cut
    factor form (axes unannotated): anchored reads run the keep-axis
    tri-join kernel, and costing prices the 3-D factor materialisation
    against the plan budget, so they only commit where they fit."""
    k = len(cut)
    if k > min(max_cut, 3) or graph_n ** k > budget:
        return None
    if anchor is not None and anchor not in cut:
        return None
    cand = Candidate(p, cut, "local")
    factors = []
    for sub, vmap in subpatterns(p, cut):
        cutpos = tuple(vmap[c] for c in sorted(cut))
        terms = _free_hom_terms(cand, sub, cutpos)
        if not terms:
            return None
        factors.append(terms)
    cut_list = sorted(cut)
    keep = (tuple(range(k)) if anchor is None
            else (cut_list.index(anchor),))
    keep_verts = tuple(cut_list[j] for j in keep)
    # anchored shrinkage corrections: Σ_σ inj(p/σ ; keep vertices pinned)
    # as one flat Möbius combination over the kept axes.  Individual
    # partitions (not deduped canonical quotients) because each one pins
    # the cut image through its own vertex map; _free_hom_terms then
    # canonicalises the underlying contractions, so repeats CSE-merge.
    corr_acc: dict = {}
    for q, blk in shrinkage_quotients_with_maps(p, cut):
        qpos = tuple(blk[c] for c in keep_verts)
        for coeff, key in _free_hom_terms(cand, q, qpos):
            corr_acc[key] = corr_acc.get(key, 0.0) + coeff
    corrections = tuple((c, key) for key, c in sorted(corr_acc.items())
                        if c != 0)
    cut_sig = "-".join(map(str, cut_list))
    keep_sig = "-".join(map(str, keep))
    out = LocalCount(f"loc:{pattern_key(p)}:{cut_sig}:k{keep_sig}",
                     k, keep, tuple(factors), corrections)
    cand.out_key = cand._add(out)
    return cand


def anchored_direct_candidate(p: Pattern, anchor: int) -> Candidate:
    """Anchored fallback without a decomposition: the flat Möbius
    expansion of inj(p ; anchor ↦ u) over single-free-vertex hom tensors
    (the compiled form of ``CountingEngine.inj_free``).  Always exists —
    the route for cliques and other patterns whose cutting sets miss the
    anchor — and shares the ``homf:`` namespace with domain fragments."""
    cand = Candidate(p, None, "local-direct")
    terms = _free_hom_terms(cand, p, (anchor,))
    _, qc, _ = mark_free(p, (anchor,))
    cand.out_key = cand._add(
        MobiusCombine(f"locd:{pattern_key(qc)}", terms, divisor=1))
    return cand


def local_candidates(p: Pattern, *, graph_n: int,
                     anchor: Optional[int] = None, budget: int = 1 << 27,
                     max_cut: int = 2) -> List[Candidate]:
    """Candidate space for one partial-embedding output.  Unanchored:
    one ``local`` candidate per eligible cutting set (possibly empty —
    cliques have no local tensor).  Anchored: cutting sets containing
    the anchor, plus the always-available flat Möbius fallback."""
    out = []
    for cut in cutting_sets(p):
        cand = local_candidate(p, cut, graph_n=graph_n, anchor=anchor,
                               budget=budget, max_cut=max_cut)
        if cand is not None:
            out.append(cand)
    if anchor is not None:
        out.append(anchored_direct_candidate(p, anchor))
    return out


# -- FSM domain fragments ----------------------------------------------------------

def domain_candidate(p: Pattern) -> Candidate:
    """FSM MINI-domain fragment: one vector-valued Möbius combination per
    automorphism orbit of the canonical form — the compiled equivalent of
    ``CountingEngine.inj_free`` for every pattern vertex at once.
    Vertices in one orbit share their domain, so only orbit
    representatives materialise; the free-hom contractions live in the
    same ``homf:`` namespace as decomposition-join factors and CSE-merge
    with them and with sibling patterns' fragments."""
    c = p.canonical()
    cand = Candidate(c, None, "domains")
    for key, rep in zip(domain_keys(c), (o[0] for o in c.vertex_orbits())):
        terms = _free_hom_terms(cand, c, (rep,))
        cand.out_key = cand._add(MobiusCombine(key, terms, divisor=1))
    return cand


# -- search space / assembly ------------------------------------------------------

def pattern_candidates(p: Pattern, *, graph_n: int, budget: int = 1 << 27,
                       max_cutjoin_cut: int = 3) -> List[Candidate]:
    """The full candidate space for one pattern, direct plan first."""
    out = [direct_candidate(p)]
    for cut in cutting_sets(p):
        out.append(direct_candidate(p, cut))
        dec = decomposed_candidate(p, cut, graph_n=graph_n, budget=budget,
                                   max_cut=max_cutjoin_cut)
        if dec is not None:
            out.append(dec)
    return out


def assemble(selections) -> Plan:
    """[(pattern, Candidate)] -> one joint Plan; nodes CSE-merge by key."""
    plan = Plan()
    for p, cand in selections:
        for node in cand.nodes:
            plan.add(node)
        plan.set_output(p, cand.out_key)
    return plan
