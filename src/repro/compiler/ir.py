"""Execution-plan IR: the compiler's explicit, serializable middle layer.

A plan is a DAG of typed ops keyed by canonical-pattern strings.  Node
keys double as the cross-pattern CSE namespace: two patterns whose
expansions need the same quotient contraction reference the *same*
``Contract`` node (the tensorised form of the paper's shared quotient
pool), so the joint plan for an application pays each contraction once.

Ops
---
``Contract``          bucket-elimination hom contraction of one quotient
                      pattern under an explicit vertex order; with ``free``
                      vertices it yields a tensor over graph vertices
                      (used by the decomposed path's per-subpattern counts).
``Intersect``         the ordered-enumeration / set-intersection route for
                      complete patterns (cliques have no cutting set,
                      paper §2.4); lowers to degeneracy-ordered
                      intersections or the Pallas triangle kernel.
``MobiusCombine``     Σ coeff · hom(quotient) over the partition lattice
                      (inj when divisor == 1, embedding count when
                      divisor == |Aut|).
``CutJoin``           the decomposition join: Σ_{e_c injective}
                      Π_i M_i(e_c), where each M_i is a Möbius combination
                      of free-vertex ``Contract`` tensors — one factor per
                      subpattern of the chosen cutting set.
``ShrinkageCorrect``  subtracts shrinkage-pattern counts (cross-component
                      vertex collisions, paper §2.4) from a ``CutJoin``
                      value and divides by |Aut|: the decomposed form of
                      an edge-induced embedding count.
``LocalCount``        the partial-embedding output (paper §5): the CutJoin
                      factor product *without* the final Σ_{e_c} reduce —
                      a tensor over cut-vertex assignments whose entry at
                      e_c is the number of injective maps of the whole
                      pattern sending the cutting set to e_c.  ``keep``
                      selects which cut axes survive: all of them is the
                      reduce-free local tensor, a single axis is an
                      anchored vector (every other cut axis summed away).
                      ``corrections`` are anchored shrinkage terms — flat
                      Möbius combinations of free-hom tensors over the
                      kept axes — subtracted entrywise, so every entry is
                      exact, not just the global sum.

Every op is a frozen dataclass with a ``to_dict``/``from_dict`` pair;
``Plan`` serialises to canonical JSON so cached plans survive processes.
Serialised plans carry ``PLAN_FORMAT_VERSION``; deserialising any other
version raises ``PlanFormatError`` (a ``ValueError``), which the on-disk
cache treats as a clean miss — stale-format entries recompile instead of
half-loading.  Structural/semantic validity beyond the schema is the
static verifier's job (``repro.analysis.verify``).
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

# LABEL_STRIDE / encode_free_label / free_skeleton / mark_free are part
# of the IR contract for free-hom Contract nodes: their patterns carry
# LABEL_STRIDE-packed labels combining the real vertex label with the
# cut-rank marker pinning each free axis; lowering and costing decode
# with ``free_skeleton`` (see core.pattern for the packing).
from repro.core.pattern import (LABEL_STRIDE, Pattern, encode_free_label,
                                free_skeleton, mark_free)

Term = Tuple[float, str]                    # (coefficient, node key)


class PlanFormatError(ValueError):
    """A serialised plan was rejected before IR construction: wrong
    format version or an unknown op kind.  ValueError subclass so
    existing clean-miss handlers (``PlanCache._load_disk``) keep
    working; the cache counts these separately from semantic verify
    rejects."""

# serialised-plan schema version; bump on any incompatible IR change so
# on-disk caches written by older code miss cleanly (see Plan.from_dict)
# v3: free-hom Contract patterns may carry LABEL_STRIDE-encoded vertex
# labels (real label + cut-rank marker) — v2 readers would strip them
# v4: LocalCount nodes (partial-embedding outputs) + "loc:"-prefixed
# entries in Plan.outputs — v3 readers would strip-and-serve them as
# count plans, so they must miss instead
# v5: CutJoin/LocalCount factor axis-subset annotation (``axes``) — the
# |cut| >= 3 tier's axis-subset decomposition joins are meaningless to a
# v4 reader (it would expand every factor over the full cut), so they
# must miss instead
PLAN_FORMAT_VERSION = 5


# -- pattern (de)serialisation ---------------------------------------------------

def pattern_key(p: Pattern) -> str:
    """Stable string key of the canonical form (the CSE identity)."""
    c = p.canonical()
    bits, labels = c._code()
    lab = "" if not labels else ":" + ",".join(map(str, labels))
    return f"{c.n}.{bits}{lab}"


def domain_keys(p: Pattern) -> tuple:
    """Node keys of a pattern's FSM MINI-domain vectors, one per
    automorphism orbit of the canonical form (orbit members share their
    domain).  Key construction is the contract between the frontend
    (which emits the nodes) and lowering (which looks them up): both
    derive them from the pattern alone."""
    c = p.canonical()
    return tuple(f"dom:{pattern_key(c)}:{orbit[0]}"
                 for orbit in c.vertex_orbits())


def local_key(p: Pattern, anchor: Optional[int] = None) -> str:
    """Output-table key of a pattern's partial-embedding (local-count)
    result.  Anchored keys canonicalise through ``mark_free``, so every
    vertex of one automorphism orbit — and every isomorphic renumbering
    of the pattern — resolves to the same entry; this is the lookup
    contract between ``compile(local=True)`` (which registers outputs)
    and ``CompiledPlan.local_counts`` (which reads them).  Anchored keys
    get their own ``loca:`` prefix: marker-encoded labels of an anchored
    unlabelled pattern could otherwise collide with the real labels of
    an unanchored labelled one."""
    if anchor is None:
        return f"loc:{pattern_key(p)}"
    _, qc, _ = mark_free(p, (anchor,))
    return f"loca:{pattern_key(qc)}"


def is_local_output(name: str) -> bool:
    """True for ``Plan.outputs`` entries holding partial-embedding
    tensors rather than scalar counts (``pattern_key`` strings always
    start with a digit, so the prefix is unambiguous)."""
    return name.startswith(("loc:", "loca:"))


def pattern_to_dict(p: Pattern) -> dict:
    d = {"n": p.n, "edges": sorted(list(e) for e in p.edges)}
    if p.labels is not None:
        d["labels"] = list(p.labels)
    return d


def pattern_from_dict(d: dict) -> Pattern:
    return Pattern(d["n"], [tuple(e) for e in d["edges"]],
                   tuple(d["labels"]) if d.get("labels") is not None else None)


# -- ops -------------------------------------------------------------------------

@dataclass(frozen=True)
class Contract:
    """hom(pattern) by bucket elimination along ``order``.  Non-empty
    ``free`` keeps those vertices as output axes (axis order = tuple
    order); the pattern's labels are then ``LABEL_STRIDE`` encodings
    packing the real vertex label (if the source pattern is labelled)
    with the cut-rank marker that pins the canonical form — decode with
    ``free_skeleton`` before contracting."""
    key: str
    pattern: Pattern
    order: Tuple[int, ...]
    free: Tuple[int, ...] = ()

    def refs(self):
        return ()

    def to_dict(self) -> dict:
        return {"op": "contract", "key": self.key,
                "pattern": pattern_to_dict(self.pattern),
                "order": list(self.order), "free": list(self.free)}


@dataclass(frozen=True)
class Intersect:
    """hom(K_k) = k! · (# k-cliques) via ordered enumeration."""
    key: str
    k: int

    def refs(self):
        return ()

    def to_dict(self) -> dict:
        return {"op": "intersect", "key": self.key, "k": self.k}


@dataclass(frozen=True)
class MobiusCombine:
    """(Σ coeff · value(ref)) / divisor."""
    key: str
    terms: Tuple[Term, ...]
    divisor: int = 1

    def refs(self):
        return tuple(r for _, r in self.terms)

    def to_dict(self) -> dict:
        return {"op": "mobius", "key": self.key,
                "terms": [[c, r] for c, r in self.terms],
                "divisor": self.divisor}


@dataclass(frozen=True)
class CutJoin:
    """Σ over injective cut tuples of Π_i M_i, with M_i = Σ coeff ·
    tensor(ref) (each ref a free-vertex Contract).  ``axes`` annotates,
    per factor, the sorted subset of cut ranks the factor's tensor
    spans (None = every factor spans the full cut, the |cut| <= 2
    legacy form): axis-subset factors broadcast along the missing cut
    axes inside the join — the |cut| >= 3 tier's pair/vector factors
    stay at their own size instead of expanding to n^|cut|."""
    key: str
    cut_size: int
    factors: Tuple[Tuple[Term, ...], ...]
    axes: Optional[Tuple[Tuple[int, ...], ...]] = None

    def factor_axes(self) -> tuple:
        """Per-factor cut-rank subsets, the full cut when unannotated."""
        if self.axes is not None:
            return self.axes
        return tuple(tuple(range(self.cut_size)) for _ in self.factors)

    def refs(self):
        return tuple(r for f in self.factors for _, r in f)

    def to_dict(self) -> dict:
        d = {"op": "cutjoin", "key": self.key, "cut_size": self.cut_size,
             "factors": [[[c, r] for c, r in f] for f in self.factors]}
        if self.axes is not None:
            d["axes"] = [list(a) for a in self.axes]
        return d


@dataclass(frozen=True)
class ShrinkageCorrect:
    """(value(base) − Σ mult · value(ref)) / divisor — the decomposed
    count after removing cross-component collision (shrinkage) terms."""
    key: str
    base: str
    corrections: Tuple[Term, ...]
    divisor: int = 1

    def refs(self):
        return (self.base,) + tuple(r for _, r in self.corrections)

    def to_dict(self) -> dict:
        return {"op": "shrinkage", "key": self.key, "base": self.base,
                "corrections": [[m, r] for m, r in self.corrections],
                "divisor": self.divisor}


@dataclass(frozen=True)
class LocalCount:
    """Per-partial-embedding counts: entry e_c of the output tensor is
    the number of injective maps of the whole pattern with the cutting
    set pinned to e_c.  Evaluates as

        L = Π_i M_i  −  Σ coeff · corr          (then off-diagonal mask)

    where each M_i is a Möbius combination of ``cut_size``-axis free-hom
    ``Contract`` tensors (the CutJoin factors, axes aligned by cut rank)
    and each correction is a free-hom tensor over the ``keep`` axes only
    (anchored shrinkage terms).  ``keep`` lists the surviving cut axes in
    output order: the full tuple is the reduce-free tensor, a single
    axis sums the others away in-kernel (the keep-axis Pallas tier).
    ``axes`` mirrors ``CutJoin.axes``: per-factor cut-rank subsets for
    axis-subset factors (None = full cut)."""
    key: str
    cut_size: int
    keep: Tuple[int, ...]
    factors: Tuple[Tuple[Term, ...], ...]
    corrections: Tuple[Term, ...] = ()
    axes: Optional[Tuple[Tuple[int, ...], ...]] = None

    def factor_axes(self) -> tuple:
        if self.axes is not None:
            return self.axes
        return tuple(tuple(range(self.cut_size)) for _ in self.factors)

    def refs(self):
        return tuple(r for f in self.factors for _, r in f) + \
            tuple(r for _, r in self.corrections)

    def to_dict(self) -> dict:
        d = {"op": "local", "key": self.key, "cut_size": self.cut_size,
             "keep": list(self.keep),
             "factors": [[[c, r] for c, r in f] for f in self.factors],
             "corrections": [[c, r] for c, r in self.corrections]}
        if self.axes is not None:
            d["axes"] = [list(a) for a in self.axes]
        return d


_OPS = {"contract": Contract, "intersect": Intersect, "mobius": MobiusCombine,
        "cutjoin": CutJoin, "shrinkage": ShrinkageCorrect,
        "local": LocalCount}


def op_from_dict(d: dict):
    kind = d["op"]
    if kind == "contract":
        return Contract(d["key"], pattern_from_dict(d["pattern"]),
                        tuple(d["order"]), tuple(d["free"]))
    if kind == "intersect":
        return Intersect(d["key"], d["k"])
    if kind == "mobius":
        return MobiusCombine(d["key"],
                             tuple((c, r) for c, r in d["terms"]),
                             d["divisor"])
    if kind == "cutjoin":
        return CutJoin(d["key"], d["cut_size"],
                       tuple(tuple((c, r) for c, r in f)
                             for f in d["factors"]),
                       tuple(tuple(a) for a in d["axes"])
                       if d.get("axes") is not None else None)
    if kind == "shrinkage":
        return ShrinkageCorrect(d["key"], d["base"],
                                tuple((m, r) for m, r in d["corrections"]),
                                d["divisor"])
    if kind == "local":
        return LocalCount(d["key"], d["cut_size"], tuple(d["keep"]),
                          tuple(tuple((c, r) for c, r in f)
                                for f in d["factors"]),
                          tuple((c, r) for c, r in d["corrections"]),
                          tuple(tuple(a) for a in d["axes"])
                          if d.get("axes") is not None else None)
    raise PlanFormatError(f"unknown op kind {kind!r}")


# -- the plan --------------------------------------------------------------------

@dataclass
class Plan:
    """A compiled application: op DAG + one output node per pattern."""
    nodes: Dict[str, object] = field(default_factory=dict)
    outputs: Dict[str, str] = field(default_factory=dict)   # pattern_key -> node
    meta: dict = field(default_factory=dict)

    def add(self, node) -> str:
        """Insert (or CSE-merge) a node; returns its key.

        Merging is first-wins by key: two candidates may carry the same
        quotient contraction with different elimination orders, and the
        first-committed order is the one that executes.  Values are
        order-invariant (plan invariance), and the cost model's shared
        pool charges exactly the committed node, so this matches the
        paper's reuse semantics."""
        have = self.nodes.get(node.key)
        if have is not None:
            return node.key
        for r in node.refs():
            if r not in self.nodes:
                raise KeyError(f"node {node.key!r} references missing {r!r}")
        self.nodes[node.key] = node
        return node.key

    def set_output(self, p: Pattern, node_key: str):
        if node_key not in self.nodes:
            raise KeyError(node_key)
        self.outputs[pattern_key(p)] = node_key

    def output_for(self, p: Pattern) -> str:
        return self.outputs[pattern_key(p)]

    def set_local_output(self, p: Pattern, node_key: str,
                         anchor: Optional[int] = None):
        """Register a partial-embedding output under ``local_key``; lives
        in the same serialised table as count outputs (prefix-separated,
        see ``is_local_output``)."""
        if node_key not in self.nodes:
            raise KeyError(node_key)
        self.outputs[local_key(p, anchor)] = node_key

    def local_output_for(self, p: Pattern,
                         anchor: Optional[int] = None) -> str:
        return self.outputs[local_key(p, anchor)]

    def op_counts(self) -> dict:
        out: dict = {}
        for node in self.nodes.values():
            name = type(node).__name__
            out[name] = out.get(name, 0) + 1
        return out

    # -- serialisation -----------------------------------------------------------
    def to_dict(self) -> dict:
        return {"version": PLAN_FORMAT_VERSION,
                "nodes": [n.to_dict() for n in self.nodes.values()],
                "outputs": dict(self.outputs), "meta": dict(self.meta)}

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True)

    @classmethod
    def from_dict(cls, d: dict) -> "Plan":
        version = d.get("version", 1)
        if version != PLAN_FORMAT_VERSION:
            raise PlanFormatError(f"plan format version {version}, "
                                  f"expected {PLAN_FORMAT_VERSION}")
        plan = cls(meta=dict(d.get("meta", {})))
        for nd in d["nodes"]:
            plan.add(op_from_dict(nd))
        for pk, nk in d["outputs"].items():
            if nk not in plan.nodes:
                raise KeyError(nk)
            plan.outputs[pk] = nk
        return plan

    @classmethod
    def from_json(cls, s: str) -> "Plan":
        return cls.from_dict(json.loads(s))

    def __eq__(self, other):
        return isinstance(other, Plan) and self.to_dict() == other.to_dict()
