"""Lowering: plan IR -> executable closures over JAX/Pallas primitives.

``CompiledPlan`` binds a serializable ``Plan`` to one input graph and
evaluates nodes on demand with per-node memoisation:

* ``Contract``   -> ``CountingEngine.hom`` / ``hom_free_tensor`` (bucket
                    elimination einsums, f64, budget-chunked).  With a
                    mesh-bound engine the same nodes lower to collective
                    einsums over the row-sharded adjacency
                    (``distributed/contract``, route ``einsum-sharded``):
                    free cut tensors come back already sliced on cut
                    axis 0 and hand off to the sharded join tier without
                    a gather, and no unsharded n x n adjacency is ever
                    materialised;
* ``Intersect``  -> degeneracy-ordered clique enumeration, or the Pallas
                    ``triangle_count`` kernel when ``use_pallas`` is set
                    (k == 3, f32 MXU path; inputs zero-padded to the tile
                    multiple, so any ``n`` works);
* ``CutJoin``    -> the fused Pallas kernel tier for |cut| <= 3: the
                    k-factor masked product-reduce (``kernels.ops.
                    cutjoin_reduce``) for |cut| <= 2, the tiled tri-join
                    (``cutjoin_reduce3``) for |cut| = 3 — axis-subset
                    factors broadcast per tile, pairwise-distinct mask
                    from tile iotas, so no O(n^|cut|) mask is ever
                    materialised — with chunked f32 tile partials summed
                    on the host in f64.  |cut| = 1 takes the vector fast
                    path.  Chunk sizes come from an exactness guard
                    (``cutjoin_exact_block``) fed by per-factor max
                    magnitudes cached on the plan: integer counts are
                    only routed to f32 chunks the bound proves exact.
                    The jitted XLA ``_join_reduce`` (dense factor stack
                    x explicit mask, f64, axis-subset factors broadcast
                    dense) remains the fallback for wider cuts /
                    over-bound magnitudes / ``cutjoin_kernel=False``,
                    and the interpret-mode oracle the kernel is tested
                    against;
* the combine ops run on host scalars.

Node values memoise per plan *and* feed the engine's hom memo, so
repeated queries against a compiled application never re-contract."""
from __future__ import annotations

import functools
from contextlib import nullcontext
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.counting import CountingEngine
from repro.core.pattern import Pattern, clique
from repro.graph.storage import Graph
from repro.compiler.ir import (Contract, CutJoin, Intersect, LocalCount,
                               MobiusCombine, Plan, ShrinkageCorrect,
                               domain_keys, free_skeleton, is_local_output,
                               local_key, pattern_key)


@jax.jit
def _join_reduce(stack):
    """Π of the stacked factor tensors (leading axis), then full sum."""
    return jnp.sum(jnp.prod(stack, axis=0))


@functools.partial(jax.jit, static_argnames=("axis",))
def _join_keep(stack, axis):
    """Keep-axis XLA fallback/oracle: Π of stacked (n, n) factors, off-
    diagonal masked, summed over the non-kept axis (f64 under x64)."""
    prod = jnp.prod(stack, axis=0)
    off = 1.0 - jnp.eye(prod.shape[0], dtype=prod.dtype)
    return jnp.sum(prod * off, axis=1 - axis)


@functools.partial(jax.jit, static_argnames=("keep",))
def _join_keep3(stack, mask, keep):
    """Keep-axis |cut| = 3 XLA fallback/oracle: Π of stacked (n, n, n)
    factors under the dense pairwise-distinct mask, summed over the two
    non-kept axes (f64 under x64) — the tri-join kernel's bit-for-bit
    reference."""
    prod = jnp.prod(stack, axis=0) * mask
    return jnp.sum(prod, axis=tuple(a for a in range(3) if a != keep))


class CompiledPlan:
    """An executable application: one plan, one graph."""

    def __init__(self, plan: Plan, graph: Graph,
                 counter: Optional[CountingEngine] = None,
                 use_pallas: bool = False, from_cache: bool = False,
                 budget: int = 1 << 27, cutjoin_kernel: bool = True,
                 mesh=None, count_store=None):
        self.plan = plan
        self.graph = graph
        # a default engine inherits the mesh so Contract nodes run their
        # hom contractions sharded too (a caller-supplied counter keeps
        # its own binding — pass mesh= to CountingEngine to shard it)
        self.counter = counter or CountingEngine(graph, budget=budget,
                                                 mesh=mesh)
        self.use_pallas = use_pallas
        self.cutjoin_kernel = cutjoin_kernel
        self.from_cache = from_cache
        # execution mesh for the sharded tiers (a 1-D ("data",) jax
        # Mesh): joins block-shard over cut axis 0 (distributed/cutjoin)
        # and the default engine's hom contractions run as collective
        # einsums over the row-sharded adjacency (distributed/contract);
        # None keeps every route single-device
        self.mesh = mesh
        # morph count store (compiler.morph.CountStore): scalar hom
        # reads consult it before contracting (route "morph-derive")
        # and every count read harvests its exact scalars back into it
        self.count_store = count_store
        self._gsig: Optional[str] = None
        self._values: Dict[str, object] = {}
        self._masks: Dict[int, np.ndarray] = {}
        self._factors: Dict[tuple, np.ndarray] = {}
        self._factor_maxes: Dict[tuple, float] = {}
        self._precert: Optional[Dict[str, int]] = None
        # attach an ``obs.Tracer`` here to record per-node span trees on
        # every public read; None (the default) costs one is-None check
        # per node eval — nothing else
        self.tracer = None
        self.stats = obs.StatsView(
            "plan", keys=("node_evals", "node_hits", "exists_early_exits"))

    # -- tracing hooks -----------------------------------------------------------
    def _root(self, op: str, key: str):
        """Root "execute" span for one public read (no-op untraced).
        Node spans opened by the ``value`` recursion nest beneath it, so
        a trace's root coverage measures how much of the end-to-end read
        the per-node accounting explains."""
        tr = self.tracer
        if tr is None:
            return nullcontext()
        return tr.span(f"{op}:{key}", kind="execute", op=op)

    def _annotate(self, **attrs):
        """Attach attributes to the innermost open span (no-op untraced
        or outside any span — eval helpers are also called directly)."""
        tr = self.tracer
        if tr is not None:
            tr.annotate(**attrs)

    # -- morph store hooks -------------------------------------------------------
    def _store_hom(self, node_key: str):
        """Held scalar hom for one ``hom:`` node key, or None (no store
        attached / miss).  The graph signature is resolved lazily once."""
        if self.count_store is None:
            return None
        if self._gsig is None:
            from repro.compiler.cache import graph_signature
            self._gsig = graph_signature(self.graph)
        return self.count_store.get_key(self._gsig, node_key)

    def _harvest(self):
        if self.count_store is not None:
            self.count_store.harvest(self)

    # -- public API --------------------------------------------------------------
    def count(self, p: Pattern) -> float:
        """Edge-induced embedding count of one compiled pattern."""
        key = self.plan.output_for(p)
        with self._root("count", key):
            val = float(self.value(key))
        self._harvest()
        return val

    def counts(self) -> dict:
        """All compiled count outputs: canonical pattern key -> count
        (partial-embedding outputs are tensors — read them through
        ``local_counts``)."""
        with self._root("counts", "*"):
            out = {pk: float(self.value(nk))
                   for pk, nk in self.plan.outputs.items()
                   if not is_local_output(pk)}
        self._harvest()
        return out

    def has_local(self, p: Pattern, anchor: Optional[int] = None) -> bool:
        """True when the plan carries the requested partial-embedding
        output (compiled with ``local=True``; unanchored tensors need an
        eligible cutting set — cliques have none)."""
        return local_key(p, anchor) in self.plan.outputs

    def local_counts(self, p: Pattern,
                     anchor: Optional[int] = None) -> np.ndarray:
        """Partial-embedding counts of one pattern compiled with
        ``local=True``.

        ``anchor=None``: the full local tensor over the cutting set
        chosen for ``p.canonical()`` — axis j indexes the assignment of
        the j-th smallest cut vertex *of the canonical form*
        (``plan.meta["local_cuts"]`` records the cut; the key collapses
        isomorphic renumberings, so the shared answer is expressed in
        the one numbering every caller can reconstruct), entry e_c is
        the exact number of injective maps pinning the cut to e_c.
        ``anchor=v``: the (N,) vector of completion counts with pattern
        vertex v pinned per graph vertex — anchors in one automorphism
        orbit share their entry (``local_key`` collapses them).  Raises
        ``KeyError`` when the plan has no such output."""
        key = local_key(p, anchor)
        nk = self.plan.outputs.get(key)
        if nk is None:
            raise KeyError(
                f"plan has no partial-embedding output {key!r} "
                f"(compiled without local=True, or the pattern has no "
                f"eligible cutting set)")
        # a copy, not the memo: plans are memoised across serving steps,
        # so handing out the node-value array itself would let one
        # caller's in-place edit corrupt every later answer
        with self._root("local_counts", nk):
            return np.array(self.value(nk), np.float64)

    def exists(self, p: Pattern) -> bool:
        """Existence with early exit: on a local plan, factor tensors
        evaluate one subpattern at a time and an all-zero factor decides
        False before the join or any shrinkage correction runs (one
        subpattern with no embeddings means the whole pattern has none);
        otherwise any positive local entry — or, without a local output,
        the scalar count — decides."""
        nk = self.plan.outputs.get(local_key(p))
        node = self.plan.nodes.get(nk) if nk is not None else None
        with self._root("exists", nk or pattern_key(p)):
            if isinstance(node, LocalCount):
                for terms, ax in zip(node.factors, node.factor_axes()):
                    if not np.any(np.abs(self._combine(terms, len(ax)))
                                  > 0.5):
                        self.stats["exists_early_exits"] += 1
                        self._annotate(early_exit=True)
                        return False
                return bool(np.max(self.value(nk)) > 0.5)
            if nk is not None:
                return bool(np.max(np.asarray(self.value(nk))) > 0.5)
            return self.count(p) > 0.5

    def executable(self, p: Pattern):
        """Zero-arg closure for one pattern (plan handle for callers that
        dispatch queries later)."""
        key = self.plan.output_for(p)
        return lambda: float(self.value(key))

    def domains(self, p: Pattern) -> dict:
        """FSM MINI domain vectors of one pattern compiled with
        ``domains=True``: canonical orbit-representative vertex -> (N,)
        array counting injective maps sending that vertex to each graph
        vertex.  Raises ``KeyError`` when the plan has no domain nodes
        for ``p``."""
        out = {}
        with self._root("domains", pattern_key(p)):
            for key in domain_keys(p):
                if key not in self.plan.nodes:
                    raise KeyError(f"plan has no domain node {key!r} "
                                   f"(compiled without domains=True?)")
                out[int(key.rsplit(":", 1)[1])] = \
                    np.asarray(self.value(key))
        return out

    def mini_support(self, p: Pattern) -> int:
        """MINI support = min over pattern vertices of the domain size;
        orbit representatives suffice (orbit members share domains)."""
        return min(int(np.count_nonzero(dom > 0.5))
                   for dom in self.domains(p).values())

    # -- evaluation --------------------------------------------------------------
    def value(self, key: str):
        if key in self._values:
            self.stats["node_hits"] += 1
            return self._values[key]
        node = self.plan.nodes[key]
        self.stats["node_evals"] += 1
        tr = self.tracer
        if tr is None:                   # the default: no span machinery
            val = self._eval(node)
        else:
            # one span per node eval, nested by the recursion itself
            # (refs evaluated inside ``_eval`` open child spans; memo
            # hits open none — the trace tree is exactly the work done).
            # ``predicted`` pairs the APCT cost the model charged at
            # selection time for the drift report; the fence closes the
            # span only after JAX async dispatch has really finished.
            attrs = {"predicted":
                     self.plan.meta.get("node_costs", {}).get(key)}
            cut = getattr(node, "cut_size", None)
            if cut is not None:
                attrs["cut_size"] = cut
            with tr.span(key, kind=type(node).__name__, **attrs):
                val = obs.fence(self._eval(node))
        self._values[key] = val
        return val

    def _eval(self, node):
        if isinstance(node, Contract):
            if not node.free:
                held = self._store_hom(node.key)
                if held is not None:
                    self._annotate(route="morph-derive")
                    return float(held)
            shards = self.counter.contract_shards()
            if node.free:
                # decode the marker-encoded pattern: strips cut-rank
                # markers, restores real vertex labels (label-masked
                # contraction on labelled patterns)
                if shards > 1:
                    self._annotate(route="einsum-sharded",
                                   adjacency="sharded", mesh_axes=["data"],
                                   num_shards=shards)
                else:
                    self._annotate(route="einsum-free")
                skel = free_skeleton(node.pattern)
                return self.counter.hom_free_tensor(skel, node.free,
                                                    order=node.order)
            if shards > 1:
                self._annotate(route="einsum-sharded", adjacency="sharded",
                               mesh_axes=["data"], num_shards=shards)
            else:
                self._annotate(route="einsum")
            return self.counter.hom(node.pattern, order=node.order or None)
        if isinstance(node, Intersect):
            held = self._store_hom(node.key)
            if held is not None:
                self._annotate(route="morph-derive")
                return float(held)
            if self.use_pallas and node.k == 3:
                from repro.kernels import ops
                self._annotate(route="pallas-triangle")
                adj = self.graph.dense_adjacency(np.float32, pad=False)
                return 6.0 * float(ops.triangle_count(adj))
            self._annotate(route="enumeration")
            return self.counter.hom(clique(node.k))
        if isinstance(node, MobiusCombine):
            self._annotate(route="host")
            acc = 0.0
            for coeff, ref in node.terms:
                acc += coeff * self.value(ref)
            return acc / node.divisor
        if isinstance(node, CutJoin):
            return self._eval_cutjoin(node)
        if isinstance(node, LocalCount):
            return self._eval_local(node)
        if isinstance(node, ShrinkageCorrect):
            self._annotate(route="host")
            acc = self.value(node.base)
            for mult, ref in node.corrections:
                acc -= mult * self.value(ref)
            return acc / node.divisor
        raise TypeError(type(node))

    def _combine(self, terms, ndim: int) -> np.ndarray:
        """One Möbius factor tensor Σ coeff · tensor(ref), f64 — treat
        the result as READ-ONLY.  Genuine combinations memoise by term
        tuple (CutJoin and LocalCount nodes over the same cut, and
        ``exists`` early-exit probes, share them); a single identity
        term returns the node value itself — duplicating every Contract
        tensor into a second (n,)*ndim array would roughly double a
        long-lived serving plan's steady-state memory.  Sharded Contract
        tensors (jax Arrays sliced over cut axis 0 — see
        ``CountingEngine.hom_free_tensor``) stay on device: combining in
        jnp keeps the slices where the sharded join tier reads them, so
        the factor handoff never gathers."""
        if len(terms) == 1 and terms[0][0] == 1.0:
            v = self.value(terms[0][1])
            if isinstance(v, jax.Array):
                return v
            return np.asarray(v, np.float64)
        key = (terms, ndim)
        M = self._factors.get(key)
        if M is None:
            vals = [(coeff, self.value(ref)) for coeff, ref in terms]
            if any(isinstance(v, jax.Array) for _, v in vals):
                with self.counter._x64():
                    M = jnp.zeros((self.graph.n,) * ndim, jnp.float64)
                    for coeff, v in vals:
                        M = M + coeff * jnp.asarray(v, jnp.float64)
            else:
                M = np.zeros((self.graph.n,) * ndim)
                for coeff, v in vals:
                    M = M + coeff * np.asarray(v, np.float64)
            self._factors[key] = M
        return M

    def _factor_max(self, terms, ndim: int, M) -> float:
        """max|M| for the factor combined from ``terms``, memoised under
        the same key as ``_combine``: the ``exact_block`` guard needs
        every factor's max magnitude on every kernel execution, and
        re-scanning long-lived serving factors would force a full
        device→host reduction per query.  Sharded factors reduce on
        device (one scalar transfer, no tensor gather)."""
        key = (terms, ndim)
        v = self._factor_maxes.get(key)
        if v is None:
            if not np.size(M):
                v = 0.0
            elif isinstance(M, jax.Array):
                with self.counter._x64():
                    v = float(jnp.max(jnp.abs(M)))
            else:
                v = float(np.abs(np.asarray(M)).max())
            self._factor_maxes[key] = v
        return v

    def _join_factors(self, node):
        """(factors, axes) of a CutJoin/LocalCount node: each factor
        combined over its *own* axis subset (axis-subset factors stay at
        their own size).  Max magnitudes are *not* scanned here — the
        exactness guard (``_guard_block``) only pays for them when no
        static certificate covers the node, and the XLA route never
        needs them at all."""
        axes = node.factor_axes()
        Ms = [self._combine(terms, len(ax))
              for terms, ax in zip(node.factors, axes)]
        return Ms, axes

    def _precertified(self) -> Dict[str, int]:
        """Statically certified ``exact_block`` chunks, computed once
        per compiled plan from the *bound graph* — never trusted from
        ``plan.meta`` (a corrupted cached certificate would silently
        break kernel exactness; recomputing from the graph the plan is
        actually bound to costs microseconds and is always sound)."""
        if self._precert is None:
            from repro import analysis
            self._precert = analysis.precertify(
                self.plan, analysis.GraphInfo.from_graph(self.graph))
        return self._precert

    def _guard_block(self, node, Ms, axes):
        """The ``exact_block`` guard for one join.  Precertified nodes
        trust the static certificate — no device→host factor scan on
        the serving path; everything else scans factor magnitudes under
        a traced ``guard-scan`` span, so the cost the certificate
        removes stays visible in traces."""
        from repro.kernels import ops
        static = self._precertified().get(node.key)
        if static is not None:
            block = ops.runtime_block(static)
            obs.counter("kernel.exact_block", outcome="precertified")
            self._annotate(exact_block=block, precertified=True)
            return block
        tr = self.tracer
        ctx = (tr.span(f"guard:{node.key}", kind="guard-scan")
               if tr is not None else nullcontext())
        with ctx:
            maxes = [self._factor_max(terms, len(ax), M)
                     for terms, M, ax in zip(node.factors, Ms, axes)]
            block = ops.cutjoin_exact_block(Ms, maxes=maxes)
        self._annotate(exact_block=block)
        return block

    def _dense_expand(self, Ms, axes, k: int):
        """Broadcast axis-subset factors to the full (n,)*k cut grid —
        the XLA dense fallback/oracle only; the kernel tier never calls
        this.  Costing admits |cut| >= 3 joins by their *factor* sizes
        (pair-only formulations stay eligible where n^k doesn't fit),
        so the dense fallback must refuse rather than materialise the
        n^k stack + mask the budget never approved — ``PlanTooWide``
        sends callers down their legacy fallback path."""
        from repro.core.homomorphism import PlanTooWide
        n = self.graph.n
        if k >= 3 and n ** k > 4 * self.counter.budget:
            raise PlanTooWide(
                f"dense |cut| = {k} fallback would materialise "
                f"{n ** k:.2e}-element factors/mask beyond the cap "
                f"(kernel guard refused or cutjoin_kernel=False)")
        out = []
        for M, ax in zip(Ms, axes):
            if len(ax) == k:
                out.append(M)
                continue
            shape = tuple(n if a in ax else 1 for a in range(k))
            out.append(np.broadcast_to(np.asarray(M).reshape(shape),
                                       (n,) * k))
        return out

    def _shard_fallback(self, reason: str):
        """Count one sharded-tier fallback, split by phase: a fresh
        compile's plan evals and a cache-hit serve's re-lower each
        re-evaluate the same nodes, so one shared counter double-counted
        the same logical fallback — phase-keyed counters (mirroring the
        batcher's ``fallbacks_compile``/``fallbacks_execute``) keep the
        two populations separable in ``obs`` snapshots."""
        phase = "execute" if self.from_cache else "compile"
        obs.counter(f"cutjoin.shard_fallbacks_{phase}", reason=reason)
        self._annotate(shard_fallback=reason)

    def _mesh_shards(self) -> int:
        """Usable shard count for this plan's joins: 1 without a mesh
        (or a trivial one); a graph smaller than the mesh falls back to
        single-device — slicing fewer rows than devices would leave
        idle shards and an all-padding grid on some of them."""
        if self.mesh is None:
            return 1
        from repro.distributed import meshes
        d = meshes.num_shards(self.mesh)
        if d <= 1:
            return 1
        if self.graph.n < d:
            self._shard_fallback("small-n")
            return 1
        return d

    def _eval_cutjoin(self, node: CutJoin) -> float:
        Ms, axes = self._join_factors(node)
        self._annotate(factor_shapes=[list(np.shape(M)) for M in Ms])
        shards = self._mesh_shards()
        if self.cutjoin_kernel and node.cut_size <= 3:
            from repro.kernels import ops
            block = self._guard_block(node, Ms, axes)
            if block is not None:            # f32 chunks provably exact
                if shards > 1:
                    from repro.distributed import cutjoin as dcj
                    self._annotate(route="kernel-sharded",
                                   mesh_axes=["data"], num_shards=shards)
                    if node.cut_size <= 2:
                        return dcj.sharded_cutjoin(
                            Ms, mesh=self.mesh,
                            distinct=node.cut_size >= 2, block=block)
                    return dcj.sharded_cutjoin3(Ms, axes, n=self.graph.n,
                                                mesh=self.mesh,
                                                block=block)
                self._annotate(route="kernel")
                if node.cut_size <= 2:
                    return ops.cutjoin_reduce(Ms,
                                              distinct=node.cut_size >= 2,
                                              bm=block, bn=block)
                return ops.cutjoin_reduce3(Ms, axes, n=self.graph.n,
                                           block=block)
            # factor magnitudes exceed what chunked f32 can represent
            # exactly: fall through to the f64 XLA join
            obs.counter("cutjoin.kernel_fallbacks", cut=node.cut_size)
        Ms = self._dense_expand(Ms, axes, node.cut_size)
        if node.cut_size >= 2:               # injectivity of the cut tuple
            Ms.append(self._mask(node.cut_size))
        if shards > 1 and node.cut_size <= 3:
            # guard refusal / cutjoin_kernel=False under a mesh: the f64
            # dense join still shards (pure XLA, no chunking, no guard)
            from repro.distributed import cutjoin as dcj
            self._annotate(route="xla-sharded", mesh_axes=["data"],
                           num_shards=shards)
            return dcj.sharded_dense_join(Ms, node.cut_size,
                                          mesh=self.mesh)
        if shards > 1:
            self._shard_fallback("wide-cut")
        self._annotate(route="xla-dense")
        with self.counter._x64():
            return float(_join_reduce(jnp.stack([jnp.asarray(M)
                                                 for M in Ms])))

    def _eval_local(self, node: LocalCount) -> np.ndarray:
        """The decomposition join without the final reduce.  Reduce-free
        (keep == all axes): the factor product with the off-diagonal
        mask applied *after* subtracting corrections — anchored
        correction tensors only equal true pinned-injective counts at
        distinct pins, so diagonal entries are defined to zero by the
        mask, matching Σ L = inj exactly.  Keep-axis (|cut| = 2, one
        surviving axis): the Pallas keep-axis kernel when the exactness
        guard admits the factors, else the jitted f64 XLA mask-and-sum
        (also the kernel's bit-for-bit oracle); corrections are already
        vector-sized and subtract after the reduce."""
        Ms, axes = self._join_factors(node)
        self._annotate(factor_shapes=[list(np.shape(M)) for M in Ms])
        if node.cut_size == 1 or len(node.keep) == node.cut_size:
            self._annotate(route="dense-product")
            dense = self._dense_expand(Ms, axes, node.cut_size)
            out = np.array(dense[0], np.float64)
            for M in dense[1:]:
                out *= M
            if node.corrections:
                out -= self._combine(node.corrections, len(node.keep))
            self._zero_collisions(out)       # injectivity of the cut tuple
            return out
        # keep-axis reduce: |cut| in {2, 3}, one surviving axis
        axis = node.keep[0]
        out = None
        shards = self._mesh_shards()
        if self.cutjoin_kernel:
            from repro.kernels import ops
            block = self._guard_block(node, Ms, axes)
            if block is not None and shards > 1:
                from repro.distributed import cutjoin as dcj
                self._annotate(route="kernel-sharded-keep",
                               mesh_axes=["data"], num_shards=shards)
                if node.cut_size == 2:
                    out = dcj.sharded_cutjoin_keep(Ms, keep=axis,
                                                   mesh=self.mesh,
                                                   block=block)
                else:
                    out = dcj.sharded_cutjoin3_keep(Ms, axes, keep=axis,
                                                    n=self.graph.n,
                                                    mesh=self.mesh,
                                                    block=block)
            elif block is not None:          # f32 chunks provably exact
                self._annotate(route="kernel-keep")
                if node.cut_size == 2:
                    out = ops.cutjoin_reduce_keep(Ms, keep=axis,
                                                  bm=block, bn=block)
                else:
                    out = ops.cutjoin_reduce3_keep(Ms, axes, keep=axis,
                                                   n=self.graph.n,
                                                   block=block)
            else:
                obs.counter("cutjoin.kernel_fallbacks", cut=node.cut_size,
                            keep=True)
        if out is None and shards > 1:
            # guard refusal / cutjoin_kernel=False under a mesh: the f64
            # dense keep join still shards (pure XLA, no chunking, no
            # guard) — mirroring the scalar route's ``xla-sharded``
            from repro.distributed import cutjoin as dcj
            dense = self._dense_expand(Ms, axes, node.cut_size)
            dense.append(self._mask(node.cut_size))
            self._annotate(route="xla-sharded-keep", mesh_axes=["data"],
                           num_shards=shards)
            out = dcj.sharded_dense_join_keep(dense, node.cut_size,
                                              keep=axis, mesh=self.mesh)
        if out is None:
            self._annotate(route="xla-keep")
            dense = self._dense_expand(Ms, axes, node.cut_size)
            with self.counter._x64():
                stack = jnp.stack([jnp.asarray(M) for M in dense])
                if node.cut_size == 2:
                    out = np.asarray(_join_keep(stack, axis), np.float64)
                else:
                    out = np.asarray(
                        _join_keep3(stack, jnp.asarray(self._mask(3)),
                                    axis), np.float64)
        if node.corrections:
            out = out - self._combine(node.corrections, 1)
        return out

    def _zero_collisions(self, out: np.ndarray):
        """Zero every entry whose index tuple repeats a value — the cut
        injectivity mask applied in place to a reduce-free local tensor
        (ndim 2: the diagonal; ndim 3: the three pairwise-equal planes;
        ndim 1: nothing — a single cut vertex is always injective)."""
        if out.ndim == 1:
            return
        if out.ndim == 2:
            np.fill_diagonal(out, 0.0)
            return
        assert out.ndim == 3
        idx = np.arange(out.shape[0])
        out[idx, idx, :] = 0.0
        out[idx, :, idx] = 0.0
        out[:, idx, idx] = 0.0

    def _mask(self, k: int) -> np.ndarray:
        """Π_{a<b} [x_a != x_b] over a (n,)*k grid."""
        if k not in self._masks:
            n = self.graph.n
            mask = np.ones((n,) * k)
            off = 1.0 - np.eye(n)
            for a in range(k):
                for b in range(a + 1, k):
                    shape = [1] * k
                    shape[a] = shape[b] = n
                    mask = mask * off.reshape(shape)
            self._masks[k] = mask
        return self._masks[k]


def lower(plan: Plan, graph: Graph, *, counter=None, use_pallas=False,
          from_cache=False, budget: int = 1 << 27,
          cutjoin_kernel: bool = True, verify: bool = False,
          mesh=None, count_store=None) -> CompiledPlan:
    """Bind a plan to a graph.  ``verify=True`` runs the static
    verifier against this graph first and raises ``PlanVerifyError``
    instead of binding a malformed plan — for plans that arrived from
    outside ``compiler.compile`` (hand-built, deserialized, mutated),
    which already verifies what it commits.  ``mesh`` (a 1-D
    ``("data",)`` jax Mesh) routes guarded joins through the sharded
    tier — numerically identical, see ``distributed/cutjoin.py``.
    ``count_store`` (a ``compiler.morph.CountStore``) serves held scalar
    homs without contracting and harvests every count read back."""
    if verify:
        from repro import analysis
        analysis.verify(
            plan, graph_info=analysis.GraphInfo.from_graph(graph),
            budget=budget).raise_if_failed()
    return CompiledPlan(plan, graph, counter=counter, use_pallas=use_pallas,
                        from_cache=from_cache, budget=budget,
                        cutjoin_kernel=cutjoin_kernel, mesh=mesh,
                        count_store=count_store)
