"""Pattern-morphing count algebra: serve motif families from held counts.

Counts of a pattern are integer linear combinations of counts of its
lattice neighbours (Pattern Morphing, Jamshidi & Vora).  The algebra is
the partition-lattice Möbius machinery already in ``core.quotient``:

    inj(p)  =  sum_sigma  mu(sigma) * hom(p / sigma)        (quotient_terms)
    hom(p)  =  sum_sigma            inj(p / sigma)          (hom_expansion)

so an exact-count store of scalar ``hom`` / ``inj`` values lets a query
pattern be answered *without compiling a plan*: expand ``inj(p)`` over
quotient homs, densify any missing ``hom`` through its own injective
expansion, and recurse — every value grounded in a store entry that some
earlier ``CompiledPlan`` evaluation materialised.  Under clustered
traffic (motif families, the FSM frontier) the handful of compiled plans
needed to warm the store then serves the whole family algebraically.

Three pieces live here:

* ``CountStore`` — the persistent exact-count store.  Keys are
  ``(graph_signature, "hom:<pattern_key>" | "inj:<pattern_key>")`` with
  canonical pattern keys, so labelled orbit members share entries.
  Process-local dict tier plus an optional atomic on-disk tier
  (one ``counts-<gsig>.json`` per graph, tmp-write + ``os.replace``,
  ``MORPH_FORMAT_VERSION``-stamped — the same write/versioning
  discipline as ``PlanCache``; see the format note in ``cache.py``).
  ``CountStore.harvest`` scrapes every exact scalar an executed
  ``CompiledPlan`` materialised (non-free Contract homs, Intersect
  clique homs, ``inj:`` Möbius nodes, ``cnt:`` outputs).
* the lattice explorer — ``morph_neighbours`` (bounded edge-add/remove
  BFS over canonical connected patterns: the coverage frontier / family
  workload) and ``derive``, which builds the inclusion–exclusion
  identity for a query pattern over store-held values and returns a
  ``MorphCandidate`` carrying the coefficients and the set of *missing*
  homs still requiring a contraction.
* the costing hook — ``MorphCandidate.missing`` maps one-to-one onto the
  ``hom:`` Contract nodes of a direct plan, so ``compiler.compile``
  prices a morph by handing ``costing.select_candidates`` the set of
  held node keys (held contractions cost ~0, missing ones keep their
  APCT price) and serves fully-closed queries straight from the store.
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass
from typing import Dict, Optional, Set, Tuple

from repro import obs
from repro.compiler.ir import (Contract, Intersect, MobiusCombine,
                               is_local_output, pattern_key)
from repro.core.pattern import Pattern, clique
from repro.core.quotient import hom_expansion, quotient_terms

MORPH_FORMAT_VERSION = 1


def pattern_from_key(key: str) -> Pattern:
    """Invert ``ir.pattern_key``: ``"<n>.<bits>[:l1,l2,...]"`` back to the
    canonical :class:`Pattern`.  The bit index runs row-major over vertex
    pairs ``i < j`` exactly as ``Pattern._code`` packs them."""
    head, _, lab = key.partition(":")
    n_s, _, bits_s = head.partition(".")
    n, bits = int(n_s), int(bits_s)
    edges = []
    k = 0
    for i in range(n):
        for j in range(i + 1, n):
            if bits >> k & 1:
                edges.append((i, j))
            k += 1
    labels = tuple(int(x) for x in lab.split(",")) if lab else None
    return Pattern(n, edges, labels)


def entry_key(kind: str, p: Pattern) -> str:
    """Store entry key for ``kind`` in {"hom", "inj"} — canonicalises, so
    ``hom`` entries carry exactly the node keys of plan Contract nodes."""
    return f"{kind}:{pattern_key(p)}"


class CountStore:
    """Exact scalar-count store keyed by graph signature and canonical
    pattern key.  Memory tier always; disk tier when ``path`` is given
    (atomic per-graph JSON files, format-versioned — drift is a clean
    miss, mirroring ``PlanCache``)."""

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self._mem: Dict[str, Dict[str, int]] = {}
        self._loaded: Set[str] = set()
        self._dirty: Set[str] = set()
        self.stats = obs.StatsView(
            "countstore", keys=("hits", "misses", "puts", "format_misses",
                                "sync_failures"))
        if path:
            os.makedirs(path, exist_ok=True)

    # -- tiers ---------------------------------------------------------------
    def _file(self, gsig: str) -> str:
        return os.path.join(self.path, f"counts-{gsig}.json")

    def _counts(self, gsig: str) -> Dict[str, int]:
        c = self._mem.setdefault(gsig, {})
        if self.path and gsig not in self._loaded:
            self._loaded.add(gsig)
            f = self._file(gsig)
            if os.path.exists(f):
                try:
                    with open(f) as fh:
                        doc = json.load(fh)
                    if doc.get("version") != MORPH_FORMAT_VERSION:
                        raise ValueError("count-store format drift")
                    disk = {str(k): int(v)
                            for k, v in doc["counts"].items()}
                except (OSError, ValueError, KeyError, TypeError):
                    self.stats["format_misses"] += 1
                else:
                    for k, v in disk.items():
                        c.setdefault(k, v)
        return c

    def sync(self) -> None:
        """Flush dirty graphs to the disk tier — atomic tmp-write +
        ``os.replace`` per file, same discipline as ``PlanCache.put``."""
        if not self.path:
            self._dirty.clear()
            return
        for gsig in sorted(self._dirty):
            doc = {"version": MORPH_FORMAT_VERSION, "graph": gsig,
                   "counts": self._mem.get(gsig, {})}
            final = self._file(gsig)
            tmp = f"{final}.tmp.{os.getpid()}"
            try:
                with open(tmp, "w") as fh:
                    fh.write(json.dumps(doc, sort_keys=True))
                os.replace(tmp, final)
            except OSError:
                # read-only store dir: serving continues off memory
                self.stats["sync_failures"] += 1
            finally:
                if os.path.exists(tmp):
                    try:
                        os.unlink(tmp)
                    except OSError:
                        pass
        self._dirty.clear()

    # -- accessors -----------------------------------------------------------
    def get_key(self, gsig: str, key: str) -> Optional[int]:
        v = self._counts(gsig).get(key)
        self.stats["hits" if v is not None else "misses"] += 1
        return v

    def get(self, gsig: str, kind: str, p: Pattern) -> Optional[int]:
        return self.get_key(gsig, entry_key(kind, p))

    def has(self, gsig: str, kind: str, p: Pattern) -> bool:
        return entry_key(kind, p) in self._counts(gsig)

    def put(self, gsig: str, kind: str, p: Pattern, value) -> int:
        """Record one exact value (rounded to int — counts are exact in
        f64 up to 2**53).  Returns 1 when the entry is new, else 0."""
        c = self._counts(gsig)
        k = entry_key(kind, p)
        iv = int(round(float(value)))
        if c.get(k) == iv:
            return 0
        c[k] = iv
        self._dirty.add(gsig)
        self.stats["puts"] += 1
        return 1

    def held_hom_keys(self, gsig: str) -> Set[str]:
        """Plan node keys (``hom:<pattern_key>``) of scalar homs held for
        ``gsig`` — the pool the costing hook prices at ~0."""
        return {k for k in self._counts(gsig) if k.startswith("hom:")}

    def __len__(self) -> int:
        return sum(len(c) for c in self._mem.values())

    # -- feeding -------------------------------------------------------------
    def harvest(self, cp) -> int:
        """Scrape every exact scalar an executed plan materialised into
        the store: evaluated non-free ``Contract`` homs, ``Intersect``
        clique homs, ``inj:`` Möbius nodes, and ``cnt:`` outputs (count ×
        |Aut| = inj).  Idempotent and cheap; syncs when anything is new."""
        from repro.compiler.cache import graph_signature
        gsig = graph_signature(cp.graph)
        plan = cp.plan
        new = 0
        for key, val in list(cp._values.items()):
            node = plan.nodes.get(key)
            if isinstance(node, Contract) and not node.free:
                new += self.put(gsig, "hom", node.pattern, val)
            elif isinstance(node, Intersect):
                new += self.put(gsig, "hom", clique(node.k), val)
            elif (isinstance(node, MobiusCombine) and node.divisor == 1
                  and key.startswith("inj:")):
                new += self.put(gsig, "inj", pattern_from_key(key[4:]), val)
        for pk, nk in plan.outputs.items():
            if is_local_output(pk) or nk not in cp._values:
                continue
            divisor = getattr(plan.nodes.get(nk), "divisor", None)
            if not divisor:
                continue
            try:
                val = float(cp._values[nk])
            except (TypeError, ValueError):
                continue  # keep-axis / domain outputs are tensors
            new += self.put(gsig, "inj", pattern_from_key(pk), val * divisor)
        if new:
            self.sync()
        return new


_DEFAULT_STORE = CountStore()


def default_store() -> CountStore:
    """The process-wide store ``compile(..., morph=True)`` uses, mirroring
    ``compiler.default_cache()``."""
    return _DEFAULT_STORE


# -- lattice explorer --------------------------------------------------------

def morph_neighbours(p: Pattern, distance: int = 1) -> tuple:
    """Connected canonical patterns within ``distance`` edge-add/remove
    steps of ``p`` (same vertex count, ``p`` itself excluded) — the
    morphing coverage frontier / motif-family workload."""
    pc = p.canonical()
    frontier = {pc}
    seen = {pc}
    for _ in range(max(0, int(distance))):
        nxt = set()
        for q in frontier:
            for u in range(q.n):
                for v in range(u + 1, q.n):
                    e = (u, v)
                    if e in q.edges:
                        r = Pattern(q.n, q.edges - {e}, q.labels)
                    else:
                        r = Pattern(q.n, q.edges | {e}, q.labels)
                    if not r.is_connected():
                        continue
                    rc = r.canonical()
                    if rc not in seen:
                        seen.add(rc)
                        nxt.add(rc)
        frontier = nxt
    seen.discard(pc)
    return tuple(sorted(seen, key=lambda q: (q.m, pattern_key(q))))


def motif_family(k: int) -> tuple:
    """All connected ``k``-vertex patterns up to isomorphism, sorted by
    edge count — the canonical motif-family workload (6 members at
    ``k = 4``, 21 at ``k = 5``)."""
    pairs = [(i, j) for i in range(k) for j in range(i + 1, k)]
    out = {}
    for bits in range(1 << len(pairs)):
        p = Pattern(k, [e for t, e in enumerate(pairs) if bits >> t & 1])
        if p.is_connected():
            out.setdefault(p.canonical(), None)
    return tuple(sorted(out, key=lambda q: (q.m, pattern_key(q))))


# -- derivation --------------------------------------------------------------

@dataclass(frozen=True)
class MorphCandidate:
    """One algebraic way to serve ``count(pattern)`` off the store:

        count(p) = (sum of coeff * hom(q) over ``terms``) / ``divisor``

    with every ``hom(q)`` either held (possibly densified through held
    ``inj`` entries) or listed in ``missing`` — the contractions a
    direct plan would still have to run.  ``value`` is the derived count
    when the identity closes (``missing`` empty), else ``None``."""
    pattern: Pattern
    terms: Tuple[Tuple[int, Pattern], ...]
    missing: Tuple[Pattern, ...]
    divisor: int
    value: Optional[int] = None

    @property
    def complete(self) -> bool:
        return not self.missing

    def missing_node_keys(self) -> Set[str]:
        """The ``hom:`` Contract node keys a direct plan still needs."""
        return {entry_key("hom", q) for q in self.missing}


class _Resolver:
    """Mutual inj <-> hom densification over the store.  ``hom_expansion``
    contains the identity term ``(1, p)``, so the recursion is guarded by
    an in-progress set — a value resolves only when it grounds in a held
    entry, never through its own expansion."""

    def __init__(self, store: CountStore, gsig: str):
        self.store = store
        self.gsig = gsig
        self._busy: Set[tuple] = set()
        self.derivations = 0

    def _close(self, kind: str, qc: Pattern, total: int) -> int:
        self.store.put(self.gsig, kind, qc, total)
        self.derivations += 1
        obs.counter("morph.derivations")
        return total

    def hom(self, q: Pattern) -> Optional[int]:
        qc = q.canonical()
        v = self.store.get(self.gsig, "hom", qc)
        if v is not None:
            return v
        mark = ("hom", qc)
        if mark in self._busy:
            return None
        self._busy.add(mark)
        try:
            total = 0
            for coeff, r in hom_expansion(qc):
                iv = self.inj(r)
                if iv is None:
                    return None
                total += coeff * iv
        finally:
            self._busy.discard(mark)
        return self._close("hom", qc, total)

    def inj(self, q: Pattern) -> Optional[int]:
        qc = q.canonical()
        v = self.store.get(self.gsig, "inj", qc)
        if v is not None:
            return v
        mark = ("inj", qc)
        if mark in self._busy:
            return None
        self._busy.add(mark)
        try:
            total = 0
            for coeff, r in quotient_terms(qc):
                hv = self.hom(r)
                if hv is None:
                    return None
                total += coeff * hv
        finally:
            self._busy.discard(mark)
        return self._close("inj", qc, total)


def derive(p: Pattern, store: CountStore, gsig: str) -> MorphCandidate:
    """Build the inclusion–exclusion identity serving ``count(p)`` from
    the store.  Resolves each quotient hom (densifying through held inj
    entries where needed); homs that fail to resolve land in ``missing``
    and correspond exactly to the Contract nodes a direct plan would run."""
    pc = p.canonical()
    res = _Resolver(store, gsig)
    terms = []
    missing = []
    total = 0
    for coeff, q in quotient_terms(pc):
        terms.append((int(coeff), q))
        v = res.hom(q)
        if v is None:
            missing.append(q)
        else:
            total += int(coeff) * v
    divisor = pc.aut_order()
    value = None
    if not missing:
        store.put(gsig, "inj", pc, total)
        quo, rem = divmod(total, divisor)
        value = quo if rem == 0 else int(round(total / divisor))
    return MorphCandidate(pattern=pc, terms=tuple(terms),
                          missing=tuple(missing), divisor=divisor,
                          value=value)
