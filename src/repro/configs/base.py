"""Config system: architecture configs, input shapes, and ShapeDtypeStruct specs.

Every assigned architecture is a frozen ``ModelConfig``.  ``input_specs``
returns allocation-free ``jax.ShapeDtypeStruct`` stand-ins for every model
input of a given (config, shape) cell, used by the multi-pod dry-run.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int                    # ffn hidden size per expert
    num_shared: int = 0              # shared (always-on) experts, deepseek-v3 style
    every_k_layers: int = 1          # MoE replaces the MLP on layers where
                                     # (layer_idx % every_k_layers) == every_k_layers - 1
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V3 multi-head latent attention."""
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 / SSD."""
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 256                 # SSD chunk length for training


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    qk_norm: bool = False
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    # one period of the layer stack; repeated num_layers/len(pattern) times.
    # 'A' = self-attention mixer, 'M' = mamba mixer, 'X' = cross-attention
    # (extra gated layer, VLM).  Each entry also carries an FFN (MLP or MoE
    # per MoEConfig.every_k_layers, counted over the flat layer index).
    layer_pattern: str = "A"
    # number of layers at the start of the stack that use a dense MLP even
    # when ``moe`` is set (deepseek-v3 has 3).
    dense_prefix: int = 0
    dense_prefix_ff: int = 0         # ffn size of the dense prefix layers
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    mlp_act: str = "swiglu"          # "swiglu" (3 mats) | "gelu" (2 mats)
    tie_embeddings: bool = False
    # modality frontend stub: "tokens" feeds int32 ids; "embeddings" feeds
    # precomputed frame/patch embeddings of width d_model (audio), and vlm
    # additionally feeds image patch embeddings for cross-attention.
    input_mode: str = "tokens"
    num_image_tokens: int = 0        # vlm: #patch embeddings per example
    # dtypes
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    # training-time attention: scan over kv blocks with online softmax when
    # seq > flash_block, bounding activation memory (flash-style).
    flash_block: int = 1024
    remat: bool = True
    # citation / provenance tag from the assignment sheet
    source: str = ""

    @property
    def d_inner(self) -> int:        # ssm inner width
        assert self.ssm is not None
        return self.ssm.expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        assert self.ssm is not None
        return self.d_inner // self.ssm.head_dim

    def pattern_layers(self) -> list[str]:
        """Flat per-layer mixer kinds, length == num_layers."""
        pat = self.layer_pattern
        assert self.num_layers % len(pat) == 0, (self.name, pat)
        return list(pat) * (self.num_layers // len(pat))

    def is_moe_layer(self, idx: int) -> bool:
        if self.moe is None or idx < self.dense_prefix:
            return False
        return (idx % self.moe.every_k_layers) == self.moe.every_k_layers - 1

    def active_param_count(self) -> int:
        """Params touched per token: total minus inactive routed experts."""
        n = self.param_count()
        if self.moe is not None:
            e = self.moe
            per_expert = 3 * self.d_model * e.d_expert
            n_moe_layers = sum(self.is_moe_layer(i)
                               for i in range(self.num_layers))
            n -= n_moe_layers * (e.num_experts - e.top_k) * per_expert
        return n

    def param_count(self) -> int:
        """Exact parameter count derived from the config (for sanity tests)."""
        c, d = self, self.d_model
        n = 0
        n += c.vocab_size * d                      # embed
        if not c.tie_embeddings:
            n += c.vocab_size * d                  # unembed
        n += d                                     # final norm
        for i, kind in enumerate(c.pattern_layers()):
            has_ffn = not (kind == "M" and c.family == "ssm")
            n += d * (2 if has_ffn else 1)         # pre-norms
            if kind == "A":
                if c.mla is not None:
                    m = c.mla
                    qk = m.qk_nope_dim + m.qk_rope_dim
                    n += d * m.q_lora_rank + m.q_lora_rank        # q down + norm
                    n += m.q_lora_rank * c.num_heads * qk          # q up
                    n += d * (m.kv_lora_rank + m.qk_rope_dim) + m.kv_lora_rank
                    n += m.kv_lora_rank * c.num_heads * (m.qk_nope_dim + m.v_dim)
                    n += c.num_heads * m.v_dim * d                 # o
                else:
                    n += d * c.num_heads * c.head_dim              # q
                    n += 2 * d * c.num_kv_heads * c.head_dim       # k, v
                    n += c.num_heads * c.head_dim * d              # o
                    if c.qk_norm:
                        n += 2 * c.head_dim
            elif kind == "M":
                s = c.ssm
                di, g = c.d_inner, s.n_groups * s.d_state
                n += d * (2 * di + 2 * g + self.ssm_heads)         # in_proj
                n += (s.d_conv + 1) * (di + 2 * g)                 # conv w+b
                n += self.ssm_heads * 3 + di                       # A,D,dt_bias,norm
                n += di * d                                        # out_proj
            elif kind == "X":
                n += d * c.num_heads * c.head_dim
                n += 2 * d * c.num_kv_heads * c.head_dim
                n += c.num_heads * c.head_dim * d
                n += 2                                             # gates
            # ffn
            if c.is_moe_layer(i):
                e = c.moe
                n += d * e.num_experts                             # router
                n += e.num_experts * 3 * d * e.d_expert
                n += e.num_shared * 3 * d * e.d_expert
            else:
                ff = c.dense_prefix_ff if (c.moe is not None and i < c.dense_prefix
                                           and c.dense_prefix_ff) else c.d_ff
                if kind != "M" or c.family == "hybrid":            # pure ssm has no ffn
                    if c.d_ff > 0 or (c.moe is not None):
                        n += (3 if c.mlp_act == "swiglu" else 2) * d * ff
        return n


# ---------------------------------------------------------------------------
# Input shapes (assignment sheet)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str                        # train | prefill | decode
    seq: int
    batch: int


SHAPES = {
    "train_4k":    ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k":  ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k":   ShapeSpec("long_500k", "decode", 524_288, 1),
}

# archs that may run long_500k (sub-quadratic sequence mixing)
SUBQUADRATIC_FAMILIES = ("ssm", "hybrid")


def cell_is_applicable(cfg: ModelConfig, shape: ShapeSpec) -> bool:
    if shape.name == "long_500k":
        return cfg.family in SUBQUADRATIC_FAMILIES
    return True


def input_specs(cfg: ModelConfig, shape: ShapeSpec):
    """ShapeDtypeStruct stand-ins for every input of this (arch, shape) cell.

    Returns a dict matching the kwargs of the corresponding step function.
    No device memory is allocated.
    """
    f = jnp.dtype(cfg.compute_dtype)
    i32 = jnp.int32
    B, S = shape.batch, shape.seq
    d = {}
    if shape.kind == "train":
        if cfg.input_mode == "embeddings":
            d["inputs"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), f)
        else:
            d["inputs"] = jax.ShapeDtypeStruct((B, S), i32)
        d["labels"] = jax.ShapeDtypeStruct((B, S), i32)
        if cfg.family == "vlm":
            d["image_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.num_image_tokens, cfg.d_model), f)
    elif shape.kind == "prefill":
        if cfg.input_mode == "embeddings":
            d["inputs"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), f)
        else:
            d["inputs"] = jax.ShapeDtypeStruct((B, S), i32)
        if cfg.family == "vlm":
            d["image_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.num_image_tokens, cfg.d_model), f)
    elif shape.kind == "decode":
        if cfg.input_mode == "embeddings":
            d["inputs"] = jax.ShapeDtypeStruct((B, 1, cfg.d_model), f)
        else:
            d["inputs"] = jax.ShapeDtypeStruct((B, 1), i32)
        d["positions"] = jax.ShapeDtypeStruct((B,), i32)
        if cfg.family == "vlm":
            d["image_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.num_image_tokens, cfg.d_model), f)
    else:
        raise ValueError(shape.kind)
    return d


def reduced_config(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    pat = cfg.layer_pattern
    changes = dict(
        num_layers=max(len(pat), 2 if len(pat) == 1 else len(pat)),
        d_model=64,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_kv_heads > 1 else 1,
        head_dim=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        dense_prefix=min(cfg.dense_prefix, 1),
        dense_prefix_ff=128 if cfg.dense_prefix_ff else 0,
        num_image_tokens=8 if cfg.num_image_tokens else 0,
        param_dtype="float32",
        compute_dtype="float32",
        flash_block=32,
    )
    if cfg.moe is not None:
        # capacity_factor high enough that smoke tests never drop tokens
        # (decode-vs-forward consistency needs lossless dispatch)
        changes["moe"] = dataclasses.replace(
            cfg.moe, num_experts=4, top_k=min(cfg.moe.top_k, 2), d_expert=64,
            capacity_factor=float(4 // min(cfg.moe.top_k, 2) + 3))
    if cfg.mla is not None:
        changes["mla"] = MLAConfig(q_lora_rank=32, kv_lora_rank=32,
                                   qk_nope_dim=16, qk_rope_dim=8, v_dim=16)
    if cfg.ssm is not None:
        changes["ssm"] = dataclasses.replace(
            cfg.ssm, d_state=16, head_dim=8, chunk=16)
    changes.update(overrides)
    return dataclasses.replace(cfg, **changes)
