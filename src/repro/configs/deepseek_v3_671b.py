"""DeepSeek-V3 671B: MLA, 1 shared + 256 routed experts top-8, fine-grained
(d_expert=2048).  61L d_model=7168 128H vocab=129280  [arXiv:2412.19437; hf]

First 3 layers are dense MLP (ff 18432) per the paper; the remaining 58 are
MoE.  KV cache stores the MLA latent (kv_lora 512 + rope 64 per token).
The MTP (multi-token prediction) auxiliary head is out of scope — the
param-count target (671.03B) is met by the backbone above.
"""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,          # nominal (MLA replaces GQA; kept for the sheet)
    head_dim=128,
    d_ff=2048,                 # routed expert hidden size (fine-grained)
    vocab_size=129280,
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_dim=128, qk_rope_dim=64, v_dim=128),
    moe=MoEConfig(num_experts=256, top_k=8, d_expert=2048, num_shared=1),
    dense_prefix=3,
    dense_prefix_ff=18432,
    source="arXiv:2412.19437; hf",
)
