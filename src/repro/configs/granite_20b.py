"""Granite-20B (code): dense llama-arch with MQA (kv=1). 52L d_model=6144
48H d_ff=24576 vocab=49152  [arXiv:2405.04324; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b",
    family="dense",
    num_layers=52,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,
    head_dim=128,
    d_ff=24576,
    vocab_size=49152,
    mlp_act="gelu",            # GPT-BigCode style non-gated MLP
    tie_embeddings=True,
    source="arXiv:2405.04324; hf",
)
