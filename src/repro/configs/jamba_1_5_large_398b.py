"""Jamba-1.5-Large (398B): Mamba+attention 1:7 interleave, MoE 16e top-2.

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536  [arXiv:2403.19887; hf]
Layer period of 8 with the self-attention mixer at position 4 (1 attn : 7
mamba), MoE replacing the MLP on every other layer.
"""
from repro.configs.base import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    layer_pattern="MMMMAMMM",
    moe=MoEConfig(num_experts=16, top_k=2, d_expert=24576, every_k_layers=2),
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, n_groups=8),
    source="arXiv:2403.19887; hf",
)
