"""Llama-3.2-Vision-11B: text decoder with gated cross-attention image
layers. 40L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]

The vision encoder is a stub: input_specs() provides precomputed patch
embeddings (B, 1600, d_model).  One gated cross-attention layer is
interleaved every 5 layers (period 'AAAAX' -> 32 self + 8 cross).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128256,
    layer_pattern="AAAAX",
    num_image_tokens=1600,
    rope_theta=500_000.0,
    source="hf:meta-llama/Llama-3.2-11B-Vision; unverified",
)
