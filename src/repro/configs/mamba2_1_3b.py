"""Mamba2-1.3B: attention-free SSD (state-space duality). 48L d_model=2048
d_ff=0 vocab=50280 ssm_state=128  [arXiv:2405.21060; unverified]"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=1,               # unused by ssm mixer
    num_kv_heads=1,
    head_dim=64,
    d_ff=0,                    # no FFN in mamba2 blocks
    vocab_size=50280,
    tie_embeddings=True,
    layer_pattern="M",
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, n_groups=1),
    source="arXiv:2405.21060; unverified",
)
