"""MusicGen-Large: decoder-only over EnCodec tokens. 48L d_model=2048 32H
(kv=32) d_ff=8192 vocab=2048  [arXiv:2306.05284; hf]

Backbone only — the EnCodec frontend is a stub: input_specs() provides
precomputed frame embeddings of width d_model (the sum of the four
codebook embeddings after the delay pattern), per the assignment sheet.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    input_mode="embeddings",
    source="arXiv:2306.05284; hf",
)
