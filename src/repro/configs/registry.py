"""Registry mapping --arch ids to ModelConfigs."""
from __future__ import annotations

import importlib

_ARCH_MODULES = {
    "jamba-1.5-large-398b": "repro.configs.jamba_1_5_large_398b",
    "deepseek-7b": "repro.configs.deepseek_7b",
    "command-r-35b": "repro.configs.command_r_35b",
    "qwen3-4b": "repro.configs.qwen3_4b",
    "granite-20b": "repro.configs.granite_20b",
    "dbrx-132b": "repro.configs.dbrx_132b",
    "deepseek-v3-671b": "repro.configs.deepseek_v3_671b",
    "musicgen-large": "repro.configs.musicgen_large",
    "llama-3.2-vision-11b": "repro.configs.llama_3_2_vision_11b",
    "mamba2-1.3b": "repro.configs.mamba2_1_3b",
}

# extra (not part of the assigned pool): e2e training example config
_EXTRA_MODULES = {
    "repro-100m": "repro.configs.repro_100m",
}

ARCH_IDS = tuple(_ARCH_MODULES)                 # the assigned pool
ALL_IDS = ARCH_IDS + tuple(_EXTRA_MODULES)
_ARCH_MODULES = {**_ARCH_MODULES, **_EXTRA_MODULES}


def get_config(arch_id: str):
    if arch_id not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_ARCH_MODULES)}")
    return importlib.import_module(_ARCH_MODULES[arch_id]).CONFIG


def all_configs():
    return {a: get_config(a) for a in ARCH_IDS}
