"""repro-100m: ~130M-parameter dense decoder for the end-to-end training
driver (llama-style, qwen3-family reduced). CPU-runnable."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="repro-100m",
    family="dense",
    num_layers=10,
    d_model=640,
    num_heads=10,
    num_kv_heads=5,
    head_dim=64,
    d_ff=2560,
    vocab_size=50304,
    qk_norm=True,
    tie_embeddings=True,
    param_dtype="float32",
    compute_dtype="float32",
    flash_block=512,
    source="in-repo (training example)",
)
