"""Approximate Pattern Count Table (paper §4.2).

Dataset profiling: random-edge-sample the input graph down to E' edges,
then estimate the count of every connected pattern up to 5 vertices with
ASAP-style neighbour sampling (Fig 21, generalised to arbitrary patterns
by sampling a BFS spanning tree and checking the non-tree edges).  The
estimator is unbiased for injective-tuple counts; frequent patterns
converge fast, infrequent ones are under-estimated — which is exactly the
property the cost model needs (frequent subpatterns are the expensive
contractions).

Misses are computed on demand and inserted (paper: "generated during cost
estimation").
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.motifs import motif_patterns
from repro.core.pattern import Pattern
from repro.graph.storage import Graph


def _bfs_tree(p: Pattern):
    """(order, parent-in-order index) with each vertex adjacent to an
    earlier one; pattern must be connected."""
    a = p.adj()
    order = [0]
    parent = {0: -1}
    while len(order) < p.n:
        for v in range(p.n):
            if v in parent:
                continue
            ns = [u for u in a[v] if u in parent]
            if ns:
                order.append(v)
                parent[v] = ns[0]
                break
    return order, parent


def estimate_inj(g: Graph, p: Pattern, num_samples: int = 32_768,
                 seed: int = 0) -> float:
    """Unbiased estimate of injective-tuple count of p in g (vectorised
    neighbour sampling)."""
    if g.m == 0 or p.n > g.n:
        return 0.0
    rng = np.random.default_rng(seed)
    offs, nbrs = g.csr
    deg = np.diff(offs)
    order, parent = _bfs_tree(p)

    S = num_samples
    verts = np.zeros((p.n, S), np.int64)
    weight = np.full(S, float(g.n))
    valid = np.ones(S, bool)

    verts[order[0]] = rng.integers(0, g.n, S)
    for v in order[1:]:
        par = verts[parent[v]]
        d = deg[par]
        ok = d > 0
        valid &= ok
        d_safe = np.maximum(d, 1)
        pick = (rng.random(S) * d_safe).astype(np.int64)
        verts[v] = nbrs[np.minimum(offs[par] + pick, len(nbrs) - 1)]
        weight *= d_safe
    # injectivity
    for i in range(p.n):
        for j in range(i + 1, p.n):
            valid &= verts[i] != verts[j]
    # non-tree edges
    tree = {(min(v, parent[v]), max(v, parent[v])) for v in order[1:]}
    for (u, v) in p.edges - tree:
        a, b = verts[u], verts[v]
        lo, hi = offs[a], offs[a + 1]
        # vectorised membership: searchsorted within each row
        pos = np.array([np.searchsorted(nbrs[l:h], x)
                        for l, h, x in zip(lo, hi, b)])
        found = (lo + pos < hi) & (nbrs[np.minimum(lo + pos, len(nbrs) - 1)] == b)
        valid &= found
    # labels
    if g.labels is not None and p.labels is not None:
        for v in range(p.n):
            valid &= g.labels[verts[v]] == np.array(p.labels[v])
    return float(np.sum(weight * valid) / S)


class APCT:
    """The table: canonical pattern -> approximate injective-tuple count."""

    def __init__(self, graph: Graph, max_profile_edges: int = 100_000,
                 num_samples: int = 32_768, max_size: int = 5, seed: int = 0):
        self.num_samples = num_samples
        self.seed = seed
        self.profile_graph = graph.subgraph_sample_edges(max_profile_edges,
                                                         seed=seed)
        self.table: dict = {}
        self.misses = 0
        t0 = time.perf_counter()
        for k in range(2, max_size + 1):
            for p in motif_patterns(k):
                self.table[p] = estimate_inj(self.profile_graph, p,
                                             num_samples, seed)
        self.profile_time_s = time.perf_counter() - t0

    def query(self, p: Pattern) -> float:
        c = p.canonical()
        # labelled queries fall back to the unlabelled skeleton (the paper
        # searches decompositions on the unlabelled version, footnote 6)
        if c.labels is not None:
            c = Pattern(c.n, c.edges).canonical()
        if c not in self.table:
            self.misses += 1
            self.table[c] = estimate_inj(self.profile_graph, c,
                                         self.num_samples, self.seed)
        return self.table[c]
