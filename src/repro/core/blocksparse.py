"""Block-sparse adjacency counting: the scalable mining backend.

Real graphs are sparse but locally dense; tiling the adjacency into
TILE x TILE blocks and keeping only non-empty tiles gives the MXU dense
work at the tile level while skipping the (vast) empty majority — the
tensorised analogue of the paper's observation that enumeration cost
follows pattern/graph structure, not n^k.

``BlockSparseAdjacency`` stores the non-empty tiles of A; the counting
kernels below (triangle / wedge-closing) iterate only over non-empty
tile triples, and each tile-level product is exactly the Pallas
``sddmm``/``matreduce`` computation (kernels/), so the same BlockSpecs
apply on TPU.  Occupancy statistics quantify the skipped work.
"""
from __future__ import annotations

import numpy as np

from repro.graph.storage import Graph

TILE = 128


class BlockSparseAdjacency:
    def __init__(self, g: Graph, tile: int = TILE):
        self.tile = tile
        self.n = g.n
        self.nb = (g.n + tile - 1) // tile
        blocks: dict = {}
        for u, v in g.edges:
            for (a, b) in ((u, v), (v, u)):
                key = (int(a) // tile, int(b) // tile)
                blocks.setdefault(key, []).append((int(a) % tile,
                                                   int(b) % tile))
        self.blocks = {}
        for key, entries in blocks.items():
            t = np.zeros((tile, tile), np.float32)
            rr, cc = zip(*entries)
            t[list(rr), list(cc)] = 1.0
            self.blocks[key] = t
        # row index: non-empty block columns per block row
        self.row_blocks: dict = {}
        for (i, j) in self.blocks:
            self.row_blocks.setdefault(i, []).append(j)
        for i in self.row_blocks:
            self.row_blocks[i].sort()

    @property
    def occupancy(self) -> float:
        return len(self.blocks) / float(self.nb * self.nb)

    def stats(self) -> dict:
        nnz = sum(int(t.sum()) for t in self.blocks.values())
        return {"tiles": len(self.blocks), "grid": self.nb * self.nb,
                "occupancy": self.occupancy, "nnz": nnz,
                "tile_density": nnz / (len(self.blocks) * self.tile ** 2)}


def triangle_count_blocksparse(bsa: BlockSparseAdjacency,
                               use_kernel: bool = False) -> float:
    """Σ A ⊙ (A @ A) / 6 over non-empty tile triples only.

    For each non-empty output tile (i,j), accumulate A[i,k] @ A[k,j] over
    k where BOTH factor tiles exist, then mask with A[i,j] and reduce —
    per-tile this is exactly kernels/matreduce (use_kernel=True routes
    through the Pallas op in interpret mode for validation).
    """
    total = 0.0
    for (i, j), mask in bsa.blocks.items():
        ks = [k for k in bsa.row_blocks.get(i, [])
              if (k, j) in bsa.blocks]
        if not ks:
            continue
        acc = np.zeros_like(mask)
        for k in ks:
            acc += bsa.blocks[(i, k)] @ bsa.blocks[(k, j)]
        if use_kernel:
            from repro.kernels import ops
            import jax.numpy as jnp
            # one fused tile op (stacked factors as a single K dim)
            lhs = np.concatenate([bsa.blocks[(i, k)] for k in ks], axis=1)
            rhs = np.concatenate([bsa.blocks[(k, j)].T for k in ks], axis=1)
            total += float(ops.masked_matmul_reduce(
                jnp.asarray(lhs), jnp.asarray(rhs), jnp.asarray(mask),
                interpret=True))
        else:
            total += float((acc * mask).sum())
    return total / 6.0


def wedge_count_blocksparse(bsa: BlockSparseAdjacency) -> float:
    """# 3-chains (edge-induced) = Σ_v deg(v)·(deg(v)-1)/2 computed from
    tile row sums — validates the block structure end-to-end."""
    deg = np.zeros(bsa.n)
    for (i, j), t in bsa.blocks.items():
        rows = t.sum(axis=1)
        lo = i * bsa.tile
        hi = min(lo + bsa.tile, bsa.n)
        deg[lo:hi] += rows[:hi - lo]
    return float((deg * (deg - 1) / 2).sum())


def dense_flops(n: int) -> float:
    return 2.0 * n ** 3


def blocksparse_flops(bsa: BlockSparseAdjacency) -> float:
    f = 0.0
    t = bsa.tile
    for (i, j) in bsa.blocks:
        ks = [k for k in bsa.row_blocks.get(i, []) if (k, j) in bsa.blocks]
        f += 2.0 * len(ks) * t ** 3
    return f
