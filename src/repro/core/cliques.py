"""Specialised clique counting (degeneracy-ordered enumeration).

Dense tensor contraction of K_k needs an N^(k-2) intermediate — exactly
the high-treewidth regime the paper's decomposition cannot help with
(cliques have no cutting set, §2.4 footnote).  The paper's observation is
that clique counting is cheap by *ordered enumeration*; we implement that
path on the host CSR (degeneracy order + out-neighbour intersections) and
route complete patterns to it.  Also provides the pseudo-clique counter
(K_k minus one edge, vertex-induced) used by the PC application.
"""
from __future__ import annotations

import numpy as np

from repro.graph.storage import Graph


def degeneracy_order(g: Graph) -> np.ndarray:
    offs, nbrs = g.csr
    deg = np.diff(offs).astype(np.int64)
    removed = np.zeros(g.n, bool)
    order = np.empty(g.n, np.int64)
    # simple bucketed peeling
    for i in range(g.n):
        v = int(np.argmin(np.where(removed, np.iinfo(np.int64).max, deg)))
        order[i] = v
        removed[v] = True
        for w in nbrs[offs[v]:offs[v + 1]]:
            if not removed[w]:
                deg[w] -= 1
    return order


def _oriented_adj(g: Graph, order: np.ndarray) -> list:
    rank = np.empty(g.n, np.int64)
    rank[order] = np.arange(g.n)
    out = [None] * g.n
    offs, nbrs = g.csr
    for v in range(g.n):
        ns = nbrs[offs[v]:offs[v + 1]]
        fwd = ns[rank[ns] > rank[v]]
        out[v] = np.sort(fwd)
    return out


def clique_count(g: Graph, k: int) -> int:
    """Number of k-cliques (vertex subsets)."""
    if k == 1:
        return g.n
    if k == 2:
        return g.m
    adj = _oriented_adj(g, degeneracy_order(g))

    def rec(cands: np.ndarray, depth: int) -> int:
        if depth == k:
            return len(cands)
        total = 0
        for v in cands:
            nxt = np.intersect1d(cands, adj[v], assume_unique=True)
            if len(nxt) >= k - depth - 1:
                total += rec(nxt, depth + 1)
        return total

    total = 0
    for v in range(g.n):
        if len(adj[v]) >= k - 1:
            total += rec(adj[v], 2)
    return total


def clique_minus_edge_count(g: Graph, k: int) -> int:
    """Vertex-induced count of K_k minus one edge: non-adjacent pairs
    (u,v) whose common neighbourhood contains a (k-2)-clique fully
    adjacent to both — i.e. cliques of size k-2 in the induced common
    neighbourhood."""
    assert k >= 3
    offs, nbrs = g.csr
    # candidate non-adjacent pairs with >= k-2 common neighbours: collect
    # from wedges
    pair_count: dict = {}
    for w in range(g.n):
        ns = nbrs[offs[w]:offs[w + 1]]
        if len(ns) < 2:
            continue
        for i in range(len(ns)):
            u = ns[i]
            for v in ns[i + 1:]:
                pair_count[(u, v)] = pair_count.get((u, v), 0) + 1
    total = 0
    for (u, v), c in pair_count.items():
        if c < k - 2 or g.has_edge(u, v):
            continue
        common = np.intersect1d(g.neighbors(u), g.neighbors(v),
                                assume_unique=True)
        sub = _induced(g, common)
        total += clique_count(sub, k - 2)
    return total


def pseudo_clique_count(g: Graph, k: int) -> int:
    """Vertex-induced pseudo-cliques with parameter 1 (paper's PC app):
    K_k plus K_k-minus-one-edge."""
    return clique_count(g, k) + clique_minus_edge_count(g, k)


def _induced(g: Graph, verts: np.ndarray) -> Graph:
    idx = {int(v): i for i, v in enumerate(verts)}
    edges = []
    vset = set(idx)
    for v in verts:
        for w in g.neighbors(int(v)):
            if int(w) in vset and int(w) > int(v):
                edges.append((idx[int(v)], idx[int(w)]))
    return Graph(len(verts), np.asarray(edges).reshape(-1, 2)
                 if edges else np.zeros((0, 2), np.int64))
