"""Cost models for decomposition plans.

DwarvesGraph model (paper §4.2): every elimination step's intermediate has
one nonzero per match of the subpattern processed so far, so its cost is
the (approximate) count of that subpattern, queried from the APCT —
"every loop iteration corresponds to a match of a subpattern".  A small
dense-tile floor term models the MXU's structural minimum.

The application-level cost accounts for cross-pattern computation reuse:
quotient contractions are shared by canonical form across all concrete
patterns, so the cost of a joint cutting-set assignment is summed over
*unique* (quotient, plan) pairs — the reason the paper searches the joint
space (§4.3).

The AutoMine baseline model (random graph, edge probability p = d/n) is
included for the Fig 22 comparison.
"""
from __future__ import annotations

from repro.core import homomorphism as H
from repro.core.pattern import Pattern
from repro.core.quotient import quotient_terms

DENSE_TILE = 128


def plan_cost_apct(p: Pattern, order, apct, n_vertices: int,
                   tile: int = DENSE_TILE) -> float:
    """Cost of one hom contraction under the APCT model."""
    steps = H.frontier_sizes(p, order)
    total = 0.0
    done = set()
    for v, front in steps:
        done |= front
        sub = p.induced(sorted(done))
        # count-bound term: matches of the processed subpattern
        cnt = apct.query(sub) if sub.is_connected() else _disc(apct, p, done)
        # dense floor: tiles of the intermediate
        floor = (max(n_vertices, tile) / tile) ** len(front)
        total += cnt + floor
    return total


def _disc(apct, p: Pattern, done: set) -> float:
    """Disconnected processed subpattern: product over components."""
    sub = p.induced(sorted(done))
    out = 1.0
    seen = set()
    for comp in sub.components_without(frozenset()):
        out *= max(apct.query(sub.induced(sorted(comp))), 1.0)
        seen |= comp
    return out


def pattern_cost(p: Pattern, cut, apct, n_vertices: int,
                 shared: dict | None = None) -> float:
    """Cost of counting inj(p) with the given cutting set (None = direct).

    ``shared``: canonical-quotient -> cost memo; pass one dict across all
    patterns of an application to model computation reuse (costs of already
    -scheduled quotients are not paid again).
    """
    total = 0.0
    for coeff, q in quotient_terms(p):
        order = (H.plan_from_cut(q, _cut_image(p, cut, q))
                 if cut else H.greedy_plan(q))
        cost = plan_cost_apct(q, order, apct, n_vertices)
        if shared is not None:
            if q in shared:                       # already scheduled: reuse
                cost = 0.0
            else:
                shared[q] = cost
        total += cost
    return total


def _cut_image(p: Pattern, cut, q: Pattern):
    """Approximate separator for a quotient: vertices of q with degree
    >= the min cut-vertex degree is fragile, so we simply reuse any valid
    cutting set of q of the same size (quotients of a decomposable pattern
    are typically decomposable with the shrunken cut); fallback greedy."""
    from repro.core.decomposition import cutting_sets
    for c in cutting_sets(q):
        if len(c) <= len(cut):
            return c
    return frozenset()


def application_cost(patterns_with_cuts, apct, n_vertices: int) -> float:
    """Joint cost of an application: Σ over unique quotient contractions."""
    shared: dict = {}
    total = 0.0
    for p, cut in patterns_with_cuts:
        total += pattern_cost(p, cut, apct, n_vertices, shared=shared)
    return total


# -- AutoMine baseline model (Fig 22) -------------------------------------------

def plan_cost_automine(p: Pattern, order, n: int, avg_degree: float) -> float:
    """Random-graph trip-count model: every vertex pair connected with
    probability pr = d/n; loop i trip count = n * pr^{#back edges}."""
    pr = min(avg_degree / max(n, 1), 1.0)
    steps = H.frontier_sizes(p, order)
    total, trips = 0.0, 1.0
    done = set()
    for v, front in steps:
        back = len(front) - 1
        trips *= n * (pr ** back)
        total += trips
        done |= front
    return total


def pattern_cost_automine(p: Pattern, cut, n: int, avg_degree: float) -> float:
    total = 0.0
    for coeff, q in quotient_terms(p):
        order = (H.plan_from_cut(q, _cut_image(p, cut, q))
                 if cut else H.greedy_plan(q))
        total += plan_cost_automine(q, order, n, avg_degree)
    return total
