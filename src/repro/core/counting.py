"""Exact pattern counting: hom -> injective -> edge/vertex-induced.

The engine memoises homomorphism counts by canonical pattern — the
tensorised form of the paper's cross-pattern computation reuse: all
concrete patterns of an application (e.g. the 112 6-motifs) draw from one
shared pool of quotient hom contractions.

Counts run in f64 (jax.experimental.enable_x64 scoped locally) — exact up
to 2^53, enough for trillion-scale embedding counts.
"""
from __future__ import annotations

import math
from functools import lru_cache

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import homomorphism as H
from repro.core.motifs import motif_patterns
from repro.core.pattern import Pattern, free_skeleton, mark_free
from repro.core.quotient import mobius, partitions, quotient_terms
from repro.graph.storage import Graph


def _quotient_order(q: Pattern, cut_blocks: frozenset | None):
    if not cut_blocks:
        return H.greedy_plan(q)
    return H.plan_from_cut(q, frozenset(cut_blocks)) \
        if q.components_without(frozenset(cut_blocks)) else H.greedy_plan(q)


class CountingEngine:
    """Tensorised counting over one input graph."""

    def __init__(self, graph: Graph, budget: int = 1 << 27,
                 use_x64: bool = True, mesh=None):
        self.graph = graph
        self.budget = budget
        self.use_x64 = use_x64
        self._x64 = jax.experimental.enable_x64 if use_x64 else _nullctx
        self._np_dtype = np.float64 if use_x64 else np.float32
        # sharded-contraction binding: a 1-D ("data",) mesh routes hom /
        # hom_free_tensor through ``distributed.contract`` (row-sharded
        # adjacency, collective einsums — bit-for-bit with the
        # single-device path).  None, a trivial mesh, or a graph smaller
        # than the mesh keeps every contraction single-device.
        self.mesh = None
        if mesh is not None:
            from repro.distributed import meshes as _meshes
            d = _meshes.num_shards(mesh)
            if d > 1 and graph.n >= d:
                self.mesh = mesh
        # dense adjacency / label indicators build lazily: the sharded
        # route must never materialise the n x n array (tests assert
        # ``_A_dense is None`` after sharded counting), and the sharded
        # buffers are only built when a mesh actually routes to them
        self._A_dense = None
        self._labels_dense = None
        self._A_blocks = None
        self._label_rows = None
        self.hom_memo: dict = {}
        self.hom_free_memo: dict = {}
        self.domain_memo: dict = {}
        self.stats = {"hom_evals": 0, "hom_hits": 0}

    @property
    def A(self):
        """Dense (n, n) adjacency on one device — lazy, so plans whose
        contractions all run sharded (or clique-enumerated) never pay
        for or hold it."""
        if self._A_dense is None:
            with self._x64():
                self._A_dense = jnp.asarray(
                    self.graph.dense_adjacency(self._np_dtype, pad=False))
        return self._A_dense

    @property
    def labels(self):
        """(num_labels, n) one-hot indicators on one device — lazy, as
        ``A``; None on an unlabelled graph."""
        if self.graph.labels is None:
            return None
        if self._labels_dense is None:
            with self._x64():
                self._labels_dense = jnp.asarray(
                    self.graph.label_indicators(self._np_dtype, pad=False))
        return self._labels_dense

    # -- sharded-contraction route --------------------------------------------
    def contract_shards(self) -> int:
        """Shard count of the contraction route (1 = single-device) —
        lowering annotates Contract evals with it."""
        if self.mesh is None:
            return 1
        from repro.distributed import meshes as _meshes
        return _meshes.num_shards(self.mesh)

    def _blocks(self):
        if self._A_blocks is None:
            from repro.distributed import contract as C
            with self._x64():
                self._A_blocks = C.adjacency_blocks(self.graph, self.mesh,
                                                    self._np_dtype)
        return self._A_blocks

    def _unary_blocks(self, p: Pattern):
        """Sharded analogue of ``_unary_for``: label-indicator rows
        column-sharded over the mesh, same alphabet-binding semantics."""
        if p.labels is None or self.graph.labels is None:
            return None
        from repro.distributed import contract as C
        with self._x64():
            if self._label_rows is None:
                self._label_rows = C.label_blocks(self.graph, self.mesh,
                                                  self._np_dtype)
            L = self._label_rows.shape[0]
            zero = jnp.zeros_like(self._label_rows[0])
            return {v: (self._label_rows[l] if 0 <= l < L else zero)
                    for v, l in enumerate(p.labels)}

    # -- memo peeks (costing reads these to zero-cost materialised work) -------
    def has_hom(self, p: Pattern) -> bool:
        """True when ``hom(p)`` is already memoised (no evaluation)."""
        return p.canonical() in self.hom_memo

    def has_free_tensor(self, p: Pattern, free: tuple) -> bool:
        """True when the ``(pattern, free)``-keyed free-hom tensor is
        already materialised — the compiler's costing stage treats such
        ``Contract`` nodes as zero-cost (shared across cut choices and
        across compiles that reuse this engine)."""
        return (p, tuple(free)) in self.hom_free_memo

    # -- hom ------------------------------------------------------------------
    def _unary_for(self, p: Pattern):
        """Per-vertex label-indicator factors binding a labelled pattern
        to this graph's label alphabet.  A pattern label outside the
        alphabet binds to the zero vector (no such vertices => count 0),
        so one compiled plan serves any graph whose alphabet covers —
        or merely overlaps — the pattern's.  An unlabelled graph ignores
        pattern labels (wildcard semantics, matching the brute-force
        reference)."""
        if p.labels is None or self.labels is None:
            return None
        L = self.labels.shape[0]
        zero = jnp.zeros_like(self.labels[0])
        return {v: (self.labels[l] if 0 <= l < L else zero)
                for v, l in enumerate(p.labels)}

    def hom(self, p: Pattern, order=None) -> float:
        c = p.canonical()
        if c in self.hom_memo:
            self.stats["hom_hits"] += 1
            return self.hom_memo[c]
        self.stats["hom_evals"] += 1
        if c.labels is None and c.m == c.n * (c.n - 1) // 2 and c.n >= 3:
            # complete pattern: no cutting set exists (paper §2.4) and the
            # dense contraction needs an N^(k-2) intermediate — route to
            # ordered enumeration.  hom(K_k) = k! * #cliques.
            import math
            from repro.core.cliques import clique_count
            val = float(math.factorial(c.n) * clique_count(self.graph, c.n))
        elif self.mesh is not None:
            from repro.distributed import contract as C
            with self._x64():
                val = float(C.sharded_hom(c, self._blocks(),
                                          mesh=self.mesh, n=self.graph.n,
                                          order=order,
                                          unary=self._unary_blocks(c),
                                          budget=self.budget))
        else:
            with self._x64():
                val = float(H.hom_count(c, self.A, order=order,
                                        unary=self._unary_for(c),
                                        budget=self.budget))
        self.hom_memo[c] = val
        return val

    def hom_free_tensor(self, p: Pattern, free: tuple,
                        order=None) -> np.ndarray:
        """hom(p) with ``free`` pattern vertices kept as output axes —
        a (N,)*len(free) tensor over graph vertices.  The compiler's
        ``Contract`` primitive for decomposition joins (per-subpattern
        extension counts as a function of the cut tuple).  Memoised by
        (pattern, free) in caller-canonical form.

        Under a mesh the contraction runs sharded (``distributed.
        contract``) and the result is a jax Array sliced ``P("data",
        ...)`` over cut axis 0 — exactly the layout the sharded join
        tier consumes, handed off without a gather; ``np.asarray`` still
        works for host consumers.  Values are bit-for-bit identical to
        the single-device route either way."""
        key = (p, tuple(free))
        if key in self.hom_free_memo:
            self.stats["hom_hits"] += 1
            return self.hom_free_memo[key]
        self.stats["hom_evals"] += 1
        if self.mesh is not None:
            from repro.distributed import contract as C
            with self._x64():
                val = C.sharded_hom(p, self._blocks(), mesh=self.mesh,
                                    n=self.graph.n,
                                    order=tuple(order) if order else None,
                                    free=tuple(free),
                                    unary=self._unary_blocks(p),
                                    budget=self.budget)
        else:
            with self._x64():
                val = np.asarray(H.hom_count(
                    p, self.A, order=tuple(order) if order else None,
                    free=tuple(free), unary=self._unary_for(p),
                    budget=self.budget))
        self.hom_free_memo[key] = val
        return val

    # -- injective tuples / embeddings ----------------------------------------
    def inj(self, p: Pattern, cut=None) -> float:
        """# injective edge-preserving maps (ordered tuples).  ``cut``
        selects the decomposition: quotient contractions eliminate the image
        of the cutting set last (the separator)."""
        total = 0.0
        for coeff, q in quotient_terms(p):
            order = None
            if cut:
                # image of the cut under some quotient map: recompute per
                # quotient via a fresh partition walk is costly; the greedy
                # fallback is used when the cut does not survive.
                order = H.greedy_plan(q)
            total += coeff * self.hom(q, order=order)
        return total

    def edge_induced(self, p: Pattern, cut=None) -> float:
        """# edge-induced embeddings = inj / |Aut| (the paper's
        multiplicity M)."""
        return self.inj(p, cut=cut) / p.aut_order()

    def inj_free(self, p: Pattern, v: int) -> np.ndarray:
        """Vector over graph vertices u: # injective maps with v -> u
        (pattern-vertex domains for FSM MINI support)."""
        return self.inj_free_all(p)[v]

    def inj_free_all(self, p: Pattern) -> np.ndarray:
        """All FSM MINI domains of one pattern as a (p.n, N) matrix: row
        v counts injective maps with v -> u.  One partition walk covers
        every vertex (the old path re-walked per vertex), evaluating one
        free-hom tensor per distinct (quotient, block); each tensor is
        canonicalised (``mark_free``) into the ``hom_free_memo``, so
        vertices sharing a block, symmetric vertices, and sibling
        patterns sharing quotients all reuse the same contraction.  The
        finished matrix memoises per pattern, so per-vertex ``inj_free``
        loops pay the partition walk once."""
        if p in self.domain_memo:
            return self.domain_memo[p]
        n = self.graph.n
        dom = np.zeros((p.n, n))
        for sigma in partitions(tuple(range(p.n))):
            q, blk = p.quotient_with_map(sigma)
            if q is None:
                continue
            mu = mobius(sigma)
            vecs = {}
            for b in set(blk.values()):
                _, qc, free_c = mark_free(q, (b,))
                vecs[b] = self.hom_free_tensor(
                    free_skeleton(qc), free_c,
                    order=H.greedy_plan(qc, free_c))
            for v in range(p.n):
                dom[v] += mu * vecs[blk[v]]
        dom.setflags(write=False)          # shared memo: no silent writes
        self.domain_memo[p] = dom
        return dom

    def vind_inj_oracle(self, p: Pattern) -> float:
        """Vertex-induced injective tuples via complement factors: edges
        must map to edges AND non-edges to non-edges.  Zero-diagonal
        factors enforce injectivity automatically.  Exponential in pattern
        size — test oracle only."""
        with self._x64():
            comp = (1.0 - self.A) - jnp.eye(self.A.shape[0], dtype=self.A.dtype)
            et = {}
            full = []
            for i in range(p.n):
                for j in range(i + 1, p.n):
                    full.append((i, j))
                    if not p.has_edge(i, j):
                        et[(i, j)] = comp
            pfull = Pattern(p.n, full, p.labels)
            val = H.hom_count(pfull, self.A, edge_tensors=et,
                              unary=self._unary_for(p), budget=self.budget)
        return float(val)

    def vertex_induced(self, p: Pattern) -> float:
        """Vertex-induced embedding count via the same-size overlay
        transform over edge-induced counts (paper §2.1)."""
        k = p.n
        pats = motif_patterns(k)
        e = {q: self.edge_induced(q) for q in pats}
        v = solve_overlay(k, e)
        return v[p.canonical()]

    def motif_table(self, k: int, cuts=None) -> dict:
        """Vertex-induced counts of every connected k-pattern (k-MC)."""
        pats = motif_patterns(k)
        e = {}
        for q in pats:
            cut = cuts.get(q) if cuts else None
            e[q] = self.edge_induced(q, cut=cut)
        return solve_overlay(k, e)

    def existence(self, p: Pattern) -> bool:
        return self.inj(p) > 0.5


class _nullctx:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False


# -- overlay transform ----------------------------------------------------------

@lru_cache(maxsize=16)
def overlay_matrix(k: int):
    """S[i][j] = # vertex permutations mapping E(P_i) into E(P_j), for the
    connected k-patterns.  edge_induced[i] = Σ_j S[i][j]/|Aut(P_i)| · vind[j].
    """
    import itertools
    pats = motif_patterns(k)
    adj = []
    for p in pats:
        bits = [0] * k
        for u, v in p.edges:
            bits[u] |= 1 << v
            bits[v] |= 1 << u
        adj.append(bits)
    S = np.zeros((len(pats), len(pats)), np.int64)
    for i, p in enumerate(pats):
        edges = sorted(p.edges)
        for j, q in enumerate(pats):
            if q.m < p.m:
                continue
            bj = adj[j]
            cnt = 0
            for perm in itertools.permutations(range(k)):
                ok = True
                for u, v in edges:
                    if not (bj[perm[u]] >> perm[v]) & 1:
                        ok = False
                        break
                if ok:
                    cnt += 1
            S[i, j] = cnt
    auts = np.array([p.aut_order() for p in pats], np.int64)
    return pats, S, auts


def solve_overlay(k: int, edge_counts: dict) -> dict:
    """Solve vind from edge-induced counts by back-substitution in
    descending edge count (S is triangular in that order)."""
    pats, S, auts = overlay_matrix(k)
    idx = {p: i for i, p in enumerate(pats)}
    order = sorted(range(len(pats)), key=lambda i: -pats[i].m)
    v = np.zeros(len(pats))
    e = np.array([edge_counts[p] for p in pats], float)
    for i in order:
        acc = e[i]
        for j in range(len(pats)):
            if j != i and S[i, j]:
                acc -= (S[i, j] / auts[i]) * v[j]
        v[i] = acc / (S[i, i] / auts[i])
    return {pats[i]: v[i] for i in range(len(pats))}


# -- brute-force reference (host) ------------------------------------------------

def brute_force_edge_induced(g: Graph, p: Pattern) -> int:
    """Nested-loop reference counter (the 'AutoMine' ground truth for
    tests).  Exponential; small graphs only."""
    adj = [set(g.neighbors(v)) for v in range(g.n)]
    order = H.greedy_plan(p)[::-1]                      # connected-first order
    order = _connected_order(p)
    pos = {v: i for i, v in enumerate(order)}
    count = 0
    assign = [None] * p.n

    def rec(i):
        nonlocal count
        if i == len(order):
            count += 1
            return
        v = order[i]
        back = [u for u in range(p.n) if p.has_edge(u, v) and pos[u] < i]
        lab_ok = (lambda x: g.labels is None or p.labels is None
                  or g.labels[x] == p.labels[v])
        if back:
            cands = set(adj[assign[back[0]]])
            for u in back[1:]:
                cands &= adj[assign[u]]
        else:
            cands = range(g.n)
        used = set(assign[order[j]] for j in range(i))
        for x in cands:
            if x in used or not lab_ok(x):
                continue
            assign[v] = x
            rec(i + 1)
            assign[v] = None

    rec(0)
    return count // p.aut_order()


def _connected_order(p: Pattern) -> list:
    a = p.adj()
    order = [0]
    seen = {0}
    while len(order) < p.n:
        nxt = [v for v in range(p.n) if v not in seen
               and any(u in seen for u in a[v])]
        if not nxt:
            nxt = [v for v in range(p.n) if v not in seen]
        order.append(nxt[0])
        seen.add(nxt[0])
    return order


def brute_force_vertex_induced(g: Graph, p: Pattern) -> int:
    """Vertex-induced reference via itertools over vertex subsets."""
    import itertools
    cnt = 0
    target = p.canonical()
    for vs in itertools.combinations(range(g.n), p.n):
        sub = [(a, b) for a, b in itertools.combinations(vs, 2)
               if g.has_edge(a, b)]
        idx = {v: i for i, v in enumerate(vs)}
        lab = (tuple(g.labels[v] for v in vs)
               if g.labels is not None and p.labels is not None else None)
        q = Pattern(p.n, [(idx[a], idx[b]) for a, b in sub], lab)
        if q.m == target.m and q.canonical() == target:
            cnt += 1
    return cnt
