"""Cutting-set enumeration and subpattern extraction (paper §2.4).

A decomposition of pattern p is a vertex cutting set V_C whose removal
splits p into K >= 2 connected components; each component union V_C is a
subpattern.  Cliques have no cutting set — the engine falls back to the
direct (no-decomposition) plan, exactly the paper's fallback behaviour.

Labels ride along: ``subpatterns`` extracts induced subpatterns with
their vertex labels intact, while cutting sets themselves are a purely
structural property, so labelled variants share one enumeration over
the unlabelled skeleton.
"""
from __future__ import annotations

import itertools
from functools import lru_cache

from repro.core.pattern import Pattern


@lru_cache(maxsize=50_000)
def cutting_sets(p: Pattern) -> tuple:
    """All cutting sets (frozensets) of p, smallest first.  O(2^n) subsets,
    fine for pattern-sized graphs.  Cutting sets depend only on the edge
    structure, so every labelled variant of one skeleton shares a single
    cached enumeration."""
    if p.labels is not None:
        return cutting_sets(Pattern(p.n, p.edges))
    out = []
    verts = list(range(p.n))
    for size in range(1, p.n - 1):
        for cs in itertools.combinations(verts, size):
            cut = frozenset(cs)
            comps = p.components_without(cut)
            if len(comps) >= 2:
                out.append(cut)
    return tuple(out)


def candidates(p: Pattern) -> tuple:
    """Search space for one pattern: None (direct enumeration fallback)
    plus every cutting set."""
    return (None,) + cutting_sets(p)


def subpatterns(p: Pattern, cut: frozenset) -> list:
    """[(subpattern, vertex map old->new)] — one per component, each
    merged with the cutting set."""
    out = []
    for comp in p.components_without(cut):
        vs = sorted(comp | cut)
        idx = {v: i for i, v in enumerate(vs)}
        out.append((p.induced(vs), idx))
    return out
