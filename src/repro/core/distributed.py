"""Distributed, fault-tolerant pattern counting.

Scale-out story for the mining side (the paper is single-node/16-thread;
we map it onto the production mesh):

  * the dense adjacency is 2-D block-sharded over (data, model);
  * every hom contraction is a sharded einsum under pjit — SUMMA-style
    distributed matmuls with XLA-inserted collectives;
  * the count is a sum over row-blocks of the first eliminated vertex:
    each block is an independent work unit, so partial sums are
    checkpointable (resume after preemption) and blocks are issued
    block-cyclically (straggler mitigation: no device owns a contiguous
    hot range of a skewed degree distribution).
"""
from __future__ import annotations

import json
import pathlib
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import homomorphism as H
from repro.core.pattern import Pattern
from repro.core.quotient import quotient_terms


def shard_adjacency(A_np: np.ndarray, mesh):
    axes = [a for a in ("data", "model") if a in mesh.shape]
    spec = P(*axes[:2]) if len(axes) >= 2 else P(axes[0] if axes else None)
    return jax.device_put(jnp.asarray(A_np), NamedSharding(mesh, spec))


def sharded_hom_count(p: Pattern, A, mesh, order=None,
                      budget: int = 1 << 27) -> float:
    """hom(p) with A sharded over the mesh; the contraction runs under jit
    with replicated scalar output."""
    fn = jax.jit(lambda a: H.hom_count(p, a, order=order, budget=budget),
                 out_shardings=NamedSharding(mesh, P()))
    return float(fn(A))


def blockwise_hom_count(p: Pattern, A, mesh, num_blocks: int = 8,
                        order=None, checkpoint: Optional[str] = None,
                        budget: int = 1 << 27,
                        fail_at_block: Optional[int] = None) -> float:
    """hom(p) = Σ_b hom(p | x_{v0} ∈ block b): resumable accumulation.

    ``checkpoint``: JSON path storing {block: partial}; completed blocks
    are skipped on restart.  ``fail_at_block`` injects a failure for the
    fault-tolerance tests.
    """
    n = A.shape[0]
    order = order or H.greedy_plan(p)
    v0 = order[-1]                       # eliminate last => outermost "loop"
    done = {}
    ckpt = pathlib.Path(checkpoint) if checkpoint else None
    if ckpt and ckpt.exists():
        done = {int(k): v for k, v in json.loads(ckpt.read_text()).items()}

    for b in range(num_blocks):
        if b in done:
            continue
        if fail_at_block is not None and b == fail_at_block:
            raise RuntimeError(f"injected failure at block {b}")
        mask = np.zeros(n, np.float64)
        sel = np.arange(b, n, num_blocks)        # block-cyclic rows
        mask[sel] = 1.0
        fn = jax.jit(lambda a, m: H.hom_count(
            p, a, order=order, unary={v0: m}, budget=budget),
            out_shardings=NamedSharding(mesh, P()) if mesh else None)
        val = float(fn(A, jnp.asarray(mask, A.dtype)))
        done[b] = val
        if ckpt:
            ckpt.write_text(json.dumps(done))
    return sum(done.values())


def sharded_inj(p: Pattern, A, mesh, budget: int = 1 << 27) -> float:
    total = 0.0
    for coeff, q in quotient_terms(p):
        total += coeff * sharded_hom_count(q, A, mesh, budget=budget)
    return total
