"""MiningEngine: the partial-embedding-centric programming model (paper §3).

Guarantees (paper):
  * Completeness — if one partial embedding of a subpattern is processed,
    all partial embeddings of that subpattern are processed;
  * Coverage — the processed subpatterns jointly cover every pattern vertex.

Both hold by construction: the engine decomposes the pattern with a
cutting set, and processes *every* partial embedding of *every* subpattern
(whose union covers V_p since each subpattern contains V_C plus one
component).

Fast paths (pattern counting, existence, FSM domains) are pure tensor
contractions.  The generic UDF path follows Algorithm 1 literally —
enumerate cut tuples e_c, per-subpattern extension counts M_i, shrinkage
hash tables — and is exact on any graph the host enumeration can afford;
it exists to give UDFs the same semantics the paper defines.
"""
from __future__ import annotations

import itertools
import math
from dataclasses import dataclass
from typing import Callable, Optional

import numpy as np

from repro.core.apct import APCT
from repro.core.counting import CountingEngine
from repro.core.decomposition import cutting_sets, subpatterns
from repro.core.pattern import Pattern
from repro.core.quotient import shrinkage_patterns
from repro.graph.storage import Graph

UNDETERMINED = -1


@dataclass(frozen=True)
class PartialEmbedding:
    subpattern_id: int
    vertices: tuple                   # per pattern vertex: graph id or -1

    def get_vertex(self, i: int) -> int:
        return self.vertices[i]

    @property
    def determined(self):
        return [(i, v) for i, v in enumerate(self.vertices)
                if v != UNDETERMINED]


class MiningEngine:
    def __init__(self, graph: Graph, apct: Optional[APCT] = None,
                 budget: int = 1 << 27, morph=False):
        self.graph = graph
        self.counter = CountingEngine(graph, budget=budget)
        self.apct = apct or APCT(graph)
        self._compiled: dict = {}           # canonical pattern -> CompiledPlan
        self.compiler_fallbacks = 0
        # morphing count algebra (compiler.morph): False off, True the
        # process store, or a CountStore — threaded into every compile,
        # so clustered queries serve algebraically from earlier reads
        self.morph = morph

    # -- decomposition choice -------------------------------------------------
    def choose_cut(self, p: Pattern):
        """Cost-model-optimal cutting set (None = direct fallback, the
        paper's degeneration guard).  Delegates to the compiler's costing
        stage — one search implementation for engine and compiler."""
        from repro.compiler import costing
        return costing.choose_cut(p, self.apct, self.graph.n)

    # -- fast paths -------------------------------------------------------------
    def get_pattern_count(self, p: Pattern, induced: str = "edge",
                          cut="auto", use_compiler: bool = True) -> float:
        """Edge/vertex-induced count.  The edge-induced path goes through
        ``compiler.compile`` (plan IR + plan cache, so repeated queries
        skip decomposition search); the legacy direct contraction remains
        the fallback (``use_compiler=False``, explicit cuts, or any
        compile/execute failure)."""
        if induced == "edge" and use_compiler and cut == "auto":
            try:
                from repro import compiler
                key = p.canonical()
                cp = self._compiled.get(key)
                if cp is None:
                    cp = compiler.compile((p,), self.graph, apct=self.apct,
                                          counter=self.counter,
                                          morph=self.morph)
                val = cp.count(p)
                # cache only plans that executed: a plan whose execution
                # raised (e.g. PlanTooWide) must not be retried from the
                # memo on every later query
                self._compiled[key] = cp
                return val
            except Exception:
                self.compiler_fallbacks += 1    # legacy path takes over
        if cut == "auto":
            cut = self.choose_cut(p)
        if induced == "edge":
            return self.counter.edge_induced(p, cut=cut)
        return self.counter.vertex_induced(p)

    def pattern_exists(self, p: Pattern) -> bool:
        return self.counter.existence(p)

    # -- Algorithm 1 (generic UDF path) -------------------------------------------
    def run_partial_embeddings(self, p: Pattern,
                               udf: Callable[[PartialEmbedding, int], None],
                               cut="auto"):
        """Enumerate all partial embeddings of every subpattern with their
        extension counts and pass them to the UDF (Algorithm 1)."""
        if cut == "auto":
            cut = self.choose_cut(p)
        if not cut:
            cs = cutting_sets(p)
            cut = cs[0] if cs else None
        if cut is None:
            # clique-like: the whole pattern is the single "subpattern"
            for emb in self._enumerate(p):
                udf(PartialEmbedding(0, emb), 1)
            return
        subs = subpatterns(p, cut)                      # [(pattern, map)]
        cut_list = sorted(cut)

        # shrinkage hash tables: num_shrinkages_i[pe]
        shrinks = [dict() for _ in subs]
        for q, sigma_map in self._shrinkage_with_maps(p, cut):
            for emb in self._enumerate(q):
                # emb maps q's vertices to graph ids; pull back to p
                pv = [emb[sigma_map[v]] for v in range(p.n)]
                for i, (sub, vmap) in enumerate(subs):
                    key = tuple(pv[v] for v in sorted(vmap))
                    shrinks[i][key] = shrinks[i].get(key, 0) + 1

        # per-subpattern embedding lists grouped by cut tuple
        sub_embs = []
        for i, (sub, vmap) in enumerate(subs):
            groups: dict = {}
            new_cut = tuple(vmap[c] for c in cut_list)
            for emb in self._enumerate(sub):
                key = tuple(emb[c] for c in new_cut)
                groups.setdefault(key, []).append(emb)
            sub_embs.append(groups)

        all_keys = set().union(*[set(g) for g in sub_embs]) \
            if sub_embs else set()
        for e_c in sorted(all_keys):
            Ms = [len(g.get(e_c, ())) for g in sub_embs]
            M = math.prod(Ms)
            if M == 0:
                continue
            for i, (sub, vmap) in enumerate(subs):
                inv = {nv: ov for ov, nv in vmap.items()}
                for emb in sub_embs[i].get(e_c, ()):
                    full = [UNDETERMINED] * p.n
                    for nv, gid in enumerate(emb):
                        full[inv[nv]] = gid
                    key = tuple(full[v] for v in sorted(vmap))
                    cnt = M // Ms[i] - shrinks[i].get(key, 0)
                    if cnt > 0:
                        udf(PartialEmbedding(i, tuple(full)), cnt)

    def materialize(self, p: Pattern, pe: PartialEmbedding,
                    num: int) -> list:
        """Extend a partial embedding to at most ``num`` whole-pattern
        embeddings (vertex-set-based extension, Fig 5)."""
        out = []
        fixed = {i: v for i, v in pe.determined}
        todo = [i for i in range(p.n) if i not in fixed]
        g = self.graph

        def rec(assign):
            if len(out) >= num:
                return
            if len(assign) == p.n:
                out.append(tuple(assign[i] for i in range(p.n)))
                return
            v = todo[len(assign) - len(fixed)]
            back = [u for u in range(p.n) if p.has_edge(u, v) and u in assign]
            cands = (set(g.neighbors(assign[back[0]]))
                     if back else set(range(g.n)))
            for u in back[1:]:
                cands &= set(g.neighbors(assign[u]))
            for x in sorted(cands):
                if x in assign.values():
                    continue
                if g.labels is not None and p.labels is not None and \
                        g.labels[x] != p.labels[v]:
                    continue
                assign[v] = x
                rec(assign)
                del assign[v]
                if len(out) >= num:
                    return

        rec(dict(fixed))
        return out

    # -- helpers -----------------------------------------------------------------
    def _enumerate(self, p: Pattern) -> list:
        """All injective embedding tuples of p (host, small patterns)."""
        from repro.core.counting import _connected_order
        g = self.graph
        order = _connected_order(p)
        pos = {v: i for i, v in enumerate(order)}
        out = []
        assign = [UNDETERMINED] * p.n

        def rec(i):
            if i == p.n:
                out.append(tuple(assign))
                return
            v = order[i]
            back = [u for u in range(p.n)
                    if p.has_edge(u, v) and pos[u] < i]
            if back:
                cands = set(g.neighbors(assign[back[0]]))
                for u in back[1:]:
                    cands &= set(g.neighbors(assign[u]))
            else:
                cands = range(g.n)
            used = {assign[order[j]] for j in range(i)}
            for x in cands:
                if x in used:
                    continue
                if g.labels is not None and p.labels is not None and \
                        g.labels[x] != p.labels[v]:
                    continue
                # edge-induced: all pattern edges to earlier vertices hold
                assign[v] = x
                rec(i + 1)
                assign[v] = UNDETERMINED

        rec(0)
        return out

    def _shrinkage_with_maps(self, p: Pattern, cut) -> list:
        """[(quotient pattern, map p-vertex -> quotient vertex)] for every
        cross-component merging partition (not deduped — Algorithm 1 needs
        every tuple).  Shared with the compiler's anchored LocalCount
        corrections via ``quotient.shrinkage_quotients_with_maps``."""
        from repro.core.quotient import shrinkage_quotients_with_maps
        return shrinkage_quotients_with_maps(p, cut)
