"""Frequent subgraph mining with MINI (minimum image-based) support.

Support of a labelled pattern = min over pattern vertices of the number of
distinct graph vertices appearing at that position across all embeddings
(paper §3, Fig 16).  MINI satisfies the downward closure property, so the
search grows patterns one edge at a time and prunes infrequent ones.

Domains come from the tensor fast path: inj_free(p, v) > 0 marks the
domain of vertex v — the vectorised equivalent of the UDF in Fig 15 (a
UDF-path cross-check lives in tests/test_engine.py).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field

import numpy as np

from repro.core.counting import CountingEngine
from repro.core.pattern import Pattern
from repro.graph.storage import Graph


@dataclass
class FSMResult:
    frequent: dict                    # canonical pattern -> support
    evaluated: int = 0
    pruned: int = 0


def mini_support(counter: CountingEngine, p: Pattern) -> int:
    sup = counter.graph.n
    for v in range(p.n):
        dom = counter.inj_free(p, v)
        sup = min(sup, int(np.count_nonzero(dom > 0.5)))
    return sup


def _seed_patterns(g: Graph) -> list:
    """All frequent-candidate single-edge labelled patterns present in g."""
    seen = {}
    la = g.labels
    for u, v in g.edges:
        key = tuple(sorted((int(la[u]), int(la[v]))))
        seen[key] = seen.get(key, 0) + 1
    return [Pattern(2, [(0, 1)], key) for key in sorted(seen)]


def _extensions(p: Pattern, labels: range) -> list:
    """Grow by one edge: close two existing vertices or attach a new
    labelled vertex to an existing one."""
    out = {}
    for u, v in itertools.combinations(range(p.n), 2):
        if not p.has_edge(u, v):
            q = Pattern(p.n, list(p.edges) + [(u, v)], p.labels)
            if q.is_connected():
                out[q.canonical()] = True
    for u in range(p.n):
        for l in labels:
            q = Pattern(p.n + 1, list(p.edges) + [(u, p.n)],
                        tuple(p.labels) + (l,))
            out[q.canonical()] = True
    return list(out)


def fsm(g: Graph, min_support: int, max_vertices: int = 3,
        max_edges: int | None = None,
        counter: CountingEngine | None = None) -> FSMResult:
    """Level-wise FSM with downward-closure pruning."""
    assert g.labels is not None, "FSM requires a labelled graph"
    counter = counter or CountingEngine(g)
    labels = range(g.num_labels)
    res = FSMResult({})
    frontier = []
    for p in _seed_patterns(g):
        res.evaluated += 1
        s = mini_support(counter, p)
        if s >= min_support:
            res.frequent[p.canonical()] = s
            frontier.append(p.canonical())
    seen = set(res.frequent)
    while frontier:
        nxt = []
        for p in frontier:
            for q in _extensions(p, labels):
                if q in seen:
                    continue
                seen.add(q)
                if q.n > max_vertices:
                    continue
                if max_edges is not None and q.m > max_edges:
                    continue
                res.evaluated += 1
                s = mini_support(counter, q)
                if s >= min_support:
                    res.frequent[q] = s
                    nxt.append(q)
                else:
                    res.pruned += 1
        frontier = nxt
    return res
