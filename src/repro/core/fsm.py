"""Frequent subgraph mining with MINI (minimum image-based) support —
level-wise, compiled.

Support of a labelled pattern = min over pattern vertices of the number of
distinct graph vertices appearing at that position across all embeddings
(paper §3, Fig 16).  MINI satisfies the downward closure property, so the
search grows patterns one edge at a time and prunes infrequent ones.

Each lattice level is evaluated *jointly*: the whole candidate frontier
goes through one ``compiler.compile(frontier, graph, domains=True)``
call, so sibling patterns sharing a parent CSE-merge their quotient
free-hom contractions (one ``homf:`` node pool per level), domain
vectors materialise once per automorphism orbit, and the plan cache
serves repeated runs.  The fallback path (``use_compiler=False``, or any
compile/execute failure) computes domains with one vectorised
``inj_free_all`` call per pattern — a single partition walk covering
every vertex, memoised through the shared engine — instead of the old
per-vertex ``inj_free`` expansions.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass

import numpy as np

from repro.core.counting import CountingEngine
from repro.core.pattern import Pattern
from repro.graph.storage import Graph


@dataclass
class FSMResult:
    frequent: dict                    # canonical pattern -> support
    evaluated: int = 0
    pruned: int = 0
    levels: int = 0
    compiled_levels: int = 0          # levels served by a compiled plan
    fallbacks: int = 0                # levels that fell back to inj_free_all


def mini_support(counter: CountingEngine, p: Pattern) -> int:
    """Fallback MINI support through the partial-embedding API: one
    anchored local-count vector per automorphism orbit (the anchored
    vector *is* the domain — # injective maps pinning the orbit
    representative per graph vertex), computed off the decomposition
    join where a cutting set contains the orbit and via the flat Möbius
    expansion otherwise; ``pattern_domains`` falls back to the engine's
    vectorised ``inj_free_all`` on any failure.  Support = min over
    orbits of the domain's nonzero count (orbit members share domains,
    so representatives suffice)."""
    from repro.api import pattern_domains
    doms = pattern_domains(counter, p)
    return int(min(np.count_nonzero(d > 0.5) for d in doms.values()))


def mini_support_dense(counter: CountingEngine, p: Pattern) -> int:
    """Legacy MINI support: the full domain matrix in one vectorised
    ``inj_free_all`` partition walk (kept as the differential oracle for
    the partial-embedding route and as a ``support_fn`` swap-in)."""
    dom = counter.inj_free_all(p)
    return int(np.count_nonzero(dom > 0.5, axis=1).min())


def _seed_patterns(g: Graph) -> list:
    """All frequent-candidate single-edge labelled patterns present in g."""
    seen = {}
    la = g.labels
    for u, v in g.edges:
        key = tuple(sorted((int(la[u]), int(la[v]))))
        seen[key] = seen.get(key, 0) + 1
    return [Pattern(2, [(0, 1)], key) for key in sorted(seen)]


def _extensions(p: Pattern, labels: range) -> list:
    """Grow by one edge: close two existing vertices or attach a new
    labelled vertex to an existing one."""
    out = {}
    for u, v in itertools.combinations(range(p.n), 2):
        if not p.has_edge(u, v):
            q = Pattern(p.n, list(p.edges) + [(u, v)], p.labels)
            if q.is_connected():
                out[q.canonical()] = True
    for u in range(p.n):
        for l in labels:
            q = Pattern(p.n + 1, list(p.edges) + [(u, p.n)],
                        tuple(p.labels) + (l,))
            out[q.canonical()] = True
    return list(out)


def _level_supports(g: Graph, level: list, counter: CountingEngine,
                    apct, plan_cache, res: FSMResult,
                    support_fn, count_store=None) -> dict:
    """MINI supports for one candidate frontier.  ``apct`` not None =>
    compile the frontier jointly (domain plans, cross-sibling CSE, plan
    cache); on failure — or with the compiler disabled — every pattern
    falls back to ``support_fn`` over the shared engine.

    ``count_store`` (a ``compiler.morph.CountStore``) makes the frontier
    feed and read the morphing algebra: level plans compile with
    ``morph=``, so homs already held (from earlier levels' reads) serve
    without contracting, and the level's exact counts are read once and
    harvested back — level k warms the store for level k+1."""
    if apct is not None:
        try:
            from repro import compiler
            # no caller-provided cache => compile uncached: frontier
            # pattern sets essentially never repeat across runs, so
            # feeding the process-global cache would only grow it
            cp = compiler.compile(tuple(level), g, apct=apct,
                                  counter=counter,
                                  cache=plan_cache if plan_cache is not None
                                  else False,
                                  domains=True,
                                  morph=count_store
                                  if count_store is not None else False)
            supports = {p: cp.mini_support(p) for p in level}
            if count_store is not None:
                # the counts() read evaluates the scalar count outputs
                # (domain reads alone touch only tensors) and harvests
                # them — the explicit feeding cost morphing opts into
                cp.counts()
            res.compiled_levels += 1
            return supports
        except Exception:
            res.fallbacks += 1
    return {p: support_fn(counter, p) for p in level}


def fsm(g: Graph, min_support: int, max_vertices: int = 3,
        max_edges: int | None = None,
        counter: CountingEngine | None = None, *,
        use_compiler: bool = True, apct=None, plan_cache=None,
        support_fn=mini_support, count_store=None) -> FSMResult:
    """Level-wise FSM with downward-closure pruning.

    ``use_compiler`` routes every lattice level through one joint
    ``compiler.compile(..., domains=True)``; ``apct`` / ``plan_cache``
    are shared across levels (a small-sample APCT is profiled on
    demand).  Without an explicit ``plan_cache`` levels compile uncached
    — frontier sets rarely repeat, and write-once entries would bloat
    the process cache; pass a ``PlanCache`` to persist plans across
    repeated runs over the same graph.  ``support_fn(counter, p)``
    serves the non-compiled path — the bench swaps in the legacy
    per-vertex expansion for comparison.  ``count_store`` (a
    ``compiler.morph.CountStore``) threads the morphing count algebra
    through every level compile: each frontier's exact counts are
    harvested into the store and later levels' held homs serve without
    contracting — the FSM frontier is morphing's natural first consumer.
    """
    assert g.labels is not None, "FSM requires a labelled graph"
    counter = counter or CountingEngine(g)
    if use_compiler and apct is None:
        from repro.core.apct import APCT
        apct = APCT(g, num_samples=4096)   # one profile, every level
    elif not use_compiler:
        apct = None
    labels = range(g.num_labels)
    res = FSMResult({})
    level = [p.canonical() for p in _seed_patterns(g)]
    seen = set(level)
    while level:
        res.levels += 1
        res.evaluated += len(level)
        supports = _level_supports(g, level, counter, apct, plan_cache,
                                   res, support_fn, count_store)
        survivors = []
        for p in level:
            s = supports[p]
            if s >= min_support:
                res.frequent[p] = s
                survivors.append(p)
            else:
                res.pruned += 1
        nxt = []
        for p in survivors:
            for q in _extensions(p, labels):
                if q in seen:
                    continue
                seen.add(q)
                if q.n > max_vertices:
                    continue
                if max_edges is not None and q.m > max_edges:
                    continue
                nxt.append(q)
        level = nxt
    return res
