"""Homomorphism-count engine: bucket elimination over the dense adjacency.

``hom_count`` contracts one tensor factor A[x_u, x_v] per pattern edge
(plus optional unary label/orientation factors) following an explicit
vertex elimination order — the tensorised form of the paper's loop nests.
Choosing the order IS choosing the decomposition: a cutting set is a
separator that the order eliminates last.

Intermediates above the element budget are computed in chunks over their
leading index (lax-free host loop of device einsums) — the dense analogue
of tiling the enumeration over vertex blocks, which is also what the
distributed path shards.
"""
from __future__ import annotations

import string
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pattern import Pattern

LETTERS = string.ascii_letters


class PlanTooWide(Exception):
    """The elimination order materialises an intermediate beyond the hard
    memory cap — the tensorised analogue of an enumeration too wide to
    tile.  Callers fall back (cliques -> ordered enumeration) or re-plan."""


def plan_from_cut(p: Pattern, cut: frozenset) -> tuple:
    """Elimination order from a cutting set: component vertices first
    (per component, leaves inward), cut vertices last."""
    comps = p.components_without(cut)
    order = []
    for comp in sorted(comps, key=lambda c: (len(c), sorted(c))):
        order.extend(sorted(comp))
    order.extend(sorted(cut))
    return tuple(order)


def greedy_plan(p: Pattern, free: tuple = ()) -> tuple:
    """Min-degree-style greedy elimination order (baseline plan)."""
    adj = {v: set(ns) for v, ns in enumerate(p.adj())}
    remaining = set(range(p.n)) - set(free)
    order = []
    while remaining:
        v = min(remaining, key=lambda x: (len(adj[x] & remaining), x))
        order.append(v)
        nb = adj[v] & (remaining - {v})
        for a in nb:                       # connect the frontier (fill-in)
            adj[a] |= nb - {a}
        remaining.remove(v)
    order.extend(sorted(free))
    return tuple(order)


def elimination_widths(p: Pattern, order: tuple, free: tuple = ()) -> list:
    """Actual per-step intermediate widths of ``hom_count``: simulate the
    factor index sets exactly as the engine contracts them — eliminating
    ``v`` joins only the factors that *touch* v, so a free output axis
    widens a step only once some factor actually carries it (it enters
    through an edge to a free vertex, then rides the produced
    intermediate).  Returns [(v, out_width)] aligned with
    ``frontier_sizes`` (free vertices skipped).

    This is the execution-faithful width the memory gate should test:
    ``frontier_sizes``-based costing used to union *every* free axis
    into *every* step, an upper bound that priced anchored flat-Möbius
    candidates infinite on large graphs even though the real einsums
    never materialise those axes early."""
    factors = [frozenset(e) for e in sorted(p.edges)]
    covered = set().union(*factors) if factors else set()
    factors += [frozenset({v}) for v in range(p.n) if v not in covered]
    out = []
    for v in order:
        if v in free:
            continue
        involved = [s for s in factors if v in s]
        rest = [s for s in factors if v not in s]
        out_idx = frozenset().union(*involved) - {v} if involved \
            else frozenset()
        out.append((v, len(out_idx)))
        factors = rest + [out_idx]
    return out


def frontier_sizes(p: Pattern, order: tuple, free: tuple = ()) -> list:
    """Width of each elimination step (ndim of the intermediate), and the
    processed-subpattern vertex sets (for the APCT cost model)."""
    adj = {v: set(ns) for v, ns in enumerate(p.adj())}
    alive = {v: set(adj[v]) for v in range(p.n)}
    steps = []
    eliminated = set()
    for v in order:
        if v in free:
            continue
        frontier = alive[v] - eliminated
        steps.append((v, frozenset(frontier | {v})))
        for a in frontier:
            alive[a] |= frontier - {a}
        eliminated.add(v)
    return steps


def _einsum_letters(idx_sets, out_idx):
    names = {}
    for s in idx_sets:
        for i in s:
            if i not in names:
                names[i] = LETTERS[len(names)]
    for i in out_idx:
        if i not in names:
            names[i] = LETTERS[len(names)]
    lhs = ",".join("".join(names[i] for i in s) for s in idx_sets)
    rhs = "".join(names[i] for i in out_idx)
    return lhs + "->" + rhs


def _contract(tensors, out_idx, budget: int):
    """einsum the (indices, array) factors down to ``out_idx``; chunk over
    the leading output index if the result exceeds the budget."""
    idx_sets = [t[0] for t in tensors]
    arrays = [t[1] for t in tensors]
    n = arrays[0].shape[0] if arrays else 1
    out_elems = n ** len(out_idx)
    if out_elems > 4 * budget:
        raise PlanTooWide(f"intermediate of {out_elems:.2e} elements "
                          f"(indices {out_idx}, n={n}) exceeds the cap")
    if out_elems <= budget or not out_idx:
        return jnp.einsum(_einsum_letters(idx_sets, out_idx), *arrays)
    # chunk over out_idx[0]
    lead = out_idx[0]
    chunk = max(1, budget // max(n ** (len(out_idx) - 1), 1))
    pieces = []
    for start in range(0, n, chunk):
        sl = slice(start, min(start + chunk, n))
        sub = []
        for s, a in tensors:
            if lead in s:
                axis = s.index(lead)
                a = jax.lax.slice_in_dim(a, sl.start, sl.stop, axis=axis)
            sub.append((s, a))
        pieces.append(jnp.einsum(
            _einsum_letters([t[0] for t in sub], out_idx),
            *[t[1] for t in sub]))
    return jnp.concatenate(pieces, axis=0)


def hom_count(p: Pattern, A, *, order: Optional[tuple] = None,
              free: tuple = (), unary: Optional[dict] = None,
              edge_tensors: Optional[dict] = None,
              budget: int = 1 << 27):
    """# homomorphisms (maps preserving edges) of p into the graph with
    dense adjacency A, with ``free`` pattern vertices kept as output axes.

    unary: {vertex: (N,) factor}    (labels, degree masks, ...)
    edge_tensors: {(u,v) sorted: (N,N) factor} overriding A for that edge
      (orientation masks for partial symmetry breaking).
    """
    n = A.shape[0]
    if p.n == 1:
        vec = unary.get(0, jnp.ones((n,), A.dtype)) if unary else \
            jnp.ones((n,), A.dtype)
        return vec if free == (0,) else jnp.sum(vec)
    factors = []
    for (u, v) in sorted(p.edges):
        t = None
        if edge_tensors:
            t = edge_tensors.get((u, v))
        factors.append(((u, v), t if t is not None else A))
    if unary:
        for v, vec in unary.items():
            factors.append(((v,), vec))
    covered = set()
    for s, _ in factors:
        covered.update(s)
    for v in range(p.n):                      # isolated vertices
        if v not in covered:
            factors.append(((v,), jnp.ones((n,), A.dtype)))

    order = order or greedy_plan(p, free)
    for v in order:
        if v in free:
            continue
        involved = [f for f in factors if v in f[0]]
        rest = [f for f in factors if v not in f[0]]
        out_idx = tuple(sorted({i for s, _ in involved for i in s} - {v}))
        arr = _contract(involved, out_idx, budget)
        factors = rest + [(out_idx, arr)]
    # multiply remaining factors over free indices
    if not free:
        total = jnp.asarray(1.0, A.dtype)
        for s, a in factors:
            total = total * (a if a.ndim == 0 else jnp.sum(a))
        return total
    arr = _contract(factors, tuple(free), budget)
    return arr
