"""Connected k-vertex pattern (motif) generation by augmentation.

3-motif = {3-chain, triangle}; 6-motif has 112 patterns, 7-motif 853
(connected graphs on 7 vertices) — the application scales the paper
targets.  Patterns are deduplicated by canonical form.
"""
from __future__ import annotations

import itertools
from functools import lru_cache

from repro.core.pattern import Pattern


@lru_cache(maxsize=None)
def connected_patterns(k: int) -> tuple:
    """All connected patterns with k vertices (canonical, deterministic)."""
    if k == 1:
        return (Pattern(1, []),)
    out = {}
    for base in connected_patterns(k - 1):
        for mask in range(1, 1 << (k - 1)):
            attach = [i for i in range(k - 1) if mask & (1 << i)]
            p = Pattern(k, list(base.edges) + [(i, k - 1) for i in attach])
            c = p.canonical()
            out[c] = True
    return tuple(sorted(out, key=lambda p: (p.m, sorted(p.edges))))


def motif_patterns(k: int) -> list:
    return list(connected_patterns(k))
