"""Pattern graphs (<= ~8 vertices): canonical forms, automorphisms,
connectivity, quotients.  Canonicalisation uses invariant refinement
(degree / neighbour-degree classes) to prune the permutation search, which
keeps 7-motif-scale generation fast in pure Python.
"""
from __future__ import annotations

import itertools
from functools import lru_cache
from typing import Iterable, Optional, Tuple

Edge = Tuple[int, int]


def _norm_edges(edges) -> frozenset:
    out = set()
    for a, b in edges:
        if a == b:
            continue
        out.add((min(a, b), max(a, b)))
    return frozenset(out)


class Pattern:
    __slots__ = ("n", "edges", "labels", "_hash")

    def __init__(self, n: int, edges: Iterable[Edge],
                 labels: Optional[tuple] = None):
        self.n = int(n)
        self.edges = _norm_edges(edges)
        self.labels = tuple(labels) if labels is not None else None
        if self.labels is not None:
            assert len(self.labels) == self.n
        self._hash = hash((self.n, self.edges, self.labels))

    # -- basics --------------------------------------------------------------
    def __hash__(self):
        return self._hash

    def __eq__(self, other):
        return (self.n, self.edges, self.labels) == \
               (other.n, other.edges, other.labels)

    def __repr__(self):
        lab = f", labels={self.labels}" if self.labels else ""
        return f"Pattern({self.n}, {sorted(self.edges)}{lab})"

    @property
    def m(self) -> int:
        return len(self.edges)

    def adj(self) -> list:
        a = [set() for _ in range(self.n)]
        for u, v in self.edges:
            a[u].add(v)
            a[v].add(u)
        return a

    def degree(self, v: int) -> int:
        return sum(1 for e in self.edges if v in e)

    def has_edge(self, u: int, v: int) -> bool:
        return (min(u, v), max(u, v)) in self.edges

    def is_connected(self) -> bool:
        if self.n <= 1:
            return True
        a = self.adj()
        seen = {0}
        stack = [0]
        while stack:
            u = stack.pop()
            for w in a[u]:
                if w not in seen:
                    seen.add(w)
                    stack.append(w)
        return len(seen) == self.n

    def components_without(self, cut: frozenset) -> list:
        """Connected components of pattern minus the cut vertices."""
        a = self.adj()
        rest = [v for v in range(self.n) if v not in cut]
        seen = set()
        comps = []
        for s in rest:
            if s in seen:
                continue
            comp = {s}
            stack = [s]
            seen.add(s)
            while stack:
                u = stack.pop()
                for w in a[u]:
                    if w not in cut and w not in seen:
                        seen.add(w)
                        comp.add(w)
                        stack.append(w)
            comps.append(frozenset(comp))
        return comps

    def induced(self, vertices) -> "Pattern":
        """Induced subpattern, vertices relabelled 0..k-1 (sorted order).
        Returns (pattern, mapping old->new)."""
        vs = sorted(vertices)
        idx = {v: i for i, v in enumerate(vs)}
        e = [(idx[u], idx[v]) for u, v in self.edges
             if u in idx and v in idx]
        lab = tuple(self.labels[v] for v in vs) if self.labels else None
        return Pattern(len(vs), e, lab)

    def relabel(self, perm) -> "Pattern":
        """perm[i] = new index of vertex i."""
        e = [(perm[u], perm[v]) for u, v in self.edges]
        lab = None
        if self.labels:
            lab = [0] * self.n
            for i, l in enumerate(self.labels):
                lab[perm[i]] = l
        return Pattern(self.n, e, tuple(lab) if lab else None)

    def quotient_with_map(self, partition):
        """Merge each block of ``partition`` (iterable of iterables covering
        0..n-1) into one vertex.  Returns (pattern, block_index map old->new)
        or (None, None) if merging adjacent vertices creates a self-loop
        (no injective images on simple G) or labels conflict."""
        blocks = [sorted(b) for b in partition]
        blocks.sort()
        idx = {}
        for bi, b in enumerate(blocks):
            for v in b:
                idx[v] = bi
        e = set()
        for u, v in self.edges:
            a, b = idx[u], idx[v]
            if a == b:
                return None, None                # self-loop
            e.add((min(a, b), max(a, b)))
        lab = None
        if self.labels:
            lab = []
            for b in blocks:
                ls = {self.labels[v] for v in b}
                if len(ls) > 1:
                    return None, None            # incompatible labels
                lab.append(ls.pop())
        return Pattern(len(blocks), e, tuple(lab) if lab else None), idx

    def quotient(self, partition) -> "Pattern":
        return self.quotient_with_map(partition)[0]

    # -- invariants / canonical form ------------------------------------------
    def _classes(self) -> list:
        """Vertex partition by a cheap 2-round WL-style invariant."""
        a = self.adj()
        inv = [(self.degree(v), self.labels[v] if self.labels else 0)
               for v in range(self.n)]
        for _ in range(2):
            inv = [(inv[v], tuple(sorted(inv[w] for w in a[v])))
                   for v in range(self.n)]
        key = {}
        for v in range(self.n):
            key.setdefault(inv[v], []).append(v)
        return [key[k] for k in sorted(key)]

    def _perms(self):
        """Permutations respecting invariant classes (maps old->new)."""
        classes = self._classes()
        slots = []
        pos = 0
        for c in classes:
            slots.append((c, list(range(pos, pos + len(c)))))
            pos += len(c)
        for assignment in itertools.product(
                *[itertools.permutations(s) for c, s in slots]):
            perm = [0] * self.n
            for (c, _), slot_perm in zip(slots, assignment):
                for v, p in zip(c, slot_perm):
                    perm[v] = p
            yield tuple(perm)

    def _code(self) -> tuple:
        bits = 0
        k = 0
        for i in range(self.n):
            for j in range(i + 1, self.n):
                if (i, j) in self.edges:
                    bits |= 1 << k
                k += 1
        return (bits, self.labels or ())

    def canonical(self) -> "Pattern":
        return _canonical_cached(self)

    def canonical_perm(self) -> tuple:
        """A permutation (old->new) achieving the canonical form."""
        best, bperm = None, None
        for perm in self._perms():
            q = self.relabel(perm)
            c = q._code()
            if best is None or c > best:
                best, bperm = c, perm
        return bperm

    def automorphisms(self) -> list:
        """All permutations (old->new) preserving edges and labels.
        Automorphisms map each invariant class onto itself, so we only
        permute members within their own class's vertex set."""
        classes = self._classes()
        code = self._code()
        out = []
        for assignment in itertools.product(
                *[itertools.permutations(c) for c in classes]):
            perm = [0] * self.n
            for c, pc in zip(classes, assignment):
                for v, t in zip(c, pc):
                    perm[v] = t
            if self.relabel(tuple(perm))._code() == code:
                out.append(tuple(perm))
        return out

    def aut_order(self) -> int:
        return len(self.automorphisms())

    def vertex_orbits(self) -> list:
        """Vertex orbits under the automorphism group (sorted tuples,
        sorted by first member).  Vertices in one orbit are exchangeable
        — in particular their FSM MINI domains coincide, so domain plans
        only materialise one representative per orbit."""
        parent = list(range(self.n))

        def find(v):
            while parent[v] != v:
                parent[v] = parent[parent[v]]
                v = parent[v]
            return v

        for perm in self.automorphisms():
            for v, w in enumerate(perm):
                a, b = find(v), find(w)
                if a != b:
                    parent[max(a, b)] = min(a, b)
        groups: dict = {}
        for v in range(self.n):
            groups.setdefault(find(v), []).append(v)
        return sorted(tuple(sorted(g)) for g in groups.values())


@lru_cache(maxsize=100_000)
def _canonical_impl(n, edges, labels):
    p = Pattern(n, edges, labels)
    return p.relabel(p.canonical_perm())


def _canonical_cached(p: Pattern) -> Pattern:
    return _canonical_impl(p.n, p.edges, p.labels)


# -- free-vertex marking --------------------------------------------------------
#
# Free-hom tensors (hom with some vertices kept as output axes) need a
# canonical identity that pins the free axes: two (pattern, free-vertex)
# pairs are interchangeable iff an isomorphism maps one onto the other
# *respecting both real labels and free positions*.  Both properties are
# packed into one int label per vertex:
#
#     unlabelled pattern:  marker                 (0 = bound, k = k-th free)
#     labelled pattern:    (label+1)*STRIDE + marker
#
# Labelled encodings are >= LABEL_STRIDE, unlabelled stay below it, and
# markers never reach the stride (patterns have <= ~8 vertices), so the
# packing is injective and decodable.  ``CountingEngine`` and the
# compiler's free-hom Contract nodes share this scheme, which is what
# lets their (pattern, free) memo keys coincide.

LABEL_STRIDE = 16


def encode_free_label(label, marker: int) -> int:
    assert 0 <= marker < LABEL_STRIDE
    return marker if label is None else (label + 1) * LABEL_STRIDE + marker


def free_skeleton(p: "Pattern") -> "Pattern":
    """Invert the marking: strip markers, restore real labels (if any)."""
    if p.labels is None or max(p.labels) < LABEL_STRIDE:
        return Pattern(p.n, p.edges)
    return Pattern(p.n, p.edges,
                   tuple(l // LABEL_STRIDE - 1 for l in p.labels))


def mark_free(p: "Pattern", free: tuple):
    """Canonicalise a (pattern, free-vertex) pair: returns
    ``(marked, canonical, free_c)`` — the marker-encoded pattern, its
    canonical form, and the free vertices' canonical positions (in rank
    order).  Isomorphic pairs (labels and free positions respected) map
    to identical results."""
    lab = [encode_free_label(p.labels[v] if p.labels else None, 0)
           for v in range(p.n)]
    for rank, fv in enumerate(free):
        lab[fv] = encode_free_label(p.labels[fv] if p.labels else None,
                                    rank + 1)
    marked = Pattern(p.n, p.edges, tuple(lab))
    perm = marked.canonical_perm()
    return marked, marked.relabel(perm), tuple(perm[fv] for fv in free)


# -- common patterns -----------------------------------------------------------

def chain(k: int) -> Pattern:
    return Pattern(k, [(i, i + 1) for i in range(k - 1)])


def clique(k: int) -> Pattern:
    return Pattern(k, [(i, j) for i in range(k) for j in range(i + 1, k)])


def cycle(k: int) -> Pattern:
    return Pattern(k, [(i, (i + 1) % k) for i in range(k)])


def star(k: int) -> Pattern:
    return Pattern(k, [(0, i) for i in range(1, k)])


def tailed_triangle() -> Pattern:
    return Pattern(4, [(0, 1), (1, 2), (0, 2), (2, 3)])


def pseudo_clique(k: int, missing: int = 1) -> list:
    """All patterns obtained by deleting ``missing`` edges from a k-clique
    (pseudo-cliques with parameter k in the paper's PC application)."""
    full = clique(k)
    out = {}
    for drop in itertools.combinations(sorted(full.edges), missing):
        p = Pattern(k, full.edges - set(drop))
        if p.is_connected():
            out[p.canonical()] = True
    return list(out)
