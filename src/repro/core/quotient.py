"""Partition lattice machinery: set partitions, Möbius coefficients, and
shrinkage (quotient) patterns.

The paper's shrinkage patterns (§2.4) are quotients of the target pattern
obtained by merging vertices from *different* subpatterns.  In full
generality, homomorphism and injective-tuple counts are related across the
partition lattice:

    hom(p, G)  =  Σ_{σ ∈ Π(V_p)}  inj(p/σ, G)
    inj(p, G)  =  Σ_{σ ∈ Π(V_p)}  μ(σ) · hom(p/σ, G),
    μ(σ)       =  Π_{B ∈ σ} (-1)^{|B|-1} (|B|-1)!

Quotients with self-loops (merging adjacent vertices) have zero counts on
simple graphs and are dropped.  Quotients are deduplicated by canonical
form, which is exactly the paper's cross-pattern computation reuse: all
112 6-motif patterns share a small pool of quotient hom computations.

Labelled patterns are first-class: ``Pattern.quotient_with_map`` refuses
to merge vertices with different labels (such a quotient has zero hom /
inj count on a vertex-labelled graph, exactly like a self-loop), and
surviving quotients carry the merged labels, so every identity above —
including ``shrinkage_patterns`` multiplicities — holds verbatim on
labelled inputs.  The dropped terms are all identically zero, never
approximations.
"""
from __future__ import annotations

import itertools
import math
from functools import lru_cache

from repro.core.pattern import Pattern


def partitions(items: tuple):
    """All set partitions of ``items`` (tuple of ints)."""
    items = list(items)
    if not items:
        yield []
        return
    first, rest = items[0], items[1:]
    for part in partitions(tuple(rest)):
        for i in range(len(part)):
            yield part[:i] + [part[i] + [first]] + part[i + 1:]
        yield [[first]] + part


def mobius(partition) -> int:
    mu = 1
    for block in partition:
        b = len(block)
        mu *= (-1) ** (b - 1) * math.factorial(b - 1)
    return mu


@lru_cache(maxsize=10_000)
def quotient_terms(p: Pattern) -> tuple:
    """Terms of inj(p) = Σ μ·hom(p/σ): tuple of (coeff, canonical quotient),
    merged by isomorphism class.  Self-loop quotients are dropped."""
    acc = {}
    for sigma in partitions(tuple(range(p.n))):
        q = p.quotient(sigma)
        if q is None:
            continue
        c = q.canonical()
        acc[c] = acc.get(c, 0) + mobius(sigma)
    return tuple(sorted(((v, k) for k, v in acc.items() if v != 0),
                        key=lambda t: (t[1].n, t[1].m, sorted(t[1].edges))))


@lru_cache(maxsize=10_000)
def hom_expansion(p: Pattern) -> tuple:
    """Terms of hom(p) = Σ inj(p/σ): tuple of (count, canonical quotient)."""
    acc = {}
    for sigma in partitions(tuple(range(p.n))):
        q = p.quotient(sigma)
        if q is None:
            continue
        c = q.canonical()
        acc[c] = acc.get(c, 0) + 1
    return tuple(sorted(((v, k) for k, v in acc.items()),
                        key=lambda t: (t[1].n, t[1].m, sorted(t[1].edges))))


def shrinkage_quotients_with_maps(p: Pattern, cut: frozenset) -> list:
    """[(quotient pattern, map p-vertex -> quotient vertex)] for every
    cross-component merging partition of p - cut — NOT deduplicated by
    isomorphism, because callers that pin cut vertices (Algorithm 1's
    hash tables, the compiler's anchored LocalCount corrections) need
    the vertex map of every individual partition.  Label-conflicting and
    self-loop merges are dropped (identically zero)."""
    comps = p.components_without(cut)
    comp_of = {}
    for ci, comp in enumerate(comps):
        for v in comp:
            comp_of[v] = ci
    non_cut = tuple(v for v in range(p.n) if v not in cut)
    out = []
    for sigma in partitions(non_cut):
        nontrivial = [b for b in sigma if len(b) > 1]
        if not nontrivial:
            continue
        if not all(len({comp_of[v] for v in b}) == len(b) for b in sigma):
            continue                        # merged within one component
        full = [[v] for v in sorted(cut)] + [sorted(b) for b in sigma]
        q, blk = p.quotient_with_map(full)
        if q is None:
            continue
        out.append((q, blk))
    return out


@lru_cache(maxsize=10_000)
def shrinkage_patterns_subset(p: Pattern, cut: frozenset) -> list:
    """Shrinkage patterns of the *axis-subset* decomposition, where each
    subpattern contains only the cut vertices adjacent to its component
    (the |cut| >= 3 tier's pair/vector factors).  The join then enforces
    injectivity only (a) among cut vertices (the kernel mask) and (b)
    within each component ∪ its adjacent cut vertices, so the allowed
    collisions — each contributing one inj(p/σ) to subtract — are:

      * vertices of different components (classic shrinkage);
      * a component vertex with a cut vertex *not* adjacent to that
        component (the distant-cut collisions the full-cut form folds
        into its factors).

    Enumerates partitions of all of V(p) whose blocks contain at most
    one cut vertex and only pairwise-allowed collisions; multiplicity 1
    per partition, deduplicated by canonical quotient.  Merging adjacent
    vertices never arises (cross-component pairs and distant-cut pairs
    are non-adjacent by construction), and label-conflicting merges are
    dropped as identically zero.  With every component adjacent to the
    whole cut this reduces exactly to ``shrinkage_patterns``."""
    comps = p.components_without(cut)
    adj = p.adj()
    comp_of = {}
    adjc = []
    for ci, comp in enumerate(comps):
        for v in comp:
            comp_of[v] = ci
        adjc.append(frozenset(c for c in cut if adj[c] & comp))

    def allowed(u, v):
        cu, cv = u in cut, v in cut
        if cu and cv:
            return False                    # the kernel mask keeps these
        if cu or cv:
            c, w = (u, v) if cu else (v, u)
            return c not in adjc[comp_of[w]]
        return comp_of[u] != comp_of[v]

    acc = {}
    for sigma in partitions(tuple(range(p.n))):
        nontrivial = [b for b in sigma if len(b) > 1]
        if not nontrivial:
            continue
        if not all(allowed(u, v) for b in nontrivial
                   for u, v in itertools.combinations(b, 2)):
            continue
        q = p.quotient(sigma)
        if q is None:
            continue                        # label conflict: zero
        c = q.canonical()
        acc[c] = acc.get(c, 0) + 1
    return sorted(acc.items(), key=lambda t: (t[0].n, t[0].m))


def shrinkage_patterns(p: Pattern, cut: frozenset) -> list:
    """The paper's shrinkage patterns for a decomposition with cutting set
    ``cut``: quotients merging >=2 vertices that lie in *different*
    connected components of p - cut (cut vertices are never merged).
    Returns a list of (canonical quotient, multiplicity) pairs where the
    multiplicity counts the partitions producing that quotient."""
    comps = p.components_without(cut)
    comp_of = {}
    for ci, comp in enumerate(comps):
        for v in comp:
            comp_of[v] = ci
    non_cut = tuple(v for v in range(p.n) if v not in cut)
    acc = {}
    for sigma in partitions(non_cut):
        # must merge at least one cross-component pair; blocks within one
        # component are not shrinkages (they are impossible tuples already
        # excluded by per-subpattern injectivity)
        nontrivial = [b for b in sigma if len(b) > 1]
        if not nontrivial:
            continue
        if not all(len({comp_of[v] for v in b}) == len(b) for b in sigma):
            continue                        # merged within one component
        full = [[v] for v in cut] + [list(b) for b in sigma]
        q = p.quotient(full)
        if q is None:
            continue
        c = q.canonical()
        acc[c] = acc.get(c, 0) + 1
    return sorted(acc.items(), key=lambda t: (t[0].n, t[0].m))
