"""Joint decomposition-space search (paper §4.3, Fig 23) and the
pseudo-clique miner (paper §3's PC application on the partial-embedding
API).

For an application with n concrete patterns, each with m candidate cutting
sets, the joint space is m^n (cross-pattern reuse couples the choices).
Circulant tuning iterates over patterns round-robin, re-picking each
pattern's cutting set greedily against the *current* assignment of all
others, until a full pass changes nothing — a coordinate-descent local
optimum.  Baselines: independent/separate tuning, random sampling, and
simulated annealing (the paper's comparison set).

``mine_pseudo_cliques`` is the advanced-app consumer of the
partial-embedding API: per-vertex participation counts of every k-clique-
minus-``missing``-edges pattern, read off anchored local-count vectors
(one per automorphism orbit per pattern) instead of materialised
embeddings — the hotspot ranking Peregrine-style systems pay a full
enumeration for.
"""
from __future__ import annotations

import math
import random
import time
from dataclasses import dataclass, field

import numpy as np

from repro.core import cost_model as CM
from repro.core.decomposition import candidates
from repro.core.pattern import Pattern, pseudo_clique


@dataclass
class SearchResult:
    cuts: list                       # chosen cutting set per pattern
    cost: float
    search_time_s: float
    evals: int = 0
    history: list = field(default_factory=list)   # (time, best_cost)


def _cost(patterns, cuts, apct, n) -> float:
    return CM.application_cost(list(zip(patterns, cuts)), apct, n)


def separate_tuning(patterns, apct, n) -> SearchResult:
    """Tune each pattern independently (no reuse awareness)."""
    t0 = time.perf_counter()
    cuts, evals = [], 0
    for p in patterns:
        best, bc = None, math.inf
        for cand in candidates(p):
            c = CM.pattern_cost(p, cand, apct, n)
            evals += 1
            if c < bc:
                best, bc = cand, c
        cuts.append(best)
    return SearchResult(cuts, _cost(patterns, cuts, apct, n),
                        time.perf_counter() - t0, evals)


def independent_sampling(patterns, apct, n, num_samples: int = 64,
                         seed: int = 0) -> SearchResult:
    t0 = time.perf_counter()
    rng = random.Random(seed)
    cands = [candidates(p) for p in patterns]
    best, bc = None, math.inf
    hist = []
    for _ in range(num_samples):
        cuts = [rng.choice(cs) for cs in cands]
        c = _cost(patterns, cuts, apct, n)
        if c < bc:
            best, bc = cuts, c
        hist.append((time.perf_counter() - t0, bc))
    return SearchResult(best, bc, time.perf_counter() - t0, num_samples, hist)


def circulant_tuning(patterns, apct, n, init=None,
                     max_rounds: int = 20) -> SearchResult:
    """Algorithm of Fig 23: round-robin coordinate descent over the joint
    cutting-set assignment until convergence."""
    t0 = time.perf_counter()
    cands = [candidates(p) for p in patterns]
    cuts = (list(init) if init is not None
            else separate_tuning(patterns, apct, n).cuts)
    best = _cost(patterns, cuts, apct, n)
    evals = 0
    hist = [(time.perf_counter() - t0, best)]
    for _ in range(max_rounds):
        converged = True
        for i, p in enumerate(patterns):
            previous = cuts[i]
            for cand in cands[i]:
                if cand == cuts[i]:
                    continue
                backup = cuts[i]
                cuts[i] = cand
                c = _cost(patterns, cuts, apct, n)
                evals += 1
                if c < best:
                    best = c
                    hist.append((time.perf_counter() - t0, best))
                else:
                    cuts[i] = backup
            if cuts[i] != previous:
                converged = False
        if converged:
            break
    return SearchResult(cuts, best, time.perf_counter() - t0, evals, hist)


def simulated_annealing(patterns, apct, n, steps: int = 300,
                        t_start: float = 2.0, seed: int = 0) -> SearchResult:
    t0 = time.perf_counter()
    rng = random.Random(seed)
    cands = [candidates(p) for p in patterns]
    cuts = [rng.choice(cs) for cs in cands]
    cur = _cost(patterns, cuts, apct, n)
    best, bcuts = cur, list(cuts)
    hist = [(time.perf_counter() - t0, best)]
    for s in range(steps):
        temp = t_start * (1 - s / steps) + 1e-3
        i = rng.randrange(len(patterns))
        old = cuts[i]
        cuts[i] = rng.choice(cands[i])
        c = _cost(patterns, cuts, apct, n)
        if c < cur or rng.random() < math.exp(min((cur - c) / (abs(cur) * temp
                                                              + 1e-9), 0)):
            cur = c
            if c < best:
                best, bcuts = c, list(cuts)
                hist.append((time.perf_counter() - t0, best))
        else:
            cuts[i] = old
    return SearchResult(bcuts, best, time.perf_counter() - t0, steps, hist)


def genetic(patterns, apct, n, pop: int = 16, gens: int = 12,
            seed: int = 0) -> SearchResult:
    """Genetic baseline (paper §4.3): uniform crossover + point mutation
    over the joint cutting-set assignment."""
    t0 = time.perf_counter()
    rng = random.Random(seed)
    cands = [candidates(p) for p in patterns]

    def rand_ind():
        return [rng.choice(cs) for cs in cands]

    popl = [rand_ind() for _ in range(pop)]
    scored = [( _cost(patterns, ind, apct, n), ind) for ind in popl]
    evals = pop
    hist = [(time.perf_counter() - t0, min(s for s, _ in scored))]
    for g in range(gens):
        scored.sort(key=lambda t: t[0])
        elite = [ind for _, ind in scored[:pop // 4]]
        children = list(elite)
        while len(children) < pop:
            a, b = rng.sample(elite, 2) if len(elite) >= 2 else (elite[0],
                                                                 elite[0])
            child = [x if rng.random() < 0.5 else y for x, y in zip(a, b)]
            if rng.random() < 0.5:
                i = rng.randrange(len(child))
                child[i] = rng.choice(cands[i])
            children.append(child)
        scored = [(_cost(patterns, ind, apct, n), ind) for ind in children]
        evals += len(children)
        hist.append((time.perf_counter() - t0, min(s for s, _ in scored)))
    best, ind = min(scored, key=lambda t: t[0])
    return SearchResult(ind, best, time.perf_counter() - t0, evals, hist)


METHODS = {
    "separate": separate_tuning,
    "random": independent_sampling,
    "circulant": circulant_tuning,
    "annealing": simulated_annealing,
    "genetic": genetic,
}


# -- pseudo-clique mining off the partial-embedding API ---------------------------

@dataclass
class PseudoCliqueResult:
    """Per-vertex pseudo-clique participation.  ``per_vertex[u]`` is the
    number of edge-induced embeddings across all k-clique-minus-
    ``missing``-edges patterns that contain graph vertex u;
    ``totals[pattern]`` the global count per pattern; ``hotspots`` the
    vertices with ``per_vertex >= min_count``, highest first."""
    k: int
    missing: int
    per_vertex: np.ndarray
    totals: dict
    hotspots: list


def mine_pseudo_cliques(graph, k: int, missing: int = 1, *,
                        min_count: int = 1, counter=None, cache=None,
                        use_compiler: bool = True) -> PseudoCliqueResult:
    """Mine pseudo-cliques (k-cliques with ``missing`` edges deleted)
    through anchored local counts: each pattern contributes one anchored
    vector per automorphism orbit — the completion counts with that
    orbit pinned per graph vertex — weighted into per-vertex embedding
    participation (``api.vertex_counts``).  No embedding is ever
    materialised; the global count falls out of the same vectors
    (Σ_u vertex_counts[u] = n_p · #embeddings, exactly).  A shared
    ``CountingEngine`` CSE-merges the patterns' quotient contractions,
    and ``cache=None`` (the process plan cache) makes repeat mines
    compile-free.
    """
    from repro.api import vertex_counts
    from repro.core.counting import CountingEngine
    counter = counter or CountingEngine(graph)
    pats = pseudo_clique(k, missing)
    per_vertex = np.zeros(graph.n)
    totals = {}
    for p in pats:
        vc = vertex_counts(p, graph, counter=counter, cache=cache,
                           use_compiler=use_compiler)
        per_vertex += vc
        totals[p] = vc.sum() / p.n
    hotspots = sorted((u for u in range(graph.n)
                       if per_vertex[u] >= min_count),
                      key=lambda u: (-per_vertex[u], u))
    return PseudoCliqueResult(k, missing, per_vertex, totals, hotspots)
