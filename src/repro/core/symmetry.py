"""Partial symmetry breaking (paper §4.4), tensorised.

Full symmetry breaking (vertex-ID restrictions) is incompatible with the
decomposition join — restricting each subpattern destroys the tuple
multiplicities the join needs (Fig 25).  PSB restricts only a *partially
symmetric* sub-structure and compensates by replaying the remaining
computation once per automorphism image (Fig 26).

Tensor form: pick an interchangeable vertex orbit O (vertices with
identical neighbourhoods outside O, O itself a clique or independent set —
so Sym(O) <= Aut(p)).  Eliminate all non-orbit vertices first, producing an
extension tensor E over O's indices; the compensation replay is the sum of
E over all |O|! axis permutations (transposes — cheap, the paper's
duplicated inner loops); the restricted enumeration contracts the
symmetrised E against strictly-upper-triangular orbit masks, touching each
vertex combination once.  ``oriented_inj_orbit`` verifies against the
unrestricted contraction in tests.
"""
from __future__ import annotations

import itertools
import math

import jax.numpy as jnp

from repro.core import homomorphism as H
from repro.core.pattern import Pattern


def interchangeable_orbits(p: Pattern) -> list:
    """Maximal vertex sets whose members are pairwise interchangeable:
    same neighbourhood outside the set, and the set is a clique or an
    independent set.  Sym(orbit) is then a subgroup of Aut(p)."""
    a = p.adj()
    orbits = {}
    closed, open_ = {}, {}
    for v in range(p.n):
        lab = p.labels[v] if p.labels else 0
        closed.setdefault((frozenset(a[v] | {v}), lab), []).append(v)
        open_.setdefault((frozenset(a[v]), lab), []).append(v)
    for groups, want_clique in ((closed, True), (open_, False)):
        for vs in groups.values():
            if len(vs) < 2:
                continue
            pairs = itertools.combinations(vs, 2)
            if want_clique and all(p.has_edge(u, w) for u, w in pairs):
                orbits[tuple(sorted(vs))] = True
            elif not want_clique and not any(p.has_edge(u, w)
                                             for u, w in pairs):
                orbits[tuple(sorted(vs))] = True
    return sorted(orbits)


def hom_oriented(p: Pattern, A, orbit, *, order=None, unary=None,
                 budget: int = 1 << 27):
    """hom count with the orbit enumerated once (x_{o1} < x_{o2} < ...)
    times the |orbit|! compensation — equals hom(p) exactly.

    Internally: eliminate non-orbit vertices -> extension tensor E over the
    orbit; symmetrise E over axis permutations (compensation replay);
    contract with strict-order masks.
    """
    k = len(orbit)
    free = tuple(orbit)
    E = H.hom_count(p, A, order=order, free=free, unary=unary, budget=budget)
    # compensation replay: sum over all axis permutations
    sym = jnp.zeros_like(E)
    for perm in itertools.permutations(range(k)):
        sym = sym + jnp.transpose(E, perm)
    # orbit-internal factors: edges (clique orbit) need A between members;
    # restrict to strictly increasing assignments
    n = A.shape[0]
    upper = jnp.triu(jnp.ones((n, n), A.dtype), 1)
    clique = all(p.has_edge(orbit[i], orbit[j])
                 for i in range(k) for j in range(i + 1, k))
    factors = []
    idx = list(range(k))
    for i in range(k):
        for j in range(i + 1, k):
            m = upper * A if clique else upper
            factors.append(((i, j), m))
    factors.append((tuple(idx), sym))
    total = H._contract(factors, (), budget)
    return total


def psb_speedup_estimate(p: Pattern, orbit) -> float:
    """Structural work reduction on the orbit contraction: the oriented
    enumeration touches C(n,k) instead of n^k combinations."""
    return float(math.factorial(len(orbit)))
