"""Autoshard: the paper's circulant tuning reused for sharding-layout search.

The decomposition searcher (core/search.py, Fig 23) optimises a joint
assignment of per-pattern cutting sets under a cost model with shared
subcomputations.  Layout search is the same problem shape: a joint
assignment of per-knob sharding choices (FSDP axes, TP axes, microbatch
count, KV layout) under the roofline cost model — so we run the same
round-robin coordinate descent, with the dry-run compile + HLO analysis as
the cost oracle.

Each evaluation is a real .lower().compile() of the cell (tens of
seconds); results are cached by (cell, assignment) JSON so re-runs and
overlapping searches share evaluations — the analogue of the paper's
cross-pattern reuse.
"""
from __future__ import annotations

import hashlib
import json
import pathlib
import time

CACHE_DIR = pathlib.Path(__file__).resolve().parents[3] / \
    "benchmarks" / "results" / "autoshard"


# knob -> candidate values.  Values are rule overrides except the
# pseudo-knob "microbatches".
TRAIN_KNOBS = {
    "embed": [(), ("data",), ("pod", "data")],          # FSDP extent
    "heads": [("model",), ()],
    "mlp": [("model",), ()],
    "vocab": [("model",), ()],
    "batch": [("pod", "data"), ("pod", "data", "model")],
    "microbatches": [1, 2, 4, 8, 16],
}
DECODE_KNOBS = {
    "heads": [("model",), ()],
    "kv": [("model",), ()],
    "kv_seq": [(), ("model",), ("data",), ("pod", "data")],
    "batch": [("pod", "data"), ("pod", "data", "model")],
}


def _key(arch, shape, mesh_kind, assign):
    blob = json.dumps([arch, shape, mesh_kind, sorted(assign.items())],
                      default=list, sort_keys=True)
    return hashlib.sha1(blob.encode()).hexdigest()[:16]


def evaluate(arch: str, shape_name: str, mesh_kind: str, assign: dict,
             objective: str = "bound_time") -> dict:
    """Compile the cell under this assignment and return the roofline
    record (cached)."""
    CACHE_DIR.mkdir(parents=True, exist_ok=True)
    f = CACHE_DIR / f"{_key(arch, shape_name, mesh_kind, assign)}.json"
    if f.exists():
        return json.loads(f.read_text())
    from repro.launch.dryrun import run_cell
    overrides = {k: tuple(v) for k, v in assign.items()
                 if k != "microbatches"}
    rec = run_cell(arch, shape_name, mesh_kind, overrides,
                   assign.get("microbatches"), tag="autoshard", save=False)
    f.write_text(json.dumps(rec, indent=1))
    return rec


def objective_of(rec: dict, objective: str = "bound_time") -> float:
    if "skipped" in rec:
        return float("inf")
    if objective == "bound_time":
        return max(rec["t_compute"], rec["t_memory"], rec["t_collective"])
    return rec[objective]


def circulant_autoshard(arch: str, shape_name: str, mesh_kind: str,
                        knobs: dict | None = None, init: dict | None = None,
                        max_rounds: int = 3, budget_evals: int = 40,
                        log=print) -> tuple:
    """Round-robin coordinate descent over the layout knobs (Fig 23 applied
    to sharding).  Returns (best assignment, best record, history)."""
    from repro.configs.base import SHAPES
    knobs = knobs or (TRAIN_KNOBS if SHAPES[shape_name].kind == "train"
                      else DECODE_KNOBS)
    assign = {k: v[0] for k, v in knobs.items()}
    assign.update(init or {})
    history = []
    best_rec = evaluate(arch, shape_name, mesh_kind, assign)
    best = objective_of(best_rec)
    evals = 1
    history.append((dict(assign), best))
    log(f"[autoshard] init {best:.3f}s  {assign}")
    for r in range(max_rounds):
        converged = True
        for knob, options in knobs.items():
            for opt in options:
                if opt == assign[knob] or evals >= budget_evals:
                    continue
                trial = dict(assign, **{knob: opt})
                try:
                    rec = evaluate(arch, shape_name, mesh_kind, trial)
                except Exception as e:              # noqa: BLE001
                    log(f"[autoshard] {knob}={opt}: compile failed: {e}")
                    evals += 1
                    continue
                evals += 1
                c = objective_of(rec)
                history.append((trial, c))
                log(f"[autoshard] {knob}={opt}: {c:.3f}s"
                    f" (best {best:.3f}s)")
                if c < best:
                    best, best_rec, assign = c, rec, trial
                    converged = False
        if converged or evals >= budget_evals:
            break
    return assign, best_rec, history
