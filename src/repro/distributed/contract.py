"""Sharded hom contractions: bucket elimination with the adjacency
row-sharded over the 1-D ``("data",)`` device mesh.

``sharded_hom`` mirrors ``core.homomorphism.hom_count`` step for step —
same factor construction, same elimination order, same ``PlanTooWide``
cap — but the dense adjacency never exists as one n x n array anywhere:

* ``adjacency_blocks`` builds each device's row block directly from the
  graph's CSR via ``jax.make_array_from_callback`` — host-side peak is
  one (rows, Rp) block, device-side each shard holds only its rows;
* ``label_blocks`` shards the one-hot label indicators over the vertex
  (column) axis, so a labelled pattern's unary factors arrive already
  sliced;
* each elimination step runs as a collective einsum under ``shard_map``:
  the eliminated vertex's axis is the sharded axis of every involved
  factor (the adjacency is symmetric, so a factor carrying the vertex
  on its column axis is relabelled to serve it from the row-sharded
  buffer — no transpose, no gather), each device contracts its slice,
  and a ``psum`` over ``"data"`` completes the sum — the intermediate
  comes out replicated;
* the final free-axis step shards its *output* over ``free[0]`` (cut
  axis 0): devices compute disjoint row blocks (``out_specs
  P("data", ...)``), so the cut tensor a decomposition join consumes is
  born sliced along exactly the axis ``distributed/cutjoin`` shards —
  the factor handoff needs no gather.  An adjacency factor between two
  *later* free vertices is the one input that must replicate into the
  step; ``contract.finish_gathers`` counts those so traces surface
  them.

**Exactness.**  Every intermediate is a sum of products of 0/1
adjacency entries and non-negative integer unaries — non-negative
integers, exact in f64 below 2^53, and f64 integer addition is
associative — so psum order, shard count, and zero-padding cannot
change any value: the sharded route is bit-for-bit equal to
``hom_count`` (the same argument as ``distributed/cutjoin``).

**Padding.**  Vertex axes run over ``Rp = ceil(n / d) * d``.  Zero-
padding is value-preserving by induction: the adjacency blocks and
unary vectors are zero outside ``[0, n)``, every elimination output
axis is carried by some involved factor, so intermediates stay zero in
every padded region and padded assignments of the eliminated vertex
contribute nothing.  When d divides n there is no padding and the
returned free tensor keeps its ``P("data", ...)`` sharding end to end;
an indivisible n must trim ``Rp -> n``, and this jax version has no
uneven sharding, so the trim replicates the finished tensor
(``contract.trim_gathers`` counts it — the adjacency itself still
never materialises unsharded either way).

Callers hold ``jax.experimental.enable_x64`` while calling (the engine
does), so factors and steps trace in f64.  All ``shard_map`` call sites
go through ``meshes.sharding_ctx`` — the repo's ``mesh-guard`` lint
rule — so logical-axis ``constrain`` calls by surrounding code resolve
against the mesh the contraction executes on.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import obs
from repro.core import homomorphism as H
from repro.distributed import meshes


def _ceil_to(x: int, m: int) -> int:
    return -(-x // m) * m


def padded_rows(n: int, mesh: Mesh) -> int:
    """Global vertex-axis extent of the sharded buffers: n rounded up to
    the shard multiple (== n exactly when the mesh divides n)."""
    return _ceil_to(max(n, 1), meshes.num_shards(mesh))


def adjacency_blocks(graph, mesh: Mesh, dtype=np.float64):
    """The (Rp, Rp) dense adjacency sharded ``P("data", None)``: each
    device's row block is built directly from CSR inside the
    ``make_array_from_callback`` shard callback, so no n x n array ever
    exists — not on the host, not on any device."""
    n = graph.n
    Rp = padded_rows(n, mesh)
    offs, nbrs = graph.csr
    sharding = NamedSharding(mesh, P("data", None))

    def block(index):
        rs = index[0]
        start = rs.start or 0
        stop = Rp if rs.stop is None else rs.stop
        out = np.zeros((stop - start, Rp), dtype)
        for r in range(start, min(stop, n)):
            out[r - start, nbrs[offs[r]:offs[r + 1]]] = 1
        return out

    return jax.make_array_from_callback((Rp, Rp), sharding, block)


def label_blocks(graph, mesh: Mesh, dtype=np.float64):
    """(num_labels, Rp) one-hot label indicators sharded
    ``P(None, "data")`` — row l is the label-l unary factor, already
    sliced along the vertex axis every elimination step shards."""
    assert graph.labels is not None
    n, L = graph.n, graph.num_labels
    Rp = padded_rows(n, mesh)
    labels = graph.labels
    sharding = NamedSharding(mesh, P(None, "data"))

    def block(index):
        cs = index[1]
        start = cs.start or 0
        stop = Rp if cs.stop is None else cs.stop
        out = np.zeros((L, stop - start), dtype)
        hi = min(stop, n)
        if hi > start:
            out[labels[start:hi], np.arange(hi - start)] = 1
        return out

    return jax.make_array_from_callback((L, Rp), sharding, block)


@functools.lru_cache(maxsize=None)
def _step_fn(mesh: Mesh, spec: str, shard_axes: tuple, ranks: tuple,
             out_rank: int, out_sharded: bool):
    """One shard_map'd contraction step, cached per (mesh, statics) so
    serving plans trace once.  ``shard_axes[i]`` is the axis of factor i
    carrying the sharded index (None = replicated into the step).
    Elimination steps (``out_sharded=False``) contract the sharded index
    locally and ``psum``; the free-output step (``out_sharded=True``)
    keeps it, each device emitting its disjoint output row block."""
    def local(*arrs):
        out = jnp.einsum(spec, *arrs)
        return out if out_sharded else jax.lax.psum(out, "data")

    in_specs = tuple(P(*[("data" if i == ax else None) for i in range(r)])
                     for r, ax in zip(ranks, shard_axes))
    out_specs = P(*(("data",) if out_sharded else (None,))
                  + (None,) * (out_rank - 1)) if out_rank else P()
    jfn = jax.jit(shard_map(local, mesh, in_specs=in_specs,
                            out_specs=out_specs, check_rep=False))

    def call(*args):
        with meshes.sharding_ctx(mesh):
            return jfn(*args)

    return call


def _collective_contract(involved, out_idx, shard_index, *, mesh, n,
                         budget, out_sharded):
    """einsum the (indices, array, is_adjacency) factors down to
    ``out_idx`` with ``shard_index``'s axis device-sharded in every
    factor that carries it — the sharded analogue of
    ``homomorphism._contract`` (whose budget chunking the device split
    replaces)."""
    out_elems = n ** len(out_idx)
    if out_elems > 4 * budget:
        raise H.PlanTooWide(f"intermediate of {out_elems:.2e} elements "
                            f"(indices {tuple(out_idx)}, n={n}) exceeds "
                            f"the cap")
    idx_sets, arrays, shard_axes = [], [], []
    gathers = 0
    for s, a, is_adj in involved:
        if shard_index in s:
            if is_adj and s.index(shard_index) == 1:
                # A is symmetric: relabel (u, v) -> (v, u) so the sharded
                # index is served from the row-sharded buffer as-is
                s = (s[1], s[0])
            shard_axes.append(s.index(shard_index))
        else:
            shard_axes.append(None)
            if is_adj:
                gathers += 1             # replicating a sharded A block
        idx_sets.append(tuple(s))
        arrays.append(a)
    if gathers:
        obs.counter("contract.finish_gathers", value=gathers)
    spec = H._einsum_letters(idx_sets, tuple(out_idx))
    fn = _step_fn(mesh, spec, tuple(shard_axes),
                  tuple(len(s) for s in idx_sets), len(out_idx),
                  out_sharded)
    return fn(*arrays)


def _trim(arr, n: int):
    """Rp -> n on every axis.  A no-op when the mesh divides n (the
    buffers were never padded and the sharding survives); otherwise the
    slice replicates — uneven shardings don't exist in this jax version
    — which the counter makes visible."""
    if not arr.ndim or arr.shape[0] == n:
        return arr
    obs.counter("contract.trim_gathers")
    return arr[(slice(0, n),) * arr.ndim]


def sharded_hom(p, blocks, *, mesh: Mesh, n: int,
                order: Optional[tuple] = None, free: tuple = (),
                unary: Optional[dict] = None, budget: int = 1 << 27):
    """# homomorphisms of ``p`` into the graph whose row-sharded
    adjacency is ``blocks`` (from ``adjacency_blocks``), with ``free``
    pattern vertices kept as output axes — the collective mirror of
    ``homomorphism.hom_count``, bit-for-bit equal to it.

    ``unary`` maps pattern vertices to (Rp,) factors (``label_blocks``
    rows, or replicated vectors zero beyond ``n``).  Scalar counts
    return a 0-d f64 array; free counts return the (n,)*len(free)
    tensor sharded ``P("data", ...)`` over cut axis 0 (replicated when
    the mesh does not divide n — see module docstring)."""
    free = tuple(free)
    Rp = blocks.shape[0]
    dtype = blocks.dtype

    def ones_vec():
        return jnp.where(jnp.arange(Rp) < n, jnp.ones((Rp,), dtype),
                         jnp.zeros((Rp,), dtype))

    if p.n == 1:
        vec = (unary or {}).get(0)
        if vec is None:
            vec = ones_vec()
        return _trim(vec, n) if free == (0,) else jnp.sum(vec)

    factors = []                    # (index tuple, array, is_adjacency)
    for (u, v) in sorted(p.edges):
        factors.append(((u, v), blocks, True))
    if unary:
        for v, vec in unary.items():
            factors.append(((v,), vec, False))
    covered = set()
    for s, _, _ in factors:
        covered.update(s)
    for v in range(p.n):                          # isolated vertices
        if v not in covered:
            factors.append(((v,), ones_vec(), False))

    order = order or H.greedy_plan(p, free)
    for v in order:
        if v in free:
            continue
        involved = [f for f in factors if v in f[0]]
        rest = [f for f in factors if v not in f[0]]
        out_idx = tuple(sorted({i for s, _, _ in involved for i in s}
                               - {v}))
        arr = _collective_contract(involved, out_idx, v, mesh=mesh, n=n,
                                   budget=budget, out_sharded=False)
        factors = rest + [(out_idx, arr, False)]

    if not free:
        total = jnp.asarray(1.0, dtype)
        for _, a, _ in factors:
            total = total * (a if a.ndim == 0 else jnp.sum(a))
        return total
    arr = _collective_contract(factors, free, free[0], mesh=mesh, n=n,
                               budget=budget, out_sharded=True)
    return _trim(arr, n)
