"""Mesh-execution tier for the decomposition join: Σ_{e_c} Π_i M_i(e_c)
sharded over a 1-D ``("data",)`` device mesh.

Two layers, mirroring the single-device kernel tier in ``kernels/ops.py``:

**Layer 1 — data-parallel plan execution** (``MeshExecutor``): the graph
(and its compiled plan) is replicated; concurrent requests fan out over
the mesh, one plan eval per device slot (``map``) or as one fused
``shard_map`` over a batch axis (``join_batch``).  Zero numerical
change — each request runs the exact single-device path.

**Layer 2 — block-sharded factors** (``sharded_cutjoin*``): the
CutJoin/LocalCount tile grid is distributed over cut axis 0.  Each
device holds its row-slice of every factor that *carries* axis 0
(axis-subset factors that miss it are replicated), runs the same Pallas
tile kernels on the slice — the injectivity mask stays globally correct
because the kernels take a per-grid-axis ``offsets`` vector
(``axis_index * rows_per_shard``) added to their tile iotas — and
reduces its f32 tile partials locally in f64.  Scalar joins finish with
a ``psum``; keep-axis locals either concatenate per-shard output slices
(the kept axis is the sharded axis) or ``psum`` per-shard partial
vectors (the kept axis is replicated).

**Exactness / bit-for-bitness.**  The sharded routes run only under the
same ``exact_block`` guard as the single-device kernels: every f32
chunk partial is then an exact integer, every per-device f64 partial
sum is an exact integer well below 2^53, and integer f64 addition is
associative — so ``psum`` order, shard count, and padding cannot change
the result, and the sharded count is bit-for-bit equal to the
single-device oracle.  The guard bound is *global* (max over the whole
factor), which dominates every shard's slice max, so a certificate for
the unsharded join certifies each shard's blocks too (see
``analysis.verify.precertify``).

Axis-0 padding to the shard x tile multiple is value-preserving for the
same reason it is in ``kernels/matreduce``: padded factor rows are
zero, the reduction is a sum, and every join has at least one factor
carrying axis 0 (``_tri_normalise`` injects a zero-padded ones-vector
on uncovered axes).

All ``shard_map`` call sites go through ``meshes.sharding_ctx`` — the
repo's ``mesh-guard`` lint rule enforces this — so logical-axis
``constrain`` calls made by factor producers resolve against the same
mesh the join executes on.
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro import obs
from repro.distributed import meshes
from repro.kernels import matreduce as _mr
from repro.kernels.ops import _auto_interpret

_x64 = jax.experimental.enable_x64

# re-exported so GPM callers need only this module
data_mesh = meshes.data_mesh
num_shards = meshes.num_shards


def _ceil_to(x: int, m: int) -> int:
    return -(-x // m) * m


def _default_block(block, interpret) -> int:
    return block if block is not None else (1024 if interpret else 128)


def _pad_axis(x, axis: int, size: int):
    """Zero-pad one axis of ``x`` up to ``size``."""
    pad = size - x.shape[axis]
    if pad <= 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, pad)
    return jnp.pad(x, pads)


# -- layer 2: block-sharded joins ---------------------------------------------------

@functools.lru_cache(maxsize=None)
def _pair_scalar_fn(mesh: Mesh, distinct: bool, b: int, rows: int,
                    interpret: bool):
    """shard_map'd scalar pair join: local (k, rows, N) row-slice ->
    per-shard f64 partial -> psum.  Cached per (mesh, statics) so
    serving plans trace once."""
    def local(stack):                       # (k, rows, N) on this shard
        off = jnp.stack([jax.lax.axis_index("data") * rows,
                         jnp.int32(0)]).astype(jnp.int32)
        tiles = _mr._pairjoin_tiles(stack, off, distinct=distinct,
                                    bm=b, bn=b, interpret=interpret)
        part = jnp.sum(tiles.astype(jnp.float64))
        return jax.lax.psum(part, "data")

    jfn = jax.jit(shard_map(local, mesh,
                            in_specs=(P(None, "data", None),),
                            out_specs=P(), check_rep=False))

    def call(*args):
        with meshes.sharding_ctx(mesh):
            return jfn(*args)

    return call


@functools.lru_cache(maxsize=None)
def _vec_scalar_fn(mesh: Mesh, b: int, interpret: bool):
    """shard_map'd |cut| = 1 join: local (k, cols) column-slice ->
    per-shard f64 partial -> psum (no mask, so no offsets needed)."""
    def local(stack):
        tiles = _mr._vecjoin_tiles(stack, bn=b, interpret=interpret)
        part = jnp.sum(tiles.astype(jnp.float64))
        return jax.lax.psum(part, "data")

    jfn = jax.jit(shard_map(local, mesh, in_specs=(P(None, "data"),),
                            out_specs=P(), check_rep=False))

    def call(*args):
        with meshes.sharding_ctx(mesh):
            return jfn(*args)

    return call


def sharded_cutjoin(factors, *, mesh: Mesh, distinct: bool = True,
                    block: Optional[int] = None,
                    interpret: Optional[bool] = None) -> float:
    """|cut| <= 2 decomposition join sharded over cut axis 0 — the mesh
    analogue of ``ops.cutjoin_reduce``.  ``block`` must come from the
    ``exact_block`` guard (``cutjoin_exact_block`` / a precertified
    chunk): the sharded route inherits the single-device exactness
    contract and is only bit-for-bit under it."""
    interpret = _auto_interpret(interpret)
    d = num_shards(mesh)
    stack = jnp.stack([jnp.asarray(F, jnp.float32) for F in factors])
    with _x64():
        if stack.ndim == 2:                  # |cut| = 1: vector fast path
            N = stack.shape[1]
            b = min(_default_block(block, interpret),
                    max(_ceil_to(max(N, 1), d) // d, 1))
            stack = _pad_axis(stack, 1, _ceil_to(max(N, 1), d * b))
            return float(_vec_scalar_fn(mesh, b, interpret)(stack))
        assert stack.ndim == 3
        M, N = stack.shape[1], stack.shape[2]
        b = min(_default_block(block, interpret), max(min(M, N), 1))
        Mp = _ceil_to(M, d * b)
        stack = _pad_axis(_pad_axis(stack, 1, Mp), 2, _ceil_to(N, b))
        return float(_pair_scalar_fn(mesh, distinct, b, Mp // d,
                                     interpret)(stack))


@functools.lru_cache(maxsize=None)
def _tri_scalar_fn(mesh: Mesh, present: tuple, distinct: bool, b: int,
                   rows: int, interpret: bool):
    """shard_map'd scalar tri join: factors carrying axis 0 arrive
    row-sliced, the rest replicated; per-shard f64 partial -> psum."""
    def local(*stacked):
        off = jnp.stack([jax.lax.axis_index("data") * rows,
                         jnp.int32(0), jnp.int32(0)]).astype(jnp.int32)
        tiles = _mr._trijoin_tiles(*stacked, offsets=off, present=present,
                                   distinct=distinct, bm=b, bn=b, bk=b,
                                   interpret=interpret)
        part = jnp.sum(tiles.astype(jnp.float64))
        return jax.lax.psum(part, "data")

    in_specs = tuple(P("data", None, None) if 0 in ax else P(None, None, None)
                     for ax in present)
    jfn = jax.jit(shard_map(local, mesh, in_specs=in_specs,
                            out_specs=P(), check_rep=False))

    def call(*args):
        with meshes.sharding_ctx(mesh):
            return jfn(*args)

    return call


def _tri_prepare(factors, axes, n: int, d: int, b: int, shard_axis: int):
    """Normalise tri factors (3-D views, tile padding, injected
    ones-vectors) and extra-pad ``shard_axis`` carriers to the shard x
    tile multiple so every shard's slice is tile-aligned."""
    stacked, present = _mr._tri_normalise(factors, axes, n, b)
    size = _ceil_to(_ceil_to(n, b), d * b)
    out = [_pad_axis(F, shard_axis, size) if shard_axis in ax else F
           for F, ax in zip(stacked, present)]
    return out, present, size


def sharded_cutjoin3(factors, axes, *, n: int, mesh: Mesh,
                     distinct: bool = True, block: Optional[int] = None,
                     interpret: Optional[bool] = None) -> float:
    """|cut| = 3 decomposition join sharded over cut axis 0 — the mesh
    analogue of ``ops.cutjoin_reduce3``.  Axis-subset factors are sliced
    only when they carry axis 0, else replicated to every device; the
    same ``exact_block`` contract as ``sharded_cutjoin`` applies."""
    interpret = _auto_interpret(interpret)
    d = num_shards(mesh)
    cap = _default_block(block, interpret)
    b = min(cap if interpret else min(cap, 128), max(n, 1))
    with _x64():
        stacked, present, size = _tri_prepare(factors, axes, n, d, b, 0)
        fn = _tri_scalar_fn(mesh, present, distinct, b, size // d,
                            interpret)
        return float(fn(*stacked))


@functools.lru_cache(maxsize=None)
def _pair_keep_fn(mesh: Mesh, distinct: bool, b: int, rows: int, q: int,
                  interpret: bool):
    """shard_map'd keep-axis pair join.  ``q`` is the position of the
    *sharded* (original cut-0) axis after the kept axis was moved to the
    front: q == 0 means the kept axis itself is sharded (each shard owns
    a slice of the output -> concatenate via out_specs), q == 1 means
    the reduced axis is sharded (each shard holds a partial output
    vector -> psum)."""
    def local(stack):
        start = jax.lax.axis_index("data") * rows
        off = jnp.stack([start, jnp.int32(0)]).astype(jnp.int32) \
            if q == 0 else \
            jnp.stack([jnp.int32(0), start]).astype(jnp.int32)
        tiles = _mr._pairjoin_keep_tiles(stack, off, distinct=distinct,
                                         bm=b, bn=b, interpret=interpret)
        vec = jnp.sum(tiles.astype(jnp.float64), axis=1)
        return vec if q == 0 else jax.lax.psum(vec, "data")

    in_specs = (P(None, "data", None),) if q == 0 \
        else (P(None, None, "data"),)
    out_specs = P("data") if q == 0 else P()
    jfn = jax.jit(shard_map(local, mesh, in_specs=in_specs,
                            out_specs=out_specs, check_rep=False))

    def call(*args):
        with meshes.sharding_ctx(mesh):
            return jfn(*args)

    return call


def sharded_cutjoin_keep(factors, *, keep: int = 0, mesh: Mesh,
                         distinct: bool = True,
                         block: Optional[int] = None,
                         interpret: Optional[bool] = None) -> np.ndarray:
    """Keep-axis |cut| = 2 join sharded over original cut axis 0 — the
    mesh analogue of ``ops.cutjoin_reduce_keep``.  keep == 0 shards the
    output itself (all-gather via out_specs); keep == 1 shards the
    reduced axis and ``psum``s per-shard partial vectors.  Same
    ``exact_block`` contract as the scalar routes."""
    interpret = _auto_interpret(interpret)
    assert keep in (0, 1)
    d = num_shards(mesh)
    stack = jnp.stack([jnp.asarray(F, jnp.float32) for F in factors])
    assert stack.ndim == 3 and stack.shape[1] == stack.shape[2]
    n = stack.shape[1]
    if keep == 1:
        stack = jnp.swapaxes(stack, 1, 2)    # kept axis leads the kernel
    q = 0 if keep == 0 else 1                # where original axis 0 sits
    b = min(_default_block(block, interpret), max(n, 1))
    size = _ceil_to(_ceil_to(n, b), d * b)
    with _x64():
        stack = _pad_axis(_pad_axis(stack, 1 + q, size), 2 - q,
                          _ceil_to(n, b))
        fn = _pair_keep_fn(mesh, distinct, b, size // d, q, interpret)
        return np.asarray(fn(stack), np.float64)[:n]


@functools.lru_cache(maxsize=None)
def _tri_keep_fn(mesh: Mesh, present: tuple, distinct: bool, b: int,
                 rows: int, q: int, interpret: bool):
    """shard_map'd keep-axis tri join; ``q`` as in ``_pair_keep_fn`` —
    the sharded (original cut-0) axis is the kernel's leading (kept)
    axis when q == 0, its first reduced axis when q == 1."""
    def local(*stacked):
        start = jax.lax.axis_index("data") * rows
        zero = jnp.int32(0)
        off = jnp.stack([start, zero, zero]).astype(jnp.int32) \
            if q == 0 else \
            jnp.stack([zero, start, zero]).astype(jnp.int32)
        tiles = _mr._trijoin_tiles(*stacked, offsets=off, present=present,
                                   distinct=distinct, bm=b, bn=b, bk=b,
                                   interpret=interpret)
        vec = jnp.sum(tiles.astype(jnp.float64), axis=(1, 2))
        return vec if q == 0 else jax.lax.psum(vec, "data")

    def spec(ax):
        return P(*[("data" if a == q and q in ax else None)
                   for a in range(3)])

    in_specs = tuple(spec(ax) for ax in present)
    out_specs = P("data") if q == 0 else P()
    jfn = jax.jit(shard_map(local, mesh, in_specs=in_specs,
                            out_specs=out_specs, check_rep=False))

    def call(*args):
        with meshes.sharding_ctx(mesh):
            return jfn(*args)

    return call


def sharded_cutjoin3_keep(factors, axes, *, keep: int, n: int,
                          mesh: Mesh, distinct: bool = True,
                          block: Optional[int] = None,
                          interpret: Optional[bool] = None) -> np.ndarray:
    """Keep-axis |cut| = 3 join sharded over original cut axis 0 — the
    mesh analogue of ``ops.cutjoin_reduce3_keep``.  Factors are
    permuted host-side so the kept axis leads (exactly as the
    single-device wrapper does); the original cut axis 0 then sits at
    kernel position 0 (keep == 0: output slices, all-gather) or 1
    (keep != 0: partial vectors, psum)."""
    interpret = _auto_interpret(interpret)
    assert keep in (0, 1, 2)
    perm = (keep,) + tuple(a for a in range(3) if a != keep)
    rank = {a: i for i, a in enumerate(perm)}
    paxes, pfactors = [], []
    for F, ax in zip(factors, axes):
        ax = tuple(ax)
        new = tuple(sorted(rank[a] for a in ax))
        order = tuple(ax.index(perm[a]) for a in new)
        pfactors.append(np.transpose(np.asarray(F), order)
                        if order != tuple(range(len(ax))) else F)
        paxes.append(new)
    q = perm.index(0)                        # 0 iff keep == 0, else 1
    d = num_shards(mesh)
    cap = _default_block(block, interpret)
    b = min(cap if interpret else min(cap, 128), max(n, 1))
    with _x64():
        stacked, present, size = _tri_prepare(pfactors, paxes, n, d, b, q)
        fn = _tri_keep_fn(mesh, present, distinct, b, size // d, q,
                          interpret)
        return np.asarray(fn(*stacked), np.float64)[:n]


@functools.lru_cache(maxsize=None)
def _dense_scalar_fn(mesh: Mesh, k: int):
    """shard_map'd dense f64 join (the ``xla-sharded`` route): the
    caller's pre-masked (nf, n, ..., n) stack row-sliced on the first
    cut axis, local Π-then-Σ, psum."""
    def local(stack):
        return jax.lax.psum(jnp.sum(jnp.prod(stack, axis=0)), "data")

    in_specs = (P(*([None, "data"] + [None] * (k - 1))),)
    jfn = jax.jit(shard_map(local, mesh, in_specs=in_specs,
                            out_specs=P(), check_rep=False))

    def call(*args):
        with meshes.sharding_ctx(mesh):
            return jfn(*args)

    return call


def sharded_dense_join(Ms, k: int, *, mesh: Mesh) -> float:
    """The f64 dense join (factors already expanded + injectivity mask
    appended, as ``lowering._eval_cutjoin`` builds them) sharded over
    the first cut axis.  Pure XLA — no f32 chunking, so no guard needed;
    f64 sums of integer counts are exact in any order, so this is
    bit-for-bit with the single-device ``_join_reduce``."""
    d = num_shards(mesh)
    with _x64():
        stack = jnp.stack([jnp.asarray(M, jnp.float64) for M in Ms])
        stack = _pad_axis(stack, 1, _ceil_to(stack.shape[1], d))
        return float(_dense_scalar_fn(mesh, k)(stack))


@functools.lru_cache(maxsize=None)
def _dense_keep_fn(mesh: Mesh, k: int, keep: int):
    """shard_map'd dense f64 keep-axis join (the ``xla-sharded-keep``
    route): the caller's pre-masked (nf, n, ..., n) stack row-sliced on
    cut axis 0, local Π-then-Σ over the reduced axes; keep == 0 means
    the kept axis is the sharded one (each shard owns an output slice —
    concatenate via out_specs), otherwise each shard holds a partial
    output vector and the shards ``psum``."""
    def local(stack):
        red = tuple(a for a in range(k) if a != keep)
        vec = jnp.sum(jnp.prod(stack, axis=0), axis=red)
        return vec if keep == 0 else jax.lax.psum(vec, "data")

    in_specs = (P(None, "data", *([None] * (k - 1))),)
    out_specs = P("data") if keep == 0 else P(None)
    jfn = jax.jit(shard_map(local, mesh, in_specs=in_specs,
                            out_specs=out_specs, check_rep=False))

    def call(*args):
        with meshes.sharding_ctx(mesh):
            return jfn(*args)

    return call


def sharded_dense_join_keep(Ms, k: int, *, keep: int,
                            mesh: Mesh) -> np.ndarray:
    """The f64 dense keep-axis join (factors expanded + injectivity
    mask appended, as ``lowering._eval_local`` builds them) sharded
    over cut axis 0 — the mesh analogue of the ``_join_keep`` /
    ``_join_keep3`` XLA oracles, for keep-axis joins whose
    ``exact_block`` guard refused (previously a wholesale single-device
    fallback).  Pure XLA, f64 integer sums — bit-for-bit with the
    single-device oracle by the same argument as
    ``sharded_dense_join``."""
    assert 0 <= keep < k
    d = num_shards(mesh)
    with _x64():
        stack = jnp.stack([jnp.asarray(M, jnp.float64) for M in Ms])
        assert stack.ndim == k + 1
        n = stack.shape[1 + keep]
        stack = _pad_axis(stack, 1, _ceil_to(stack.shape[1], d))
        out = _dense_keep_fn(mesh, k, keep)(stack)
        return np.asarray(out, np.float64)[:n]


# -- layer 1: data-parallel plan execution ------------------------------------------

@functools.lru_cache(maxsize=None)
def _batch_pair_fn(mesh: Mesh, distinct: bool):
    """shard_map'd fused request batch: (B, k, n, n) f64 factor stacks
    sharded over the *batch* axis, each device evaluating its slice of
    requests as one dense masked join (product over factors, injectivity
    mask from iotas, per-request sum) — the same f64 arithmetic as the
    single-device ``_join_reduce`` dense route, so exact on integer
    counts with no block guard, in one XLA fusion per device."""
    def local(batch):                        # (per, k, n, n) on this shard
        prod = jnp.prod(batch, axis=1)       # (per, n, n)
        if distinct:
            rows = jax.lax.broadcasted_iota(jnp.int32, prod.shape[1:], 0)
            cols = jax.lax.broadcasted_iota(jnp.int32, prod.shape[1:], 1)
            prod = jnp.where(rows != cols, prod, 0.0)
        return jnp.sum(prod, axis=(1, 2))

    jfn = jax.jit(shard_map(local, mesh,
                            in_specs=(P("data", None, None, None),),
                            out_specs=P("data"), check_rep=False))

    def call(*args):
        with meshes.sharding_ctx(mesh):
            return jfn(*args)

    return call


class MeshExecutor:
    """Layer-1 data-parallel fan-out: the graph and compiled plans are
    replicated, concurrent requests spread over the ``data`` axis.

    ``map`` round-robins arbitrary per-request thunks over device slots
    via ``jax.default_device`` — zero numerical change, works for any
    plan eval (``PatternQueryBatcher`` requests, ``vertex_counts``,
    FSM-frontier probes).  ``join_batch`` is the fused fast path for
    homogeneous |cut| = 2 join batches: one ``shard_map`` dispatch
    evaluates ``ceil(B / d)`` joins per device instead of ``B``
    sequential kernel dispatches — on forced-host-device CI this is
    where the layer-1 throughput scaling comes from (per-dispatch
    overhead is amortised ~B-fold)."""

    def __init__(self, mesh: Mesh):
        self.mesh = mesh
        self.devices = list(mesh.devices.reshape(-1))

    def map(self, fn, items: Sequence):
        out = []
        for i, item in enumerate(items):
            dev = self.devices[i % len(self.devices)]
            with jax.default_device(dev):
                out.append(fn(item))
        obs.counter("mesh.map_requests", devices=len(self.devices),
                    value=len(items))
        return out

    def join_batch(self, stacks, *, distinct: bool = True) -> np.ndarray:
        """Fused scalar pair joins: ``stacks[r]`` is one request's
        (k, n, n) factor stack; returns the (B,) f64 counts.  Each
        device evaluates its request slice in f64 dense arithmetic
        (exact on integer counts — the same contract as the lowered
        dense route), so the result is bit-for-bit equal to ``B``
        serial guarded-kernel dispatches while paying for one."""
        d = num_shards(self.mesh)
        B = len(stacks)
        with _x64():
            # one host-side stack + one transfer — a per-request
            # jnp conversion loop costs more than the join itself
            big = jnp.asarray(np.asarray(stacks), jnp.float64)
            assert big.ndim == 4
            per = _ceil_to(B, d) // d
            big = _pad_axis(big, 0, per * d)
            fn = _batch_pair_fn(self.mesh, distinct)
            return np.asarray(fn(big), np.float64)[:B]
