"""Compiled-HLO roofline analysis.

``cost_analysis`` provides per-device FLOPs and HBM bytes; collective
traffic is NOT in cost_analysis, so we parse the optimized (post-SPMD,
per-device) HLO text and sum the operand/result sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute,
converting each to per-device link traffic with the standard ring model.

Hardware constants: TPU v5e-like — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (per the assignment sheet).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per chip (ICI)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"=\s*(?P<result>\([^)]*\)|[a-z0-9_]+\[[0-9,]*\](?:\{[^}]*\})?)\s*"
    r"(?P<op>all-reduce-start|all-gather-start|reduce-scatter-start|"
    r"all-to-all-start|collective-permute-start|all-reduce|all-gather|"
    r"reduce-scatter|all-to-all|collective-permute)\(")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_GROUPS_V1_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class Collective:
    op: str
    result_bytes: int
    group_size: int
    traffic_bytes: int


def parse_collectives(hlo_text: str, default_group: int) -> list[Collective]:
    out = []
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        is_start = op.endswith("-start")
        base = op[:-6] if is_start else op
        rb = _shape_bytes(m.group("result"))
        if is_start:
            rb //= 2            # start result = (operands, outputs)
        g = default_group
        m2 = _GROUPS_V2_RE.search(line)
        if m2:
            g = int(m2.group(2))
        else:
            m1 = _GROUPS_V1_RE.search(line)
            if m1:
                g = len(m1.group(1).split(","))
        g = max(g, 1)
        if base == "all-reduce":
            traffic = int(2 * rb * (g - 1) / g)
        elif base == "all-gather":
            traffic = int(rb * (g - 1) / g)
        elif base == "reduce-scatter":
            traffic = int(rb * (g - 1))          # operand = result * g
        elif base == "all-to-all":
            traffic = int(rb * (g - 1) / g)
        else:                                    # collective-permute
            traffic = rb
        out.append(Collective(base, rb, g, traffic))
    return out


@dataclass
class Roofline:
    chips: int
    flops_per_device: float
    hbm_bytes_per_device: float
    collective_traffic_per_device: float
    num_collectives: int
    collective_summary: list = field(default_factory=list)
    raw_cost: dict = field(default_factory=dict)
    score_bytes_per_device: float = 0.0

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes_per_device / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_traffic_per_device / LINK_BW

    @property
    def t_memory_kernelized(self) -> float:
        """Memory term with attention-score traffic removed — the modeled
        effect of the Pallas flashattn kernel (scores stay in VMEM; its
        own tile IO is O(q+k+v+o), < 2% of the score traffic)."""
        return max(self.hbm_bytes_per_device
                   - self.score_bytes_per_device, 0.0) / HBM_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def bound_time(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    def as_dict(self):
        return {
            "chips": self.chips,
            "flops_per_device": self.flops_per_device,
            "hbm_bytes_per_device": self.hbm_bytes_per_device,
            "collective_traffic_per_device": self.collective_traffic_per_device,
            "num_collectives": self.num_collectives,
            "t_compute": self.t_compute,
            "t_memory": self.t_memory,
            "t_collective": self.t_collective,
            "dominant": self.dominant,
            "t_memory_kernelized": self.t_memory_kernelized,
            "score_bytes_per_device": self.score_bytes_per_device,
            "collective_summary": self.collective_summary,
            "raw_cost_analysis": self.raw_cost,
        }


def analyze(compiled, chips: int, score_dims=None) -> Roofline:
    """Trip-count-aware roofline terms from the compiled per-device HLO.

    ``compiled.cost_analysis()`` counts while (scan) bodies once, so we use
    the hlo_parse call-graph walker for the real totals and keep the raw
    cost_analysis numbers for cross-checking (they match on scan-free
    programs; see tests/test_hlo_parse.py).
    """
    from repro.distributed import hlo_parse

    cost = compiled.cost_analysis() or {}
    parsed = hlo_parse.analyze_text(compiled.as_text(), default_group=chips,
                                    score_dims=score_dims)
    return Roofline(
        chips, parsed.flops, parsed.bytes, parsed.collective_traffic,
        parsed.num_collectives, parsed.collectives,
        raw_cost={"flops": float(cost.get("flops", 0.0)),
                  "bytes_accessed": float(cost.get("bytes accessed", 0.0))},
        score_bytes_per_device=parsed.score_bytes)


def model_flops_train(n_active_params: int, tokens: int) -> float:
    """6·N·D for a fwd+bwd train step."""
    return 6.0 * n_active_params * tokens


def model_flops_decode(n_active_params: int, tokens: int) -> float:
    """2·N per generated token (forward only)."""
    return 2.0 * n_active_params * tokens
