"""Trip-count-aware HLO cost analysis.

XLA's HloCostAnalysis (exposed via ``compiled.cost_analysis()``) counts a
``while`` body ONCE, so any scan-over-layers program under-reports FLOPs,
bytes, and collective traffic by ~num_layers x.  This module parses the
optimized (post-SPMD, per-device) HLO text, builds the computation call
graph, infers scan trip counts from the loop-condition constants, and
accumulates:

  * dot FLOPs (2 * prod(result dims) * prod(contracting dims)) plus 1 FLOP
    per output element of arithmetic elementwise ops,
  * HBM traffic: result + operand bytes of every materialising top-level
    instruction (fusion internals excluded — they live in registers/VMEM),
  * collective link traffic via the ring model (see hlo_analysis).

Approximations are conservative and documented in EXPERIMENTS.md §Roofline.
The parser is validated against cost_analysis() on scan-free programs in
tests/test_hlo_parse.py.
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INST_RE = re.compile(
    r"^\s*(?:ROOT\s+)?(?P<name>%[\w.\-]+)\s*=\s*"
    r"(?P<type>\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s*"
    r"(?P<op>[\w\-]+)\((?P<args>.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?(?P<name>%[\w.\-]+)\s*(?:\([^)]*\))?.*\{\s*$")
_OPERAND_RE = re.compile(r"%[\w.\-]+")
_CONST_RE = re.compile(r"constant\((\d+)\)")

ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "tanh", "log", "rsqrt", "sqrt", "negate", "abs",
    "logistic", "cosine", "sine", "floor", "ceil", "round-nearest-afz",
    "select", "compare", "and", "or", "xor", "not", "clamp",
    "exponential-minus-one", "log-plus-one", "atan2", "sign",
}
SKIP_BYTES = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "bitcast-convert", "after-all", "partition-id", "replica-id", "iota",
    "copy-start", "copy-done",
}
COLLECTIVES = {
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "all-reduce-start", "all-gather-start",
    "reduce-scatter-start", "all-to-all-start", "collective-permute-start",
}
CALLEE_ATTRS = ("calls", "to_apply", "body", "condition",
                "true_computation", "false_computation", "update_computation",
                "select", "scatter", "branch_computations", "called_computations")


def _parse_shapes(type_str):
    """-> list of (dtype, dims list)."""
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        d = [int(x) for x in dims.split(",") if x.strip()]
        out.append((dt, d))
    return out


def _bytes_of(type_str):
    return sum(_DTYPE_BYTES[dt] * math.prod(d) for dt, d in _parse_shapes(type_str))


def _elems_of(type_str):
    return sum(math.prod(d) for _, d in _parse_shapes(type_str))


@dataclass
class Inst:
    name: str
    op: str
    type_str: str
    operands: list
    attrs_str: str
    line: str


@dataclass
class Computation:
    name: str
    insts: list = field(default_factory=list)
    by_name: dict = field(default_factory=dict)


def parse_module(text: str) -> dict:
    comps = {}
    cur = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_RE.match(line)
            if m and "{" in line:
                cur = Computation(m.group("name"))
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INST_RE.match(line)
        if not m:
            continue
        args = m.group("args")
        # split operand region (up to matching paren) from attrs
        depth, i = 1, 0
        while i < len(args) and depth:
            if args[i] == "(":
                depth += 1
            elif args[i] == ")":
                depth -= 1
            i += 1
        operand_str, attr_str = args[:i - 1], args[i:]
        inst = Inst(m.group("name"), m.group("op"), m.group("type"),
                    _OPERAND_RE.findall(operand_str), attr_str, line)
        cur.insts.append(inst)
        cur.by_name[inst.name] = inst
    return comps


def _callees(inst: Inst, kind: str):
    """Computation names referenced by attrs.  kind selects which edges."""
    out = []
    for attr in CALLEE_ATTRS:
        for m in re.finditer(attr + r"=\{?([%\w.\-, ]+)\}?", inst.attrs_str):
            out.extend(_OPERAND_RE.findall(m.group(1)))
    return out


def _trip_count(cond: Computation) -> int:
    """Largest integer constant in the loop condition — jax scans compare
    the induction variable against the trip count."""
    best = 1
    for inst in cond.insts:
        for m in _CONST_RE.finditer(inst.line):
            best = max(best, int(m.group(1)))
    return best


def _dot_flops(inst: Inst, comp: Computation) -> float:
    res = _elems_of(inst.type_str)
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", inst.attrs_str)
    cdims = [int(x) for x in m.group(1).split(",") if x.strip()] if m else []
    # lhs operand dims from symbol table
    contr = 1
    if inst.operands:
        lhs = comp.by_name.get(inst.operands[0])
        if lhs is not None:
            shapes = _parse_shapes(lhs.type_str)
            if shapes:
                dims = shapes[0][1]
                for c in cdims:
                    if c < len(dims):
                        contr *= dims[c]
    return 2.0 * res * max(contr, 1)


def _fusion_bytes(inst: Inst, comp: Computation, comps: dict) -> float:
    """HBM bytes touched by a fusion call.

    Operands that are only dynamic-sliced/gathered inside the body count as
    the slice size, not the full buffer (scan-carried stacks would otherwise
    inflate traffic by the trip count).  A root dynamic-update-slice writes
    in place, so it counts as the update size.
    """
    m = re.search(r"calls=([%\w.\-]+)", inst.attrs_str)
    body = comps.get(m.group(1)) if m else None
    if body is None:
        ob = sum(_bytes_of(comp.by_name[o].type_str)
                 for o in inst.operands if o in comp.by_name)
        return _bytes_of(inst.type_str) + ob

    # map operand index -> param name in body
    params = {}
    for bi in body.insts:
        if bi.op == "parameter":
            m2 = re.search(r"parameter\((\d+)\)", bi.line)
            if m2:
                params[int(m2.group(1))] = bi.name
    total = 0.0
    for i, op_name in enumerate(inst.operands):
        full = (_bytes_of(comp.by_name[op_name].type_str)
                if op_name in comp.by_name else 0)
        pname = params.get(i)
        if pname is None:
            total += full
            continue
        consumers = [bi for bi in body.insts if pname in bi.operands]
        touched, nonslice = 0.0, 0
        for c in consumers:
            if c.op in ("dynamic-slice", "slice", "gather"):
                touched += _bytes_of(c.type_str)
            elif (c.op == "dynamic-update-slice" and c.operands
                  and c.operands[0] == pname):
                upd = (body.by_name.get(c.operands[1])
                       if len(c.operands) > 1 else None)
                touched += 2 * (_bytes_of(upd.type_str) if upd is not None
                                else full)
            else:
                nonslice += 1
        if not consumers:
            total += full
        elif nonslice == 0:
            total += touched
        else:
            # mixed consumers: count slices + bound the rest by the fusion
            # result (a fusion cannot stream more than it materialises
            # per element without being a reduction of the operand)
            total += min(full, touched + max(_bytes_of(inst.type_str),
                                             full // max(len(consumers), 1)))

    def result_bytes(r: Inst) -> float:
        if r.op == "dynamic-update-slice":
            upd = body.by_name.get(r.operands[1]) if len(r.operands) > 1 else None
            return 2.0 * (_bytes_of(upd.type_str) if upd is not None
                          else _bytes_of(r.type_str))
        return float(_bytes_of(r.type_str))

    root = body.insts[-1] if body.insts else None
    for bi in body.insts:
        if bi.line.strip().startswith("ROOT"):
            root = bi
            break
    if root is None:
        total += _bytes_of(inst.type_str)
    elif root.op == "tuple":
        for o in root.operands:
            e = body.by_name.get(o)
            total += result_bytes(e) if e is not None else 0.0
    else:
        total += result_bytes(root)
    return total


@dataclass
class Costs:
    flops: float = 0.0
    bytes: float = 0.0
    collective_traffic: float = 0.0
    num_collectives: int = 0
    collectives: list = field(default_factory=list)
    score_bytes: float = 0.0     # attention-score-shaped traffic (see
                                 # score_dims in analyze_text): the HBM
                                 # round-trips a fused attention kernel
                                 # (kernels/flashattn.py) eliminates


def _ring_traffic(op: str, result_bytes: int, g: int) -> int:
    base = op[:-6] if op.endswith("-start") else op
    rb = result_bytes // 2 if op.endswith("-start") else result_bytes
    if base == "all-reduce":
        return int(2 * rb * (g - 1) / max(g, 1))
    if base == "all-gather":
        return int(rb * (g - 1) / max(g, 1))
    if base == "reduce-scatter":
        return int(rb * (g - 1))
    if base == "all-to-all":
        return int(rb * (g - 1) / max(g, 1))
    return rb                                     # collective-permute


_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_GROUPS_V1_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")


def _group_size(inst: Inst, default: int) -> int:
    m = _GROUPS_V2_RE.search(inst.attrs_str)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_V1_RE.search(inst.attrs_str)
    if m:
        return max(len(m.group(1).split(",")), 1)
    return default


def analyze_text(text: str, default_group: int,
                 score_dims: tuple | None = None) -> Costs:
    """score_dims=(S, block): instructions shaped like attention score
    tiles (one axis == S, another == block) have their traffic also
    accumulated in ``score_bytes`` — the portion a fused attention kernel
    keeps in VMEM."""
    comps = parse_module(text)

    def is_score(type_str: str) -> bool:
        if not score_dims:
            return False
        S, blk = score_dims
        if S == blk:
            return False
        for _, dims in _parse_shapes(type_str):
            if S in dims and blk in dims:
                return True
        return False
    # ENTRY = computation containing no parent reference; HLO marks it, but
    # we detect it as the one never referenced as a callee.
    referenced = set()
    for c in comps.values():
        for inst in c.insts:
            referenced.update(_callees(inst, "all"))
    entries = [c for n, c in comps.items() if n not in referenced]
    total = Costs()
    memo_flops: dict = {}

    def flops_of(cname: str, seen=()) -> float:
        """dot+elementwise flops of computation incl. fusion/while callees."""
        if cname in memo_flops:
            return memo_flops[cname]
        comp = comps.get(cname)
        if comp is None or cname in seen:
            return 0.0
        f = 0.0
        for inst in comp.insts:
            if inst.op == "dot":
                f += _dot_flops(inst, comp)
            elif inst.op in ("convolution",):
                f += 2.0 * _elems_of(inst.type_str)   # underestimate; unused
            elif inst.op in ELEMENTWISE:
                f += _elems_of(inst.type_str)
            elif inst.op == "while":
                body = _OPERAND_RE.search(
                    re.search(r"body=([%\w.\-]+)", inst.attrs_str).group(1))
                cond = re.search(r"condition=([%\w.\-]+)", inst.attrs_str)
                trip = _trip_count(comps[cond.group(1)]) if cond and \
                    cond.group(1) in comps else 1
                f += trip * flops_of(body.group(0), seen + (cname,))
            else:
                for callee in _callees(inst, "all"):
                    if callee in comps and inst.op != "while":
                        f += flops_of(callee, seen + (cname,))
        memo_flops[cname] = f
        return f

    def walk_bytes(cname: str, mult: float, seen=()):
        comp = comps.get(cname)
        if comp is None or cname in seen:
            return
        for inst in comp.insts:
            if inst.op == "while":
                cond = re.search(r"condition=([%\w.\-]+)", inst.attrs_str)
                body = re.search(r"body=([%\w.\-]+)", inst.attrs_str)
                trip = _trip_count(comps[cond.group(1)]) if cond and \
                    cond.group(1) in comps else 1
                if body and body.group(1) in comps:
                    walk_bytes(body.group(1), mult * trip, seen + (cname,))
                continue
            if inst.op in ("call", "conditional", "async-start"):
                for callee in _callees(inst, "all"):
                    if callee in comps:
                        walk_bytes(callee, mult, seen + (cname,))
                continue
            if inst.op in COLLECTIVES:
                rb = _bytes_of(inst.type_str)
                g = _group_size(inst, default_group)
                tr = _ring_traffic(inst.op, rb, g)
                total.collective_traffic += mult * tr
                total.num_collectives += int(mult)
                total.collectives.append(
                    {"op": inst.op, "result_bytes": rb, "group": g,
                     "traffic": tr, "mult": mult})
            if inst.op in SKIP_BYTES:
                continue
            rb = _bytes_of(inst.type_str)
            # Slicing ops touch only the slice, not the backing buffer;
            # DUS/scatter write in place (their result aliases the input).
            if inst.op in ("dynamic-slice", "slice", "gather"):
                b = 2 * rb
            elif inst.op == "dynamic-update-slice":
                upd = (comp.by_name.get(inst.operands[1])
                       if len(inst.operands) > 1 else None)
                ub = _bytes_of(upd.type_str) if upd is not None else rb
                b = 2 * min(ub, rb)
            elif inst.op == "scatter":
                upd = (comp.by_name.get(inst.operands[2])
                       if len(inst.operands) > 2 else None)
                ub = _bytes_of(upd.type_str) if upd is not None else rb
                b = 2 * min(ub, rb)
            elif inst.op == "fusion":
                b = _fusion_bytes(inst, comp, comps)
            else:
                ob = sum(_bytes_of(comp.by_name[o].type_str)
                         for o in inst.operands if o in comp.by_name)
                b = rb + ob
            total.bytes += mult * b
            if is_score(inst.type_str) or any(
                    o in comp.by_name and is_score(comp.by_name[o].type_str)
                    for o in inst.operands):
                total.score_bytes += mult * b

    for e in entries:
        total.flops += flops_of(e.name)
        walk_bytes(e.name, 1.0)
    # aggregate collective summary (top by traffic*mult)
    total.collectives.sort(key=lambda c: -(c["traffic"] * c["mult"]))
    total.collectives = total.collectives[:15]
    return total
