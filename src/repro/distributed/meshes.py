"""Mesh axis conventions and logical-axis sharding rules.

Mesh axes: ``("pod", "data", "model")`` multi-pod, ``("data", "model")``
single pod.  Parameters and activations carry *logical* axis names
("embed", "heads", "mlp", "vocab", "batch", ...) which are resolved to mesh
axes through a rules dict.  The resolver checks divisibility so that a rule
never produces an invalid sharding (falls back to replication).

The rules dict is the search space of the autoshard hillclimber
(distributed/autoshard.py) — the paper's circulant tuning reused for
layout search.
"""
from __future__ import annotations

import contextlib
import math
import threading
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Baseline logical->mesh rules (single- and multi-pod share names; "pod" is
# simply absent from the single-pod mesh and gets dropped by the resolver).
#   embed   : FSDP axis of weight matrices (d_model rows)  -> data
#   heads/kv/mlp/vocab/experts : tensor-parallel columns    -> model
#   batch   : data parallel                                 -> pod+data
#   seq     : sequence parallel (long-context decode only)  -> None here
DEFAULT_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "embed": ("data",),          # FSDP weight sharding
    "heads": ("model",),
    "kv": ("model",),
    "mlp": ("model",),
    "vocab": ("model",),
    "experts": ("model",),
    "expert_embed": (),
    "expert_mlp": ("data",),
    "seq": (),
    "kv_seq": (),                # decode KV-cache sequence axis
    "layers": (),
    "head_dim": (),
    "state": (),
    "conv": (),
    "lora": (),
    "img": (),
}


class _Ctx(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.rules: Optional[dict] = None


_CTX = _Ctx()


@contextlib.contextmanager
def sharding_ctx(mesh: Mesh, rules: Optional[dict] = None):
    """Activate a mesh + rules so ``constrain``/``spec_for`` resolve."""
    prev = (_CTX.mesh, _CTX.rules)
    _CTX.mesh, _CTX.rules = mesh, dict(DEFAULT_RULES, **(rules or {}))
    try:
        yield
    finally:
        _CTX.mesh, _CTX.rules = prev


def active_mesh() -> Optional[Mesh]:
    return _CTX.mesh


def current_rules() -> dict:
    return dict(_CTX.rules or DEFAULT_RULES)


def _resolve_dim(name: Optional[str], dim: int, mesh: Mesh, rules: dict,
                 used: set):
    """Mesh axes for one logical dim: drop axes that don't divide the dim
    or were already consumed by an earlier dim of the same spec."""
    if name is None:
        return None
    want = rules.get(name, ())
    if isinstance(want, str):
        want = (want,)
    got = []
    prod = 1
    for ax in want:
        if ax not in mesh.shape or ax in used:
            continue
        sz = mesh.shape[ax]
        if dim % (prod * sz) == 0:
            got.append(ax)
            prod *= sz
    used.update(got)
    if not got:
        return None
    return tuple(got) if len(got) > 1 else got[0]


def spec_for(axes: tuple, shape: tuple, mesh: Mesh, rules: dict) -> P:
    assert len(axes) == len(shape), (axes, shape)
    used: set = set()
    return P(*[_resolve_dim(a, d, mesh, rules, used)
               for a, d in zip(axes, shape)])


def sharding_for(axes: tuple, shape: tuple,
                 mesh: Optional[Mesh] = None,
                 rules: Optional[dict] = None) -> Optional[NamedSharding]:
    mesh = mesh or _CTX.mesh
    rules = rules or _CTX.rules or DEFAULT_RULES
    if mesh is None:
        return None
    return NamedSharding(mesh, spec_for(axes, shape, mesh, rules))


def constrain(x, *axes):
    """with_sharding_constraint by logical axis names; no-op without a mesh."""
    if _CTX.mesh is None:
        return x
    s = sharding_for(tuple(axes), x.shape)
    return jax.lax.with_sharding_constraint(x, s)


def tree_shardings(axes_tree, shapes_tree, mesh=None, rules=None):
    """Map (axes, shapes) pytrees to NamedShardings (for in/out_shardings).

    ``shapes_tree`` leaves may be shape tuples or anything with ``.shape``
    (arrays / ShapeDtypeStructs).
    """
    mesh = mesh or _CTX.mesh
    rules = dict(DEFAULT_RULES, **(rules or _CTX.rules or {}))

    def one(a, s):
        shape = s.shape if hasattr(s, "shape") else s
        return NamedSharding(mesh, spec_for(a, tuple(shape), mesh, rules))

    return jax.tree.map(
        one, axes_tree, shapes_tree,
        is_leaf=lambda t: isinstance(t, tuple) and all(
            isinstance(e, (str, type(None))) for e in t),
    )


def num_chips(mesh: Mesh) -> int:
    return math.prod(mesh.devices.shape)


def data_mesh(num_devices: Optional[int] = None) -> Mesh:
    """1-D ``("data",)`` mesh over the first ``num_devices`` local
    devices — the execution mesh of the GPM join tier
    (``distributed/cutjoin.py``).  Distinct from
    ``launch.mesh.make_host_mesh``, whose ``("data", "model")`` grid
    puts every device on the *model* axis: the join tier shards the cut
    grid (and fans request batches) over ``data`` only."""
    devs = jax.devices()
    if num_devices is not None:
        assert 1 <= num_devices <= len(devs), (num_devices, len(devs))
        devs = devs[:num_devices]
    return Mesh(np.asarray(devs), ("data",))


def num_shards(mesh: Optional[Mesh], axis: str = "data") -> int:
    """Size of ``axis`` in ``mesh`` — 1 when the mesh is absent or does
    not carry the axis, so callers can treat "no mesh" and "trivial
    mesh" uniformly."""
    if mesh is None:
        return 1
    return int(dict(mesh.shape).get(axis, 1))
