"""Synthetic graph generators: Erdős–Rényi, RMAT, small-world, labelled."""
from __future__ import annotations

import numpy as np

from repro.graph.storage import Graph


def erdos_renyi(n: int, avg_degree: float, seed: int = 0,
                num_labels: int = 0) -> Graph:
    rng = np.random.default_rng(seed)
    m = int(n * avg_degree / 2)
    e = rng.integers(0, n, size=(int(m * 1.2) + 8, 2))
    labels = rng.integers(0, num_labels, n) if num_labels else None
    return Graph(n, e[:m * 2], labels)


def rmat(n_log2: int, avg_degree: float, seed: int = 0,
         a: float = 0.57, b: float = 0.19, c: float = 0.19,
         num_labels: int = 0) -> Graph:
    """R-MAT generator (Chakrabarti et al. 2004), used for RMAT-100M-style
    skewed graphs in the paper's Table 7."""
    rng = np.random.default_rng(seed)
    n = 1 << n_log2
    m = int(n * avg_degree / 2)
    src = np.zeros(m, np.int64)
    dst = np.zeros(m, np.int64)
    p = np.array([a, b, c, 1 - a - b - c])
    for bit in range(n_log2):
        q = rng.choice(4, size=m, p=p)
        src |= ((q >> 1) & 1) << bit
        dst |= (q & 1) << bit
    labels = rng.integers(0, num_labels, n) if num_labels else None
    return Graph(n, np.stack([src, dst], 1), labels)


def small_world(n: int, k: int = 4, beta: float = 0.1, seed: int = 0,
                num_labels: int = 0) -> Graph:
    """Watts–Strogatz ring with rewiring — high structural locality, the
    regime where the paper's APCT beats the random-graph cost model."""
    rng = np.random.default_rng(seed)
    edges = []
    for off in range(1, k // 2 + 1):
        u = np.arange(n)
        v = (u + off) % n
        rewire = rng.random(n) < beta
        v = np.where(rewire, rng.integers(0, n, n), v)
        edges.append(np.stack([u, v], 1))
    labels = rng.integers(0, num_labels, n) if num_labels else None
    return Graph(n, np.concatenate(edges), labels)


def triangle_rich(n: int, communities: int, seed: int = 0,
                  num_labels: int = 0) -> Graph:
    """Clustered graph (dense communities + sparse bridges): a proxy for
    CiteSeer/MiCo-like locality used in the cost-model experiments."""
    rng = np.random.default_rng(seed)
    size = max(n // communities, 3)
    edges = []
    for ci in range(communities):
        lo = ci * size
        hi = min(lo + size, n)
        verts = np.arange(lo, hi)
        if len(verts) < 2:
            continue
        # dense-ish intra-community
        k = min(len(verts) * 3, len(verts) * (len(verts) - 1) // 2)
        u = rng.choice(verts, k)
        v = rng.choice(verts, k)
        edges.append(np.stack([u, v], 1))
    bridges = rng.integers(0, n, size=(n // 4 + 1, 2))
    edges.append(bridges)
    labels = rng.integers(0, num_labels, n) if num_labels else None
    return Graph(n, np.concatenate(edges), labels)
