"""Graph substrate: edge lists -> CSR + dense (tile-padded) adjacency.

The tensorized counting engine contracts over the dense adjacency (padded
to multiples of 128 for MXU tiling); CSR backs the sampling primitives
(APCT profiling) and host-side materialisation.  Vertex labels are kept as
per-label indicator vectors; N(v, l) of the paper's labelled CSR becomes
label-partitioned adjacency slices A ⊙ L_l.
"""
from __future__ import annotations

import numpy as np

TILE = 128


class Graph:
    """Undirected simple graph (dedup'd edges, no self loops)."""

    def __init__(self, num_vertices: int, edges: np.ndarray,
                 labels: np.ndarray | None = None):
        edges = np.asarray(edges, np.int64).reshape(-1, 2)
        # canonicalise: undirected, dedup, no self-loops
        u = np.minimum(edges[:, 0], edges[:, 1])
        v = np.maximum(edges[:, 0], edges[:, 1])
        keep = u != v
        uv = np.unique(np.stack([u[keep], v[keep]], 1), axis=0)
        self.n = int(num_vertices)
        self.edges = uv                                   # (E, 2) u < v
        self.m = len(uv)
        self.labels = (np.asarray(labels, np.int32)
                       if labels is not None else None)
        self.num_labels = (int(self.labels.max()) + 1
                           if self.labels is not None and self.n else 0)
        self._csr = None
        self._dense = None

    def invalidate_signature(self):
        """Drop every content-derived memo after an in-place mutation of
        ``edges``/``labels``: the ``_plan_signature`` content hash set by
        ``repro.compiler.cache.graph_signature`` (the plan cache and the
        morph ``CountStore`` key exact results by it — a stale one would
        serve the pre-mutation graph's plans and counts) plus the CSR
        and dense-adjacency caches.  The evolving-graph path must call
        this on every applied delta."""
        if hasattr(self, "_plan_signature"):
            del self._plan_signature
        self._csr = None
        self._dense = None

    # -- CSR ---------------------------------------------------------------
    @property
    def csr(self):
        if self._csr is None:
            deg = np.zeros(self.n, np.int64)
            np.add.at(deg, self.edges[:, 0], 1)
            np.add.at(deg, self.edges[:, 1], 1)
            offs = np.zeros(self.n + 1, np.int64)
            np.cumsum(deg, out=offs[1:])
            nbrs = np.zeros(2 * self.m, np.int64)
            fill = offs[:-1].copy()
            for a, b in self.edges:
                nbrs[fill[a]] = b
                fill[a] += 1
                nbrs[fill[b]] = a
                fill[b] += 1
            for i in range(self.n):                       # sorted rows
                nbrs[offs[i]:offs[i + 1]].sort()
            self._csr = (offs, nbrs)
        return self._csr

    @property
    def degrees(self):
        offs, _ = self.csr
        return np.diff(offs)

    def neighbors(self, v: int) -> np.ndarray:
        offs, nbrs = self.csr
        return nbrs[offs[v]:offs[v + 1]]

    def has_edge(self, u: int, v: int) -> bool:
        nb = self.neighbors(u)
        i = np.searchsorted(nb, v)
        return i < len(nb) and nb[i] == v

    # -- dense adjacency ----------------------------------------------------
    @property
    def n_padded(self) -> int:
        return max(TILE, ((self.n + TILE - 1) // TILE) * TILE)

    def dense_adjacency(self, dtype=np.float32, pad: bool = True) -> np.ndarray:
        key = (np.dtype(dtype), pad)
        if self._dense is None or self._dense[0] != key:
            n = self.n_padded if pad else self.n
            a = np.zeros((n, n), dtype)
            a[self.edges[:, 0], self.edges[:, 1]] = 1
            a[self.edges[:, 1], self.edges[:, 0]] = 1
            self._dense = (key, a)
        return self._dense[1]

    def label_indicators(self, dtype=np.float32, pad: bool = True) -> np.ndarray:
        """(num_labels, N) one-hot vertex-label indicators."""
        assert self.labels is not None
        n = self.n_padded if pad else self.n
        out = np.zeros((self.num_labels, n), dtype)
        out[self.labels, np.arange(self.n)] = 1
        return out

    # -- misc ----------------------------------------------------------------
    def subgraph_sample_edges(self, max_edges: int, seed: int = 0) -> "Graph":
        """Random edge sampling for the APCT profile graph (paper §4.2)."""
        if self.m <= max_edges:
            return self
        rng = np.random.default_rng(seed)
        idx = rng.choice(self.m, size=max_edges, replace=False)
        return Graph(self.n, self.edges[idx],
                     self.labels if self.labels is not None else None)

    def __repr__(self):
        return f"Graph(n={self.n}, m={self.m}, labels={self.num_labels or None})"
