"""Packed-bitset neighbour intersection Pallas kernel (VPU).

The direct TPU analogue of the paper's set-intersection inner loop: for a
batch of vertex pairs, AND their packed uint32 neighbour bitsets and
popcount — common-neighbour counts per edge (per-edge triangle counts).
Runs on the VPU (no MXU): bitwise ops + SWAR popcount, grid over row
blocks so each block's working set sits in VMEM.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def _popcount32(v):
    v = v - ((v >> 1) & 0x55555555)
    v = (v & 0x33333333) + ((v >> 2) & 0x33333333)
    v = (v + (v >> 4)) & 0x0F0F0F0F
    return (v * 0x01010101) >> 24


def _kernel(a_ref, b_ref, out_ref):
    x = a_ref[...] & b_ref[...]
    # popcount stays uint32; the output ref is int32
    out_ref[...] = jnp.sum(_popcount32(x).astype(jnp.int32),
                           axis=1, keepdims=True)


def bitset_intersect(rows_a, rows_b, *, block: int = 256,
                     interpret: bool = False):
    """rows_a, rows_b: (E, W) uint32 packed bitsets -> (E,) int32 popcounts
    of the per-row intersection."""
    E, W = rows_a.shape
    assert rows_b.shape == (E, W)
    block = min(block, E)
    assert E % block == 0, (E, block)
    out = pl.pallas_call(
        _kernel,
        grid=(E // block,),
        in_specs=[
            pl.BlockSpec((block, W), lambda i: (i, 0)),
            pl.BlockSpec((block, W), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((E, 1), jnp.int32),
        interpret=interpret,
    )(rows_a.astype(jnp.uint32), rows_b.astype(jnp.uint32))
    return out[:, 0]


def pack_bitsets(adj_bool: np.ndarray) -> np.ndarray:
    """(N, N) boolean adjacency -> (N, ceil(N/32)) uint32 packed rows."""
    n = adj_bool.shape[1]
    W = (n + 31) // 32
    pad = np.zeros((adj_bool.shape[0], W * 32), np.uint8)
    pad[:, :n] = adj_bool.astype(np.uint8)
    bits = pad.reshape(adj_bool.shape[0], W, 32)
    weights = (1 << np.arange(32, dtype=np.uint64)).astype(np.uint32)
    return (bits.astype(np.uint32) * weights[None, None, :]).sum(
        axis=2, dtype=np.uint32)
