"""Blocked causal flash-attention Pallas kernel.

Online-softmax attention with the (bq, bk) score tile resident in
VMEM/registers only — removing the score-tensor HBM round-trips that
dominate the memory roofline term of the XLA scan lowering (§Perf).
Layout: (BH, S, D) with one batch*head per grid row; fully-masked causal
blocks are skipped.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, out_ref, m_ref, l_ref, acc_ref, *,
            scale: float, n_k: int, causal: bool, bq: int, bk: int):
    qi, ki = pl.program_id(1), pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    needed = (not causal) or (ki * bk <= qi * bq + bq - 1)

    @pl.when(needed)
    def _step():
        q = q_ref[0].astype(jnp.float32) * scale          # (bq, D)
        k = k_ref[0].astype(jnp.float32)                  # (bk, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32,
                                                      (bq, bk), 0)
            kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32,
                                                      (bq, bk), 1)
            mask = qpos >= kpos
            s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        if causal:
            p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v_ref[0].astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == n_k - 1)
    def _done():
        out_ref[0] = (acc_ref[...] /
                      jnp.maximum(l_ref[...], 1e-20)).astype(out_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, bq: int = 128,
                    bk: int = 128, interpret: bool = False):
    """q, k, v: (BH, S, D) -> (BH, S, D)."""
    BH, Sq, D = q.shape
    Skv = k.shape[1]
    bq, bk = min(bq, Sq), min(bk, Skv)
    assert Sq % bq == 0 and Skv % bk == 0
    n_k = Skv // bk
    scale = 1.0 / math.sqrt(D)
    return pl.pallas_call(
        functools.partial(_kernel, scale=scale, n_k=n_k, causal=causal,
                          bq=bq, bk=bk),
        grid=(BH, Sq // bq, n_k),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
