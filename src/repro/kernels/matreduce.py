"""Fused masked-matmul-and-reduce Pallas kernel:
total = Σ_{i,j} mask[i,j] · (lhs @ rhsᵀ)[i,j].

The final contraction step of a counting plan (e.g. triangle count
= Σ A ⊙ (A@A)); fusing the reduction keeps the (M,N) product entirely in
VMEM — it is never materialised to HBM.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(lhs_ref, rhs_ref, mask_ref, out_ref, acc_ref):
    i, j, k = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    first = (i == 0) & (j == 0) & (k == 0)

    @pl.when(first)
    def _init():
        acc_ref[0, 0] = jnp.float32(0.0)

    prod = jax.lax.dot_general(lhs_ref[...], rhs_ref[...],
                               (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)
    acc_ref[0, 0] += jnp.sum(prod * mask_ref[...].astype(jnp.float32))

    # write-out every step (sequential grid on TPU): last value wins
    out_ref[0, 0] = acc_ref[0, 0]


def matreduce(lhs, rhs, mask, *, bm: int = 128, bn: int = 128,
              bk: int = 128, interpret: bool = False):
    """Σ mask ⊙ (lhs @ rhsᵀ): lhs (M,K), rhs (N,K), mask (M,N) -> f32 scalar.

    NOTE: with a K-grid the per-(i,j) product tile is partial, so the mask
    must be applied to partial products — valid because the mask is
    multiplicative and the reduction is a sum: Σ_k mask⊙P_k = mask⊙Σ_k P_k.
    """
    M, K = lhs.shape
    N = rhs.shape[0]
    assert rhs.shape[1] == K and mask.shape == (M, N)
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0
    out = pl.pallas_call(
        _kernel,
        grid=(M // bm, N // bn, K // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bk), lambda i, j, k: (j, k)),
            pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, 1), jnp.float32)],
        interpret=interpret,
    )(lhs, rhs, mask)
    return out[0, 0]
