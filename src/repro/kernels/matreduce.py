"""Fused masked-reduce Pallas kernels for counting-plan contractions.

Two primitives live here:

``matreduce``    total = Σ_{i,j} mask[i,j] · (lhs @ rhsᵀ)[i,j] — the final
                 contraction step of a counting plan (e.g. triangle count
                 = Σ A ⊙ (A@A)); fusing the reduction keeps the (M,N)
                 product entirely in VMEM, never materialised to HBM.

``prod_reduce``  the k-factor masked product-reduce behind the compiler's
                 ``CutJoin`` op: Σ_{x,y} [x≠y] · Π_i F_i[x,y] over stacked
                 2-D factor tensors (|cut| = 2), or Σ_x Π_i F_i[x] for 1-D
                 factors (|cut| = 1, no mask needed — a single cut vertex
                 is always injective).  The off-diagonal injectivity mask
                 is derived *in-kernel* from tile indices (broadcasted
                 iotas offset by the grid position), so no O(n²) mask is
                 ever built.  Each 2-D grid tile writes a row of per-
                 column f32 partials (each accumulating bm cells; 1-D
                 chunks write one bn-cell scalar); the host reduces the
                 partials in f64, so integer counts stay exact as long as
                 every chunk partial fits f32's 2^24 integer range —
                 ``exact_block`` picks the chunk size that provably does.

``tri_reduce``   the |cut| = 3 tier: Σ_{x≠y, y≠z, x≠z} Π_i F_i over a
                 3-D tile grid, where each factor touches a *subset* of
                 the three cut axes — (n,) vectors, (n, n) pair tensors
                 (the common case: an axis-subset decomposition factor
                 spans only the cut vertices its subpattern contains),
                 or full (n, n, n) tensors.  Factors are stored with
                 size-1 broadcast dims on the axes they miss (a free
                 reshape — nothing is expanded in HBM) and broadcast
                 per (bm, bn, bk) tile inside the kernel; the pairwise-
                 distinct mask comes from three broadcasted tile iotas,
                 so no O(n³) mask is ever materialised.  Each grid tile
                 writes a (bm, bn) sheet of f32 partials, each
                 accumulating bk cells — the same chunk-size bound
                 ``exact_block`` certifies — and the host reduces the
                 (M, N, gk) partial tensor in f64.

``tri_reduce_keep``  the keep-axis |cut| = 3 variant behind 3-cut
                 ``LocalCount`` plans: out[x] = Σ_{y,z} [distinct] ·
                 Π_i F_i — the factors are transposed host-side so the
                 kept axis leads, then the same kernel runs and the host
                 reduces the non-kept partial axes per row in f64.

``prod_reduce_keep``  the keep-axis variant behind ``LocalCount`` plans
                 (the partial-embedding API): out[x] = Σ_{y≠x} Π_i
                 F_i[x, y] — the same masked product but with one cut
                 axis *kept* as the output, reducing only the other.
                 Each grid tile writes its (bm,) per-row f32 partials
                 (each accumulating bn cells, the same bound
                 ``exact_block`` certifies for ``prod_reduce``); the
                 host sums the column-tile partials per row in f64.
                 ``keep=1`` transposes the factors host-side and runs
                 the same kernel.

Both primitives zero-pad their inputs up to the tile multiple, so any
``n`` works; padding is value-preserving because padded mask / factor
entries are zero and the reduction is a sum.

**Global index offsets.**  Every masked kernel takes a small int32
``offsets`` vector (one entry per grid axis, default zeros) that is
added to the tile iotas before the injectivity comparison: a caller
holding only a *slice* of the factor tensors — one device's block of
cut axis 0 under the mesh tier (``distributed/cutjoin.py``) — passes
its global start index so ``rows == cols`` still compares global cut
vertices, not slice-local positions.  Offsets ride as a tiny array
input (they may be traced values, e.g. ``axis_index * block`` inside
``shard_map``), replicated to every tile by its BlockSpec.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _pad_to(x, multiples):
    """Zero-pad every axis of ``x`` up to the matching tile multiple."""
    pads = [(0, (-s) % m) for s, m in zip(x.shape, multiples)]
    if any(p for _, p in pads):
        x = jnp.pad(x, pads)
    return x


# -- matreduce: Σ mask ⊙ (lhs @ rhsᵀ) ---------------------------------------------

def _matreduce_kernel(lhs_ref, rhs_ref, mask_ref, out_ref, acc_ref):
    i, j, k = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    first = (i == 0) & (j == 0) & (k == 0)

    @pl.when(first)
    def _init():
        acc_ref[0, 0] = jnp.float32(0.0)

    prod = jax.lax.dot_general(lhs_ref[...], rhs_ref[...],
                               (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)
    acc_ref[0, 0] += jnp.sum(prod * mask_ref[...].astype(jnp.float32))

    # write-out every step (sequential grid on TPU): last value wins
    out_ref[0, 0] = acc_ref[0, 0]


def matreduce(lhs, rhs, mask, *, bm: int = 128, bn: int = 128,
              bk: int = 128, interpret: bool = False):
    """Σ mask ⊙ (lhs @ rhsᵀ): lhs (M,K), rhs (N,K), mask (M,N) -> f32 scalar.

    Inputs are zero-padded to the tile multiple (count-preserving: padded
    mask entries are zero), so arbitrary shapes work.

    NOTE: with a K-grid the per-(i,j) product tile is partial, so the mask
    must be applied to partial products — valid because the mask is
    multiplicative and the reduction is a sum: Σ_k mask⊙P_k = mask⊙Σ_k P_k.
    """
    M, K = lhs.shape
    N = rhs.shape[0]
    assert rhs.shape[1] == K and mask.shape == (M, N)
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    lhs = _pad_to(lhs, (bm, bk))
    rhs = _pad_to(rhs, (bn, bk))
    mask = _pad_to(mask, (bm, bn))
    (M, K), N = lhs.shape, rhs.shape[0]
    out = pl.pallas_call(
        _matreduce_kernel,
        grid=(M // bm, N // bn, K // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bk), lambda i, j, k: (j, k)),
            pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, 1), jnp.float32)],
        interpret=interpret,
    )(lhs, rhs, mask)
    return out[0, 0]


# -- prod_reduce: Σ over (injective) index tuples of Π_i F_i ----------------------

def _pairjoin_kernel(stack_ref, off_ref, out_ref, *, nf, masked, bm, bn):
    """One (bm, bn) tile of Σ [x≠y] · Π_i F_i[x, y]: product over the
    factor axis, injectivity mask from tile indices (offset to global
    coordinates), one row of per-column f32 partials (each bounded by
    max|Π F| · bm — finer chunks than a per-tile scalar, so large tiles
    stay exact on integers)."""
    i, j = pl.program_id(0), pl.program_id(1)
    prod = stack_ref[0, ...]
    for f in range(1, nf):
        prod = prod * stack_ref[f, ...]
    if masked:
        rows = jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 0) \
            + i * bm + off_ref[0]
        cols = jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 1) \
            + j * bn + off_ref[1]
        prod = jnp.where(rows == cols, jnp.float32(0.0), prod)
    out_ref[0, :] = jnp.sum(prod, axis=0)


def _vecjoin_kernel(stack_ref, out_ref, *, nf):
    """One bn-wide chunk of Σ_x Π_i F_i[x] (the |cut| = 1 fast path)."""
    prod = stack_ref[0, ...]
    for f in range(1, nf):
        prod = prod * stack_ref[f, ...]
    out_ref[0, 0] = jnp.sum(prod)


@functools.partial(jax.jit,
                   static_argnames=("distinct", "bm", "bn", "interpret"))
def _pairjoin_tiles(stack, offsets, *, distinct, bm, bn, interpret):
    k, M, N = stack.shape
    grid = (M // bm, N // bn)
    kern = functools.partial(_pairjoin_kernel, nf=k, masked=distinct,
                             bm=bm, bn=bn)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[pl.BlockSpec((k, bm, bn), lambda i, j: (0, i, j)),
                  pl.BlockSpec((2,), lambda i, j: (0,))],
        out_specs=pl.BlockSpec((1, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((grid[0], N), jnp.float32),
        interpret=interpret,
    )(stack, offsets)


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def _vecjoin_tiles(stack, *, bn, interpret):
    k, N = stack.shape
    grid = (N // bn,)
    return pl.pallas_call(
        functools.partial(_vecjoin_kernel, nf=k),
        grid=grid,
        in_specs=[pl.BlockSpec((k, bn), lambda j: (0, j))],
        out_specs=pl.BlockSpec((1, 1), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, grid[0]), jnp.float32),
        interpret=interpret,
    )(stack)


def _pairjoin_keep_kernel(stack_ref, off_ref, out_ref, *, nf, masked,
                          bm, bn):
    """One (bm, bn) tile of the keep-axis join: per-row partials
    out[x] = Σ_y [x≠y] · Π_i F_i[x, y] over this tile's columns.  Each
    partial accumulates bn cells — the same chunk bound ``exact_block``
    certifies — and the host reduces the per-tile rows in f64."""
    i, j = pl.program_id(0), pl.program_id(1)
    prod = stack_ref[0, ...]
    for f in range(1, nf):
        prod = prod * stack_ref[f, ...]
    if masked:
        rows = jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 0) \
            + i * bm + off_ref[0]
        cols = jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 1) \
            + j * bn + off_ref[1]
        prod = jnp.where(rows == cols, jnp.float32(0.0), prod)
    out_ref[:, 0] = jnp.sum(prod, axis=1)


@functools.partial(jax.jit,
                   static_argnames=("distinct", "bm", "bn", "interpret"))
def _pairjoin_keep_tiles(stack, offsets, *, distinct, bm, bn, interpret):
    k, M, N = stack.shape
    grid = (M // bm, N // bn)
    kern = functools.partial(_pairjoin_keep_kernel, nf=k, masked=distinct,
                             bm=bm, bn=bn)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[pl.BlockSpec((k, bm, bn), lambda i, j: (0, i, j)),
                  pl.BlockSpec((2,), lambda i, j: (0,))],
        out_specs=pl.BlockSpec((bm, 1), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, grid[1]), jnp.float32),
        interpret=interpret,
    )(stack, offsets)


def _offsets_or_zero(offsets, naxes: int):
    """Normalise a per-axis global-offset vector (None -> zeros).  The
    entries may be traced (``shard_map`` passes ``axis_index``-derived
    starts), so everything downstream treats this as array data."""
    if offsets is None:
        return jnp.zeros((naxes,), jnp.int32)
    off = jnp.asarray(offsets, jnp.int32)
    assert off.shape == (naxes,), (off.shape, naxes)
    return off


def prod_reduce_keep(factors, *, keep: int = 0, distinct: bool = True,
                     bm: int = 128, bn: int = 128,
                     interpret: bool = False,
                     offsets=None) -> np.ndarray:
    """Keep-axis masked product-reduce over (n, n) factors:

        keep=0:  out[x] = Σ_y [x≠y] · Π_i F_i[x, y]
        keep=1:  out[y] = Σ_x [x≠y] · Π_i F_i[x, y]

    The anchored partial-embedding read off a |cut| = 2 decomposition
    join: one cut axis survives as the output vector, the other is
    reduced in-kernel under the same tile-index injectivity mask as
    ``prod_reduce`` — still nothing O(n²) materialised beyond the factor
    tensors the caller already holds.  Factors are cast to f32 and
    zero-padded to the tile multiple (padding adds zero cells to real
    rows and zero rows beyond n, both harmless); per-tile f32 row
    partials are summed across column tiles on the host in f64 — exact
    for integer factors while each bn-cell partial stays below 2^24,
    which ``exact_block`` certifies (the guard is identical: both
    kernels chunk the same per-partial cell count).  ``offsets`` gives
    the factors' global start index per *original* cut axis (sliced
    callers only — see the module docstring); the swap below reorders
    it alongside the axes.
    """
    stack = jnp.stack([jnp.asarray(F, jnp.float32) for F in factors])
    assert stack.ndim == 3        # rectangular slices legal (sharded rows)
    assert keep in (0, 1)
    off = _offsets_or_zero(offsets, 2)
    if keep == 1:
        stack = jnp.swapaxes(stack, 1, 2)    # same kernel, rows <-> cols
        off = off[::-1]
    n = stack.shape[1]
    b = min(bm, bn, max(min(n, stack.shape[2]), 1))
    stack = _pad_to(stack, (1, b, b))
    tiles = _pairjoin_keep_tiles(stack, off, distinct=distinct, bm=b, bn=b,
                                 interpret=interpret)
    return np.asarray(tiles, np.float64).sum(axis=1)[:n]


# -- tri_reduce: the |cut| = 3 tiled tri-join --------------------------------------

def _trijoin_kernel(*refs, nf, masked, bm, bn, bk):
    """One (bm, bn, bk) tile of Σ [x,y,z pairwise distinct] · Π_i F_i.
    Factor tiles carry size-1 dims on absent axes and broadcast against
    the full tile shape (never expanded in memory); the pairwise-
    distinct mask is three tile-iota comparisons, each offset to global
    coordinates.  The tile writes a (bm, bn) sheet of f32 partials,
    each accumulating bk cells — the chunk bound ``exact_block``
    certifies."""
    out_ref = refs[-1]
    off_ref = refs[-2]
    prod = refs[0][...]
    for f in range(1, nf):
        prod = prod * refs[f][...]
    if masked:
        i, j, k = pl.program_id(0), pl.program_id(1), pl.program_id(2)
        shape = (bm, bn, bk)
        x = jax.lax.broadcasted_iota(jnp.int32, shape, 0) + i * bm \
            + off_ref[0]
        y = jax.lax.broadcasted_iota(jnp.int32, shape, 1) + j * bn \
            + off_ref[1]
        z = jax.lax.broadcasted_iota(jnp.int32, shape, 2) + k * bk \
            + off_ref[2]
        bad = (x == y) | (x == z) | (y == z)
        prod = jnp.where(bad, jnp.float32(0.0), prod)
    else:
        prod = jnp.broadcast_to(prod, (bm, bn, bk))
    out_ref[:, :, 0] = jnp.sum(prod, axis=2)


@functools.partial(jax.jit,
                   static_argnames=("present", "distinct", "bm", "bn",
                                    "bk", "interpret"))
def _trijoin_tiles(*stack, offsets, present, distinct, bm, bn, bk,
                   interpret):
    """``stack``: one 3-D array per factor, shape (M|1, N|1, K|1) with
    size-1 dims on the axes ``present[f]`` misses.  Returns the (M, N,
    gk) f32 partial tensor (gk = K // bk column-tile partials)."""
    M = max(s.shape[0] for s in stack)
    N = max(s.shape[1] for s in stack)
    K = max(s.shape[2] for s in stack)
    grid = (M // bm, N // bn, K // bk)

    def spec(axes):
        block = (bm if 0 in axes else 1, bn if 1 in axes else 1,
                 bk if 2 in axes else 1)
        return pl.BlockSpec(
            block, lambda i, j, k, axes=axes: (i if 0 in axes else 0,
                                               j if 1 in axes else 0,
                                               k if 2 in axes else 0))

    kern = functools.partial(_trijoin_kernel, nf=len(stack),
                             masked=distinct, bm=bm, bn=bn, bk=bk)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[spec(axes) for axes in present] +
                 [pl.BlockSpec((3,), lambda i, j, k: (0,))],
        out_specs=pl.BlockSpec((bm, bn, 1), lambda i, j, k: (i, j, k)),
        out_shape=jax.ShapeDtypeStruct((M, N, grid[2]), jnp.float32),
        interpret=interpret,
    )(*stack, offsets)


def _tri_normalise(factors, axes, n: int, b: int):
    """Cast each factor to f32, reshape to 3-D with size-1 dims on its
    absent axes (a free view — axis-subset factors are broadcast per
    tile, never expanded), zero-pad present axes to the tile multiple,
    and inject a ones-vector on any axis no factor covers (zero-padded,
    so padding never contributes even on uncovered axes)."""
    covered = set()
    stacked, present = [], []
    for F, ax in zip(factors, axes):
        ax = tuple(ax)
        assert ax == tuple(sorted(set(ax))) and set(ax) <= {0, 1, 2}
        F = jnp.asarray(F, jnp.float32)
        assert F.ndim == len(ax) and all(s == n for s in F.shape), \
            (F.shape, ax, n)
        covered |= set(ax)
        shape = tuple(n if a in ax else 1 for a in range(3))
        F = F.reshape(shape)
        F = _pad_to(F, tuple(b if a in ax else 1 for a in range(3)))
        stacked.append(F)
        present.append(ax)
    for a in sorted({0, 1, 2} - covered):
        ones = _pad_to(jnp.ones((n,), jnp.float32), (b,))
        shape = tuple(-1 if x == a else 1 for x in range(3))
        stacked.append(ones.reshape(shape))
        present.append((a,))
    return stacked, tuple(present)


def tri_reduce(factors, axes, *, n: int, distinct: bool = True,
               bm: int = 128, bn: int = 128, bk: int = 128,
               interpret: bool = False, offsets=None) -> float:
    """Σ over (pairwise-distinct) index triples of Π_i F_i, where factor
    i spans only the cut axes ``axes[i]`` (a sorted subset of (0, 1, 2))
    and broadcasts along the rest.

    The |cut| = 3 decomposition join.  The injectivity mask is derived
    in-kernel from tile indices — nothing O(n³) is materialised beyond
    whatever genuinely 3-D factors the caller already holds; axis-subset
    factors stay at their own size.  Per-tile (bm, bn) f32 partials each
    accumulate bk cells, so ``exact_block`` certifies the same chunk
    bound as the pair tier with b = bk; the host reduces the partial
    tensor in f64.  ``offsets`` gives the factors' global start index
    per cut axis (sliced callers only)."""
    b = min(bm, bn, bk, max(n, 1))
    stacked, present = _tri_normalise(factors, axes, n, b)
    tiles = _trijoin_tiles(*stacked, offsets=_offsets_or_zero(offsets, 3),
                           present=present, distinct=distinct,
                           bm=b, bn=b, bk=b, interpret=interpret)
    return float(np.asarray(tiles, np.float64).sum())


def tri_reduce_keep(factors, axes, *, keep: int, n: int,
                    distinct: bool = True, bm: int = 128, bn: int = 128,
                    bk: int = 128, interpret: bool = False,
                    offsets=None) -> np.ndarray:
    """Keep-axis tri-join: out[w] = Σ over the other two (pairwise-
    distinct) axes of Π_i F_i — the anchored partial-embedding vector of
    a |cut| = 3 plan.  ``keep`` picks the surviving axis; factors are
    transposed host-side so it leads (free for axis-subset factors —
    only their axis labels move), then the same kernel runs and the
    host sums the non-kept partial axes per row in f64.  ``offsets``
    gives the factors' global start index per *original* cut axis; the
    permutation below reorders it alongside the axes."""
    assert keep in (0, 1, 2)
    perm = (keep,) + tuple(a for a in range(3) if a != keep)
    rank = {a: i for i, a in enumerate(perm)}
    paxes = []
    pfactors = []
    for F, ax in zip(factors, axes):
        ax = tuple(ax)
        new = tuple(sorted(rank[a] for a in ax))
        order = tuple(ax.index(perm[a]) for a in new)
        pfactors.append(np.transpose(np.asarray(F), order)
                        if order != tuple(range(len(ax))) else F)
        paxes.append(new)
    off = _offsets_or_zero(offsets, 3)[jnp.asarray(perm)]
    b = min(bm, bn, bk, max(n, 1))
    stacked, present = _tri_normalise(pfactors, paxes, n, b)
    tiles = _trijoin_tiles(*stacked, offsets=off, present=present,
                           distinct=distinct, bm=b, bn=b, bk=b,
                           interpret=interpret)
    return np.asarray(tiles, np.float64).sum(axis=(1, 2))[:n]


EXACT_LIMIT = float(1 << 24)                 # f32 exact-integer range


def exact_block(factors, max_block: int = 1024, min_block: int = 8,
                maxes=None):
    """Largest power-of-two chunk size whose f32 partial sums stay exact
    for integer-valued ``factors``.  A chunk accumulates ``b`` cells
    (per-column partials of a (b, bn) tile for 2-D factors, one bn-wide
    scalar for 1-D, the bk depth of one (bm, bn) partial sheet for the
    tri tier), so every partial is an integer bounded by
    (Π_i max|F_i|) · b, and integers up to 2^24 are exactly
    representable in f32.  ``maxes`` supplies precomputed per-factor max
    magnitudes (serving plans cache them — see ``CompiledPlan``) so
    repeated executions skip the full-tensor scan.  Returns None when
    even a ``min_block`` chunk cannot guarantee exactness — callers
    should take an f64 path instead."""
    maxprod = 1.0
    if maxes is None:
        maxes = [float(np.abs(np.asarray(F)).max()) for F in factors]
    for m in maxes:
        maxprod *= float(m)
    b = max_block
    while b >= min_block:
        if maxprod * b <= EXACT_LIMIT:
            return b
        b //= 2
    return None


def prod_reduce(factors, *, distinct: bool = True, bm: int = 128,
                bn: int = 128, interpret: bool = False,
                offsets=None) -> float:
    """Σ over index tuples of Π_i F_i, factors all (n,) or all (n, n).

    ``distinct`` (2-D only) restricts the sum to off-diagonal cells —
    the |cut| = 2 injectivity constraint — via an in-kernel tile-index
    mask; nothing O(n²) is ever materialised besides the factor tensors
    the caller already holds.  Factors are cast to f32 and zero-padded to
    the tile multiple; chunked f32 partials (per-column for 2-D tiles)
    are reduced on the host in f64 — exact for integer-valued factors
    while each chunk partial stays below 2^24, which ``exact_block``
    certifies for a given factor set.  ``offsets`` gives the factors'
    global start index per cut axis (sliced callers only; the 1-D fast
    path has no mask, so it ignores them).
    """
    stack = jnp.stack([jnp.asarray(F, jnp.float32) for F in factors])
    if stack.ndim == 2:                      # |cut| = 1: vector fast path
        N = stack.shape[1]
        stack = _pad_to(stack, (1, min(bn, max(N, 1))))
        tiles = _vecjoin_tiles(stack, bn=min(bn, stack.shape[1]),
                               interpret=interpret)
    else:
        # rectangular (m, n) slices are legal: a sharded caller holds one
        # device's rows of cut axis 0 and passes their global offset
        assert stack.ndim == 3
        M, N = stack.shape[1], stack.shape[2]
        b = min(bm, bn, max(min(M, N), 1))
        stack = _pad_to(stack, (1, b, b))
        tiles = _pairjoin_tiles(stack, _offsets_or_zero(offsets, 2),
                                distinct=distinct, bm=b, bn=b,
                                interpret=interpret)
    return float(np.asarray(tiles, np.float64).sum())
