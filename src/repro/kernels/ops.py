"""jit'd public wrappers for the Pallas kernels (padding, dtypes, reshapes).

``interpret=None`` auto-selects: real TPU lowering on TPU backends,
interpreter (Python/CPU execution of the kernel body) elsewhere — the
validation mode this container uses.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.kernels import bitset as _bitset
from repro.kernels import flashattn as _fa
from repro.kernels import matreduce as _mr
from repro.kernels import sddmm as _sd


def _auto_interpret(interpret):
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


def _pad2(x, bm, bn):
    M, N = x.shape
    pm, pn = (-M) % bm, (-N) % bn
    if pm or pn:
        x = jnp.pad(x, ((0, pm), (0, pn)))
    return x


def sddmm(lhs, rhs, mask, *, bm=128, bn=128, bk=128, interpret=None):
    M, N = mask.shape
    interpret = _auto_interpret(interpret)
    lhs_p = _pad2(lhs, bm, bk)
    rhs_p = _pad2(rhs, bn, bk)
    mask_p = _pad2(mask, bm, bn)
    out = _sd.sddmm(lhs_p, rhs_p, mask_p, bm=min(bm, lhs_p.shape[0]),
                    bn=min(bn, rhs_p.shape[0]), bk=min(bk, lhs_p.shape[1]),
                    interpret=interpret)
    return out[:M, :N]


def masked_matmul_reduce(lhs, rhs, mask, *, bm=128, bn=128, bk=128,
                         interpret=None):
    interpret = _auto_interpret(interpret)
    lhs_p = _pad2(lhs, bm, bk)
    rhs_p = _pad2(rhs, bn, bk)
    mask_p = _pad2(mask, bm, bn)
    return _mr.matreduce(lhs_p, rhs_p, mask_p, bm=min(bm, lhs_p.shape[0]),
                         bn=min(bn, rhs_p.shape[0]),
                         bk=min(bk, lhs_p.shape[1]), interpret=interpret)


def triangle_count(adj, *, interpret=None):
    """Σ A ⊙ (A@A) / 6 with the product tile kept in VMEM."""
    a = jnp.asarray(adj, jnp.float32)
    return masked_matmul_reduce(a, a, a, interpret=interpret) / 6.0


def cutjoin_reduce(factors, *, distinct=True, bm=None, bn=None,
                   interpret=None, offsets=None) -> float:
    """The decomposition join Σ_{e_c} Π_i M_i(e_c) as a fused kernel.

    ``factors`` is a sequence of equal-shape cut tensors: (n,) vectors for
    |cut| = 1 (``distinct`` is moot — one vertex is always injective) or
    (n, n) matrices for |cut| = 2, where ``distinct`` applies the
    off-diagonal injectivity mask in-kernel from tile indices.  Arbitrary
    ``n`` works (zero-padding to the tile multiple); the result is the
    f64 host-side sum of per-tile f32 partials.  ``offsets`` gives the
    factors' global start index per cut axis when the caller holds only
    a slice (the mesh tier — see ``distributed/cutjoin.py``).

    Default tiles: 128 on TPU (MXU-aligned, VMEM-sized) but 1024 in
    interpret mode, where per-grid-step dispatch dominates and VMEM is
    not a constraint — fewer, larger chunks keep the CPU validation path
    faster than the XLA dense-mask join.
    """
    interpret = _auto_interpret(interpret)
    if bm is None:
        bm = 1024 if interpret else 128
    if bn is None:
        bn = bm
    obs.counter("kernel.calls", op="cutjoin_reduce",
                cut=2 if getattr(factors[0], "ndim", 2) == 2 else 1)
    return _mr.prod_reduce(factors, distinct=distinct, bm=bm, bn=bn,
                           interpret=interpret, offsets=offsets)


def cutjoin_reduce_keep(factors, *, keep=0, distinct=True, bm=None,
                        bn=None, interpret=None,
                        offsets=None) -> np.ndarray:
    """Keep-axis decomposition join: out[x] = Σ_{y≠x} Π_i M_i(x, y) over
    (n, n) cut tensors — the anchored partial-embedding vector of a
    |cut| = 2 plan (``keep`` picks which cut axis survives).  Same
    padding, masking, and chunked f32/f64 exactness story as
    ``cutjoin_reduce``; ``cutjoin_exact_block`` certifies the same chunk
    size for both (each partial accumulates one tile-width of cells).
    """
    interpret = _auto_interpret(interpret)
    if bm is None:
        bm = 1024 if interpret else 128
    if bn is None:
        bn = bm
    obs.counter("kernel.calls", op="cutjoin_reduce_keep", cut=2)
    return _mr.prod_reduce_keep(factors, keep=keep, distinct=distinct,
                                bm=bm, bn=bn, interpret=interpret,
                                offsets=offsets)


def cutjoin_reduce3(factors, axes, *, n, distinct=True, block=None,
                    interpret=None, offsets=None) -> float:
    """The |cut| = 3 decomposition join Σ_{e_c pairwise distinct} Π_i
    M_i(e_c) as a tiled tri-join kernel.

    ``factors[i]`` spans only the cut axes ``axes[i]`` (a sorted subset
    of (0, 1, 2)): (n,) vectors, (n, n) pair tensors, or full (n, n, n)
    tensors.  Axis-subset factors broadcast per tile inside the kernel
    — they are never expanded to 3-D — and the pairwise-distinct mask
    is derived from tile iotas, so nothing O(n³) is materialised beyond
    whatever genuinely 3-D factors the caller already holds.  ``block``
    bounds the per-partial chunk (bk); take it from
    ``cutjoin_exact_block`` so integer counts stay exact.
    """
    interpret = _auto_interpret(interpret)
    if block is None:
        block = 1024 if interpret else 128
    b = min(block, 128) if not interpret else block
    obs.counter("kernel.calls", op="cutjoin_reduce3", cut=3)
    return _mr.tri_reduce(factors, axes, n=n, distinct=distinct,
                          bm=b, bn=b, bk=b, interpret=interpret,
                          offsets=offsets)


def cutjoin_reduce3_keep(factors, axes, *, keep, n, distinct=True,
                         block=None, interpret=None,
                         offsets=None) -> np.ndarray:
    """Keep-axis |cut| = 3 join: out[w] = Σ over the two non-kept cut
    axes (pairwise-distinct triples only) of Π_i M_i — the anchored
    partial-embedding vector of a 3-cut plan.  Same axis-subset
    broadcasting, in-kernel mask, and chunked f32/f64 exactness story
    as ``cutjoin_reduce3``."""
    interpret = _auto_interpret(interpret)
    if block is None:
        block = 1024 if interpret else 128
    b = min(block, 128) if not interpret else block
    obs.counter("kernel.calls", op="cutjoin_reduce3_keep", cut=3)
    return _mr.tri_reduce_keep(factors, axes, keep=keep, n=n,
                               distinct=distinct, bm=b, bn=b, bk=b,
                               interpret=interpret, offsets=offsets)


def runtime_block(block: int, *, interpret=None) -> int:
    """Clamp a statically certified ``exact_block`` chunk to the running
    backend's tile cap (the same 1024-interpret / 128-TPU cap
    ``cutjoin_exact_block`` applies).  Certificates are computed against
    the interpret-mode maximum (``analysis.verify.precertify``); a
    smaller chunk is always at least as exact, so clamping preserves the
    guarantee."""
    cap = 1024 if _auto_interpret(interpret) else 128
    return min(int(block), cap)


def cutjoin_exact_block(factors, *, interpret=None, maxes=None):
    """Chunk size for which ``cutjoin_reduce`` / ``cutjoin_reduce3`` is
    exact on the given integer-valued factors, or None when no f32
    chunking can guarantee it (callers should use an f64 path).
    ``maxes`` passes cached per-factor max magnitudes so serving plans
    skip the device→host factor scan (see ``matreduce.exact_block``).
    """
    cap = 1024 if _auto_interpret(interpret) else 128
    block = _mr.exact_block(factors, max_block=cap, maxes=maxes)
    obs.counter("kernel.exact_block",
                outcome="granted" if block is not None else "refused")
    return block


def common_neighbors(adj_bool: np.ndarray, edges: np.ndarray, *,
                     interpret=None):
    """Per-edge common-neighbour counts via the bitset kernel."""
    packed = _bitset.pack_bitsets(adj_bool)
    rows_a = jnp.asarray(packed[edges[:, 0]])
    rows_b = jnp.asarray(packed[edges[:, 1]])
    E = rows_a.shape[0]
    block = min(256, max(8, E))
    pad = (-E) % block
    if pad:
        z = jnp.zeros((pad, rows_a.shape[1]), rows_a.dtype)
        rows_a = jnp.concatenate([rows_a, z])
        rows_b = jnp.concatenate([rows_b, z])
    out = _bitset.bitset_intersect(rows_a, rows_b, block=block,
                                   interpret=_auto_interpret(interpret))
    return out[:E]


def flash_attention(q, k, v, *, causal=True, bq=128, bk=128,
                    interpret=None):
    """(B, S, H, D) attention via the Pallas kernel."""
    B, Sq, H, D = q.shape
    Skv = k.shape[1]
    interpret = _auto_interpret(interpret)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, Skv, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, Skv, D)
    out = _fa.flash_attention(qf, kf, vf, causal=causal,
                              bq=min(bq, Sq), bk=min(bk, Skv),
                              interpret=interpret)
    return out.reshape(B, H, Sq, D).transpose(0, 2, 1, 3)
