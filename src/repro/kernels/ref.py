"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import math

import jax.numpy as jnp
import numpy as np


def sddmm_ref(lhs, rhs, mask):
    prod = jnp.einsum("mk,nk->mn", lhs.astype(jnp.float32),
                      rhs.astype(jnp.float32))
    return prod * mask.astype(jnp.float32)


def matreduce_ref(lhs, rhs, mask):
    return jnp.sum(sddmm_ref(lhs, rhs, mask))


def bitset_intersect_ref(rows_a, rows_b):
    a = np.asarray(rows_a, np.uint32)
    b = np.asarray(rows_b, np.uint32)
    x = a & b
    # numpy popcount via bit_count (numpy >= 2)
    return x.astype(np.uint32).view(np.uint32)


def bitset_popcount_ref(rows_a, rows_b):
    x = np.bitwise_and(np.asarray(rows_a, np.uint32),
                       np.asarray(rows_b, np.uint32))
    cnt = np.zeros(x.shape[0], np.int32)
    for w in range(x.shape[1]):
        cnt += np.bitwise_count(x[:, w]).astype(np.int32)
    return cnt


def flash_attention_ref(q, k, v, *, causal: bool = True):
    BH, Sq, D = q.shape
    Skv = k.shape[1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(D)
    if causal:
        mask = jnp.tril(jnp.ones((Sq, Skv), bool))
        s = jnp.where(mask[None], s, -1e30)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return jnp.einsum("bqk,bkd->bqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def triangle_count_ref(adj):
    a = jnp.asarray(adj, jnp.float32)
    return jnp.sum(a * (a @ a)) / 6.0
