"""SDDMM Pallas kernel: out = mask ⊙ (lhs @ rhsᵀ).

The wedge-closing hot-spot of tensorised pattern counting (count paths
between endpoints, keep only adjacent pairs).  MXU-tiled: grid
(M/bm, N/bn, K/bk), f32 accumulation in a VMEM scratch, the mask applied
once on the last K step — the product tile never round-trips to HBM,
which is precisely the traffic the XLA lowering pays (see §Perf).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(lhs_ref, rhs_ref, mask_ref, out_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        lhs_ref[...], rhs_ref[...],
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _done():
        out_ref[...] = (acc_ref[...] *
                        mask_ref[...].astype(jnp.float32)
                        ).astype(out_ref.dtype)


def sddmm(lhs, rhs, mask, *, bm: int = 128, bn: int = 128, bk: int = 128,
          interpret: bool = False):
    """lhs (M,K), rhs (N,K), mask (M,N) -> f32 (M,N) = mask ⊙ (lhs @ rhsᵀ)."""
    M, K = lhs.shape
    N = rhs.shape[0]
    assert rhs.shape[1] == K and mask.shape == (M, N)
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    n_k = K // bk
    return pl.pallas_call(
        functools.partial(_kernel, n_k=n_k),
        grid=(M // bm, N // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bk), lambda i, j, k: (j, k)),
            pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(lhs, rhs, mask)
