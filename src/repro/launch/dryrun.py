import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the
# device count on first init).  --host-devices N overrides for CI smokes.
import sys  # noqa: E402

if "--host-devices" in sys.argv:
    _n = sys.argv[sys.argv.index("--host-devices") + 1]
    os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={_n}"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this produces:
  * proof of compile (sharding coherence) on the production mesh,
  * memory_analysis (bytes per device),
  * cost_analysis FLOPs/bytes + parsed collective traffic -> roofline terms.

Results are cached as JSON under benchmarks/results/dryrun/<mesh>/ so the
grid is resumable.  Usage:

  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --summary
"""
import argparse     # noqa: E402
import json         # noqa: E402
import pathlib      # noqa: E402
import time         # noqa: E402
import traceback    # noqa: E402

import jax          # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs.base import (SHAPES, cell_is_applicable, input_specs)  # noqa: E402
from repro.configs.registry import ARCH_IDS, get_config  # noqa: E402
from repro.distributed import hlo_analysis  # noqa: E402
from repro.distributed.meshes import (sharding_ctx, tree_shardings)  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402

RESULTS_DIR = pathlib.Path(__file__).resolve().parents[3] / "benchmarks" / "results" / "dryrun"


# ---------------------------------------------------------------------------
# Per-cell rules (baseline; the autoshard hillclimber perturbs these)
# ---------------------------------------------------------------------------

def rules_for(cfg, shape, overrides=None):
    rules = {}
    if shape.kind == "train":
        # DEFAULT_RULES = FSDP + TP; fine-grained MoE (>=256 experts) gets
        # full-mesh expert parallelism (each device owns whole experts)
        if cfg.moe is not None and cfg.moe.num_experts % 256 == 0:
            rules["experts"] = ("data", "model")
            rules["expert_mlp"] = ()
    else:
        # serving: replicate weights across data (low-latency TP), except
        # MoE experts which are expert-parallel across the whole mesh.
        rules["embed"] = ()
        # MLA: shard the latent-cache dim over model for decode only
        # (hillclimbed: halves decode memory term and HBM/dev on
        # deepseek-v3; hurts prefill where the latent is recomputed)
        rules["lora"] = (("model",) if cfg.mla is not None
                         and shape.kind == "decode" else ())
        if cfg.moe is not None:
            total = 256
            if cfg.moe.num_experts % total == 0:
                rules["experts"] = ("data", "model")
                rules["expert_mlp"] = ()
            else:
                rules["experts"] = ("model",)
                rules["expert_mlp"] = ("data",)
        if shape.name == "long_500k":
            rules["kv_seq"] = ("pod", "data")    # sequence-parallel KV
    rules.update(overrides or {})
    return rules


def default_microbatches(cfg, shape) -> int:
    n = cfg.param_count()
    if n > 100e9:
        return 8
    if n > 10e9:
        return 4
    return 2


def input_axes(cfg, shape):
    ax = {}
    if shape.kind == "train":
        ax["inputs"] = (("batch", "seq", None) if cfg.input_mode == "embeddings"
                        else ("batch", "seq"))
        ax["labels"] = ("batch", "seq")
    elif shape.kind == "prefill":
        ax["inputs"] = (("batch", "seq", None) if cfg.input_mode == "embeddings"
                        else ("batch", "seq"))
    else:
        ax["inputs"] = (("batch", None, None) if cfg.input_mode == "embeddings"
                        else ("batch", None))
        ax["positions"] = ("batch",)
    if cfg.family == "vlm":
        ax["image_embeds"] = ("batch", "img", None)
    return ax


# ---------------------------------------------------------------------------
# Cell construction
# ---------------------------------------------------------------------------

def build_cell(cfg, shape, mesh, rules, microbatches=None):
    """Returns (fn, args, in_shardings, out_shardings, donate_argnums)."""
    from repro.models.transformer import Model, cache_specs
    from repro.serve.engine import make_decode_step, make_prefill_step
    from repro.train import optimizer as opt_mod
    from repro.train.train_step import (abstract_state, make_train_step,
                                        state_axes)

    specs = input_specs(cfg, shape)
    in_ax = input_axes(cfg, shape)
    repl = NamedSharding(mesh, P())

    def shard_of(axes, abstract):
        return tree_shardings(axes, abstract, mesh, rules)

    if shape.kind == "train":
        state_dtype = "bfloat16" if cfg.param_count() > 50e9 else "float32"
        opt_cfg = opt_mod.OptConfig(state_dtype=state_dtype)
        mb = microbatches or default_microbatches(cfg, shape)
        fn = make_train_step(cfg, opt_cfg, microbatches=mb)
        state = abstract_state(cfg, opt_cfg)
        st_sh = shard_of(state_axes(cfg), state)
        batch_keys = [k for k in ("inputs", "labels", "image_embeds")
                      if k in specs]
        batch = {k: specs[k] for k in batch_keys}
        b_sh = {k: shard_of(in_ax[k], batch[k]) for k in batch_keys}
        metrics_sh = {k: repl for k in ("loss", "ce", "lr", "grad_norm")}
        wrapped = lambda state, batch: fn(state, batch)
        return (wrapped, (state, batch), (st_sh, b_sh),
                (st_sh, metrics_sh), (0,))

    model = Model(cfg)
    params = model.abstract_params()
    p_sh = shard_of(model.param_axes(), params)

    if shape.kind == "prefill":
        fn = make_prefill_step(cfg)
        cs, cax = cache_specs(cfg, shape.batch, shape.seq)
        c_sh = shard_of(cax, cs)
        args = [params, specs["inputs"]]
        in_sh = [p_sh, shard_of(in_ax["inputs"], specs["inputs"])]
        if "image_embeds" in specs:
            args.append(specs["image_embeds"])
            in_sh.append(shard_of(in_ax["image_embeds"], specs["image_embeds"]))
        logits_sh = shard_of(("batch", "vocab"),
                             (shape.batch, cfg.vocab_size))
        return (fn, tuple(args), tuple(in_sh), (logits_sh, c_sh), ())

    # decode
    fn = make_decode_step(cfg)
    cs, cax = cache_specs(cfg, shape.batch, shape.seq)
    c_sh = shard_of(cax, cs)
    args = [params, cs, specs["inputs"], specs["positions"]]
    in_sh = [p_sh, c_sh,
             shard_of(in_ax["inputs"], specs["inputs"]),
             shard_of(in_ax["positions"], specs["positions"])]
    if "image_embeds" in specs:
        args.append(specs["image_embeds"])
        in_sh.append(shard_of(in_ax["image_embeds"], specs["image_embeds"]))
    logits_sh = shard_of(("batch", "vocab"), (shape.batch, cfg.vocab_size))
    return (fn, tuple(args), tuple(in_sh), (logits_sh, c_sh), (1,))


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             rule_overrides=None, microbatches=None, tag: str = "",
             save: bool = True):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if not cell_is_applicable(cfg, shape):
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "skipped": "long_500k requires sub-quadratic mixing"}
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = mesh.devices.size
    rules = rules_for(cfg, shape, rule_overrides)

    t0 = time.perf_counter()
    with sharding_ctx(mesh, rules):
        fn, args, in_sh, out_sh, donate = build_cell(
            cfg, shape, mesh, rules, microbatches)
        jf = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                     donate_argnums=donate)
        lowered = jf.lower(*args)
        t_lower = time.perf_counter() - t0
        t0 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0

    mem = compiled.memory_analysis()
    score_dims = ((shape.seq, min(cfg.flash_block, shape.seq))
                  if shape.kind in ("train", "prefill") else None)
    roof = hlo_analysis.analyze(compiled, chips, score_dims=score_dims)
    tokens = shape.batch * (shape.seq if shape.kind == "train" else 1)
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        mf = hlo_analysis.model_flops_train(n_active, tokens)
    else:
        mf = hlo_analysis.model_flops_decode(n_active, tokens)
        if shape.kind == "prefill":
            mf = hlo_analysis.model_flops_decode(
                n_active, shape.batch * shape.seq)
    total_hlo_flops = roof.flops_per_device * chips
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "kind": shape.kind, "chips": chips, "tag": tag,
        "rules": {k: list(v) if isinstance(v, tuple) else v
                  for k, v in rules.items()},
        "microbatches": microbatches,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "params": cfg.param_count(), "active_params": n_active,
        "model_flops": mf,
        "useful_flops_ratio": (mf / total_hlo_flops) if total_hlo_flops else 0,
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_est_bytes": mem.argument_size_in_bytes
            + mem.temp_size_in_bytes + mem.output_size_in_bytes
            - mem.alias_size_in_bytes,
        },
        **roof.as_dict(),
    }
    if save:
        out = RESULTS_DIR / mesh_kind
        out.mkdir(parents=True, exist_ok=True)
        name = f"{arch}__{shape_name}{('__' + tag) if tag else ''}.json"
        (out / name).write_text(json.dumps(rec, indent=1))
    return rec


def fmt_cell(r):
    if "skipped" in r:
        return f"{r['arch']:22s} {r['shape']:12s} SKIP ({r['skipped']})"
    m = r["memory"]["peak_est_bytes"] / 2**30
    return (f"{r['arch']:22s} {r['shape']:12s} {r['mesh']:6s} "
            f"comp={r['t_compute']*1e3:9.2f}ms mem={r['t_memory']*1e3:9.2f}ms "
            f"coll={r['t_collective']*1e3:9.2f}ms dom={r['dominant']:10s} "
            f"useful={r['useful_flops_ratio']:5.1%} hbm/dev={m:6.2f}GiB "
            f"compile={r['compile_s']:.0f}s")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--summary", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--set", action="append", default=[],
                    help="rule override, e.g. --set embed=pod,data")
    ap.add_argument("--host-devices", default="512")
    args = ap.parse_args()

    if args.summary:
        for mk in ("single", "multi"):
            d = RESULTS_DIR / mk
            if not d.exists():
                continue
            print(f"=== mesh: {mk} ===")
            for f in sorted(d.glob("*.json")):
                print(fmt_cell(json.loads(f.read_text())))
        return

    overrides = {}
    for s in args.set:
        k, _, v = s.partition("=")
        overrides[k] = tuple(x for x in v.split(",") if x)

    cells = []
    archs = [args.arch] if args.arch else list(ARCH_IDS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    for a in archs:
        for s in shapes:
            cells.append((a, s))

    failures = []
    for a, s in cells:
        out = (RESULTS_DIR / args.mesh /
               f"{a}__{s}{('__' + args.tag) if args.tag else ''}.json")
        if out.exists() and not args.force:
            print(f"cached {a} {s}")
            continue
        try:
            rec = run_cell(a, s, args.mesh, overrides or None,
                           args.microbatches, args.tag)
            print(fmt_cell(rec), flush=True)
        except Exception as e:  # noqa: BLE001
            failures.append((a, s, repr(e)))
            print(f"FAIL {a} {s}: {e}", flush=True)
            traceback.print_exc()
    if failures:
        print(f"{len(failures)} failures")
        sys.exit(1)
    print("dry-run complete")


if __name__ == "__main__":
    main()
