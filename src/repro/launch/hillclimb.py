import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# (must precede any jax import — see dryrun.py)

"""§Perf hillclimb driver: circulant-tuning layout search per cell.

  PYTHONPATH=src python -m repro.launch.hillclimb \
      --arch qwen3-4b --shape train_4k --mesh single --budget 18

Every evaluation is a full lower+compile+HLO-roofline of the cell; results
land in benchmarks/results/autoshard/ and the search log in
benchmarks/results/hillclimb_<cell>.json.
"""
import argparse     # noqa: E402
import json         # noqa: E402
import pathlib      # noqa: E402

from repro.distributed.autoshard import circulant_autoshard  # noqa: E402

OUT = pathlib.Path(__file__).resolve().parents[3] / "benchmarks" / "results"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--budget", type=int, default=18)
    ap.add_argument("--rounds", type=int, default=2)
    args = ap.parse_args()

    assign, rec, history = circulant_autoshard(
        args.arch, args.shape, args.mesh, max_rounds=args.rounds,
        budget_evals=args.budget)
    log = {
        "arch": args.arch, "shape": args.shape, "mesh": args.mesh,
        "best_assignment": {k: list(v) if isinstance(v, tuple) else v
                            for k, v in assign.items()},
        "best": {k: rec[k] for k in
                 ("t_compute", "t_memory", "t_collective", "dominant",
                  "useful_flops_ratio")},
        "best_memory_gib": rec["memory"]["peak_est_bytes"] / 2**30,
        "history": [({k: list(v) if isinstance(v, tuple) else v
                      for k, v in a.items()}, c) for a, c in history],
    }
    out = OUT / f"hillclimb_{args.arch}__{args.shape}__{args.mesh}.json"
    out.write_text(json.dumps(log, indent=1))
    print(f"wrote {out}")
    print(json.dumps(log["best"], indent=1))


if __name__ == "__main__":
    main()
