"""Production mesh factory.

Defined as a function (never a module-level constant) so importing this
module never touches jax device state.  The dry-run forces 512 host
platform devices before the first jax import; everything else sees the
real device count.
"""
from __future__ import annotations

import jax


def _mesh_kwargs(num_axes: int) -> dict:
    """axis_types only where the installed jax has it (>= 0.5); older
    releases default every axis to Auto anyway."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * num_axes}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_mesh_kwargs(len(axes)))


def make_host_mesh(shape=None, axes=None):
    """Small mesh over whatever devices exist (tests, local runs)."""
    n = len(jax.devices())
    if shape is None:
        shape, axes = (1, n), ("data", "model")
    return jax.make_mesh(shape, axes, **_mesh_kwargs(len(axes)))
