"""Production mesh factory.

Defined as a function (never a module-level constant) so importing this
module never touches jax device state.  The dry-run forces 512 host
platform devices before the first jax import; everything else sees the
real device count.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(shape=None, axes=None):
    """Small mesh over whatever devices exist (tests, local runs)."""
    n = len(jax.devices())
    if shape is None:
        shape, axes = (1, n), ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
