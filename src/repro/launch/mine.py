"""Graph-mining driver: the paper's workloads on synthetic graphs.

  PYTHONPATH=src python -m repro.launch.mine --app motif --k 5 --n 2000
  PYTHONPATH=src python -m repro.launch.mine --app fsm --support 100
  PYTHONPATH=src python -m repro.launch.mine --app chain --k 7
  PYTHONPATH=src python -m repro.launch.mine --app pc --k 7

Counting apps compile the whole pattern set jointly through
``repro.compiler`` (one plan, shared quotient contractions, plan cache);
``--no-compiler`` keeps the legacy per-pattern engine path, and
``--plan-cache DIR`` persists compiled plans across runs.

``--local-counts`` switches to the partial-embedding API (paper §5):
``chain`` prints the hottest vertices by per-vertex embedding
participation, ``pc`` mines pseudo-clique hotspots through anchored
local-count vectors, and ``existence`` takes the factor-level early
exit.
"""
from __future__ import annotations

import argparse
import time


from repro.core.counting import CountingEngine, solve_overlay
from repro.core.engine import MiningEngine
from repro.core.fsm import fsm
from repro.core.motifs import motif_patterns
from repro.core.pattern import chain, pseudo_clique
from repro.graph import generators as gen


def build_graph(args):
    if args.graph == "er":
        return gen.erdos_renyi(args.n, args.deg, seed=args.seed,
                               num_labels=args.labels)
    if args.graph == "rmat":
        import math
        return gen.rmat(max(int(math.ceil(math.log2(args.n))), 4), args.deg,
                        seed=args.seed, num_labels=args.labels)
    if args.graph == "ws":
        return gen.small_world(args.n, int(args.deg), seed=args.seed,
                               num_labels=args.labels)
    return gen.triangle_rich(args.n, max(args.n // 30, 2), seed=args.seed,
                             num_labels=args.labels)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--app", default="motif",
                    choices=["motif", "chain", "pc", "fsm", "existence"])
    ap.add_argument("--k", type=int, default=4)
    ap.add_argument("--graph", default="er",
                    choices=["er", "rmat", "ws", "tri"])
    ap.add_argument("--n", type=int, default=2000)
    ap.add_argument("--deg", type=float, default=8.0)
    ap.add_argument("--labels", type=int, default=0)
    ap.add_argument("--support", type=int, default=100)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-compiler", action="store_true",
                    help="legacy per-pattern engine path (no plan IR)")
    ap.add_argument("--plan-cache", default=None, metavar="DIR",
                    help="persist compiled plans in DIR across runs")
    ap.add_argument("--plan-cache-entries", type=int, default=None,
                    metavar="N", help="cap the on-disk plan store at N "
                    "entries (LRU-by-mtime eviction)")
    ap.add_argument("--local-counts", action="store_true",
                    help="partial-embedding API: per-vertex counts "
                    "(chain), pseudo-clique hotspots (pc), early-exit "
                    "existence")
    ap.add_argument("--top-k", type=int, default=10, metavar="K",
                    help="hottest vertices to report for --local-counts "
                    "(the streaming top-k reader; the full per-vertex "
                    "vector is never returned)")
    ap.add_argument("--verify-plans", action="store_true",
                    help="print the static verifier's report for every "
                         "compiled plan (diagnostics + exact_block "
                         "precertification summary)")
    ap.add_argument("--trace", default=None, metavar="FILE",
                    help="record per-node execution spans on compiled "
                    "plans and write the trace to FILE (JSON; a "
                    "*.chrome.json suffix writes chrome://tracing "
                    "format instead)")
    ap.add_argument("--metrics", action="store_true",
                    help="print the process metrics registry "
                    "(counters/gauges/histograms) after the run")
    ap.add_argument("--mesh", type=int, default=None, metavar="N",
                    help="shard compiled-plan execution over the first N "
                    "devices (1-D data mesh): the adjacency lives "
                    "row-sharded and Contract nodes run as collective "
                    "einsums, CutJoin/LocalCount routes split their cut "
                    "grid — results stay bit-for-bit equal to "
                    "single-device)")
    args = ap.parse_args(argv)

    mesh = None
    if args.mesh is not None and args.mesh > 1:
        from repro.distributed import meshes
        mesh = meshes.data_mesh(args.mesh)
        print(f"mesh: {args.mesh} device(s) on axis 'data'")

    tracer = None
    if args.trace:
        from repro import obs
        tracer = obs.Tracer()

    def verify_report(cp):
        """Re-verify a compiled plan and print the findings — what an
        operator checks when a served count looks off (the compile path
        already verified; this proves the *cached/loaded* plan still
        does)."""
        if not args.verify_plans:
            return
        from repro import analysis
        res = analysis.verify(cp.plan)
        pre = cp.plan.meta.get("precert") or {}
        guarded = sum(1 for n in cp.plan.nodes.values()
                      if getattr(n, "cut_size", 0) and hasattr(n, "factors"))
        print(f"  verify: {'OK' if res.ok else 'FAILED'} — "
              f"{len(cp.plan.nodes)} nodes, {len(res.errors)} error(s), "
              f"{len(res.warnings)} warning(s); "
              f"{len(pre)}/{guarded} join(s) precertified "
              f"(skip the runtime guard scan)")
        for d in res.diagnostics:
            print(f"    {d}")

    if args.app == "fsm" and args.labels == 0:
        args.labels = 6
    g = build_graph(args)
    print(f"graph: {g}")
    t0 = time.perf_counter()

    plan_cache = None
    if args.plan_cache:
        from repro.compiler import PlanCache
        plan_cache = PlanCache(args.plan_cache,
                               max_disk_entries=args.plan_cache_entries)

    if args.app == "motif":
        pats = motif_patterns(args.k)
        if args.no_compiler:
            eng = MiningEngine(g)
            cuts = {p: eng.choose_cut(p) for p in pats}
            table = eng.counter.motif_table(args.k, cuts=cuts)
        else:
            from repro import compiler
            cp = compiler.compile(pats, g, cache=plan_cache, mesh=mesh)
            cp.tracer = tracer
            t_compile = time.perf_counter() - t0
            e = {p: cp.count(p) for p in pats}
            table = solve_overlay(args.k, e)
            print(f"  compiled {len(pats)} patterns -> "
                  f"{len(cp.plan.nodes)} plan nodes "
                  f"({'cache hit' if cp.from_cache else 'cache miss'}, "
                  f"{t_compile:.2f}s)")
            verify_report(cp)
        for p, v in sorted(table.items(), key=lambda t: t[0].m):
            print(f"  {args.k}-motif m={p.m:2d} {sorted(p.edges)}: "
                  f"{v:,.0f}")
    elif args.app == "chain":
        p = chain(args.k)
        hot = None
        if args.no_compiler:
            eng = MiningEngine(g)
            c = eng.get_pattern_count(p, use_compiler=False)
            if args.local_counts:
                from repro.api import vertex_counts
                hot = vertex_counts(p, g, counter=eng.counter,
                                    use_compiler=False, top_k=args.top_k)
        else:
            from repro import compiler
            cp = compiler.compile(p, g, cache=plan_cache,
                                  local=args.local_counts, mesh=mesh)
            cp.tracer = tracer
            verify_report(cp)
            c = cp.count(p)
            if args.local_counts:
                # the top-k reader straight off the plan just compiled
                # — its node-value memo already holds the anchored
                # orbit vectors, so no recompile and no relowering
                from repro.api import plan_vertex_counts, top_vertices
                hot = top_vertices(plan_vertex_counts(cp, p), args.top_k)
        print(f"  {args.k}-chain (edge-induced): {c:,.0f}")
        if hot is not None:
            print("  hottest vertices (embeddings containing u):")
            for v, u in hot:
                print(f"    v{u}: {v:,.0f}")
    elif args.app == "pc":
        if args.local_counts:
            from repro.core.search import mine_pseudo_cliques
            r = mine_pseudo_cliques(g, args.k, missing=1)
            tot = sum(r.totals.values())
            print(f"  {args.k}-pseudo-clique (missing=1) embeddings: "
                  f"{tot:,.0f} across {len(r.totals)} patterns")
            print("  hotspots (participation):")
            for u in r.hotspots[:args.top_k]:
                print(f"    v{u}: {r.per_vertex[u]:,.0f}")
        else:
            from repro.core.cliques import pseudo_clique_count
            total = pseudo_clique_count(g, args.k)
            print(f"  {args.k}-pseudo-clique (k=1) count: {total:,.0f}")
    elif args.app == "existence":
        if args.local_counts:
            from repro import api
            from repro.core.counting import CountingEngine
            from repro.core.pattern import clique
            eng = CountingEngine(g)
            for k in range(3, args.k + 1):
                print(f"  K{k} exists: "
                      f"{api.exists(clique(k), g, counter=eng)}")
        else:
            eng = MiningEngine(g)
            from repro.core.pattern import clique
            for k in range(3, args.k + 1):
                print(f"  K{k} exists: {eng.pattern_exists(clique(k))}")
    elif args.app == "fsm":
        r = fsm(g, args.support, max_vertices=args.k if args.k >= 2 else 3,
                use_compiler=not args.no_compiler, plan_cache=plan_cache)
        print(f"  frequent patterns: {len(r.frequent)} "
              f"(evaluated {r.evaluated}, pruned {r.pruned}; "
              f"{r.compiled_levels}/{r.levels} levels compiled)")
        for p, s in sorted(r.frequent.items(),
                           key=lambda t: (-t[1], t[0].n))[:10]:
            print(f"    support {s}: n={p.n} edges={sorted(p.edges)} "
                  f"labels={p.labels}")
    print(f"done in {time.perf_counter() - t0:.2f}s")
    if tracer is not None:
        if tracer.roots:
            tracer.save(args.trace)
            cov = tracer.coverage()
            print(f"trace: {args.trace} ({len(tracer.roots)} root spans"
                  + (f", node coverage {cov:.1%}" if cov is not None
                     else "") + ")")
        else:
            print(f"trace: no compiled-plan execution to record "
                  f"(--app {args.app}"
                  + (" --no-compiler" if args.no_compiler else "")
                  + " runs off the traced path)")
    if args.metrics:
        from repro import obs
        print("metrics:")
        print(obs.dump(indent=2))


if __name__ == "__main__":
    main()
