"""Batched serving driver: continuous batching over synthetic requests.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --reduced \
      --requests 12 --slots 4
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.base import reduced_config
from repro.configs.registry import get_config
from repro.models.transformer import Model
from repro.serve.batching import ContinuousBatcher, Request


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--capacity", type=int, default=96)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)

    b = ContinuousBatcher(cfg, params, slots=args.slots,
                          capacity=args.capacity)
    for i in range(args.requests):
        T = int(rng.integers(4, 17))
        prompt = rng.integers(0, cfg.vocab_size, T).astype(np.int32)
        b.submit(Request(uid=i, prompt=prompt, max_new_tokens=args.max_new))
    t0 = time.perf_counter()
    steps = b.run_to_completion()
    dt = time.perf_counter() - t0
    tokens = sum(len(r.generated) for r in b.finished)
    print(f"served {len(b.finished)}/{args.requests} requests, "
          f"{tokens} tokens in {steps} engine steps, {dt:.2f}s "
          f"({tokens / max(dt, 1e-9):.1f} tok/s)")
    for r in b.finished[:3]:
        print(f"  req {r.uid}: prompt[{len(r.prompt)}] -> {r.generated}")
    return b


if __name__ == "__main__":
    main()
