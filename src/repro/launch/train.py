"""End-to-end training driver.

Runs any registry config (full or reduced) with the complete substrate:
deterministic data pipeline, microbatched AdamW, async checkpointing,
preemption handling, restart-from-latest, straggler watchdog, optional
gradient compression.  On this CPU container the intended run is the
~130M ``repro-100m`` config:

  PYTHONPATH=src python -m repro.launch.train --arch repro-100m \
      --steps 200 --batch 8 --seq 256 --ckpt-dir /tmp/ck100m
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import reduced_config
from repro.configs.registry import get_config
from repro.train import checkpoint as ckpt
from repro.train import optimizer as opt_mod
from repro.train.data import TokenPipeline
from repro.train.fault_tolerance import PreemptionGuard, StepWatchdog
from repro.train.train_step import init_state, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="repro-100m")
    ap.add_argument("--reduced", action="store_true",
                    help="shrink the config for smoke runs")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    opt_cfg = opt_mod.OptConfig(lr=args.lr, warmup_steps=20,
                                total_steps=max(args.steps, 100))
    step_fn = jax.jit(make_train_step(cfg, opt_cfg, args.microbatches),
                      donate_argnums=(0,))
    pipe = TokenPipeline(cfg.vocab_size, args.seq, args.batch,
                         seed=args.seed)

    state = init_state(cfg, opt_cfg, jax.random.PRNGKey(args.seed))
    start = 0
    writer = None
    if args.ckpt_dir:
        writer = ckpt.AsyncCheckpointer(args.ckpt_dir)
        restored, s = ckpt.restore_latest(args.ckpt_dir, state)
        if restored is not None:
            state, start = restored, s
            print(f"resumed from step {start}")

    n_params = sum(int(np.prod(x.shape))
                   for x in jax.tree.leaves(state["params"]))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M "
          f"tokens/step={args.batch * args.seq}")

    guard = PreemptionGuard()
    watchdog = StepWatchdog()
    losses = []
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(step).items()}
        watchdog.start()
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        dt = watchdog.stop(step)
        losses.append(loss)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d}  loss {loss:.4f}  "
                  f"lr {float(metrics['lr']):.2e}  "
                  f"gnorm {float(metrics['grad_norm']):.2f}  {dt:.2f}s",
                  flush=True)
        if writer and ((step + 1) % args.ckpt_every == 0
                       or guard.requested):
            writer.save(step + 1, state)
            if guard.requested:
                print(f"preempted: saved step {step + 1}, exiting")
                writer.wait()
                return losses
    if writer:
        writer.save(args.steps, state)
        writer.wait()
    if watchdog.straggler_events:
        print(f"straggler steps: {watchdog.straggler_events}")
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})")
    return losses


if __name__ == "__main__":
    main()
