"""Transformer building blocks: norms, RoPE, GQA/flash attention, MLP.

All functions are pure; parameters come in as dict pytrees created from the
spec trees in this module.  Softmax/norm math runs in f32; matmuls run in
the config compute dtype.

Attention uses a per-head (B, S, H, D) layout with KV heads explicitly
expanded to H — H is divisible by the model axis for every assigned arch,
so head tensor-parallelism always shards cleanly (KV-head counts like 8 or
1 would not).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.distributed.meshes import constrain
from repro.models.params import P

NEG_INF = -1e30


def rms_norm(x, scale, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, D) with D even; positions broadcastable to (..., S)."""
    d = x.shape[-1]
    half = d // 2
    inv = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * inv          # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]                              # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def expand_kv(k, H: int):
    """(B, S, KV, D) -> (B, S, H, D) by repeating each KV head H/KV times."""
    KV = k.shape[2]
    if KV == H:
        return k
    k = jnp.repeat(k, H // KV, axis=2)
    return constrain(k, "batch", None, "heads", None)


# ---------------------------------------------------------------------------
# Attention cores (per-head layout)
# ---------------------------------------------------------------------------

def flash_attention(q, k, v, *, causal: bool, block: int,
                    q_positions=None, kv_positions=None, scale=None):
    """Memory-bounded attention: lax.scan over KV blocks, online softmax.

    q: (B, Sq, H, Dq); k: (B, Skv, H, Dq); v: (B, Skv, H, Dv).
    Returns (B, Sq, H, Dv) in q.dtype.  XLA-level counterpart of
    kernels/flashattn.py.
    """
    B, Sq, H, Dq = q.shape
    Skv, Dv = k.shape[1], v.shape[-1]
    scale = scale if scale is not None else 1.0 / math.sqrt(Dq)
    if q_positions is None:
        q_positions = jnp.arange(Sq)
    if kv_positions is None:
        kv_positions = jnp.arange(Skv)
    block = min(block, Skv)
    assert Skv % block == 0, (Skv, block)
    nb = Skv // block

    qf = q.astype(jnp.float32) * scale
    kb = jnp.moveaxis(k.reshape(B, nb, block, H, Dq), 1, 0)
    vb = jnp.moveaxis(v.reshape(B, nb, block, H, Dv), 1, 0)
    pb = kv_positions.reshape(nb, block)

    def body(carry, xs):
        m, l, acc = carry
        kblk, vblk, pblk = xs
        s = jnp.einsum("bqhd,bthd->bqht", qf, kblk.astype(jnp.float32))
        if causal:
            mask = (q_positions[:, None] >= pblk[None, :])[None, :, None, :]
        else:
            mask = jnp.ones((1, 1, 1, block), bool)
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None]) * mask
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bqht,bthd->bqhd", p, vblk.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Sq, H), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, H), jnp.float32)
    a0 = jnp.zeros((B, Sq, H, Dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kb, vb, pb))
    out = acc / jnp.maximum(l, 1e-20)[..., None]
    return out.astype(q.dtype)


def causal_attention(q, k, v, *, flash_block: int, scale=None):
    """Full-sequence causal attention, flash-scanned beyond flash_block."""
    B, S, H, Dq = q.shape
    scale = scale if scale is not None else 1.0 / math.sqrt(Dq)
    if S > flash_block:
        return flash_attention(q, k, v, causal=True, block=flash_block,
                               scale=scale)
    s = jnp.einsum("bqhd,bthd->bqht", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    mask = jnp.tril(jnp.ones((S, S), bool))[None, :, None, :]
    s = jnp.where(mask, s, NEG_INF)
    o = jnp.einsum("bqht,bthd->bqhd", jax.nn.softmax(s, axis=-1),
                   v.astype(jnp.float32))
    return o.astype(q.dtype)


def decode_attention(q, k, v, positions, *, scale=None):
    """q: (B,1,H,Dq) against cache k/v: (B,Sc,H,D*); positions: (B,)."""
    Sc, Dq = k.shape[1], q.shape[-1]
    scale = scale if scale is not None else 1.0 / math.sqrt(Dq)
    s = jnp.einsum("bqhd,bthd->bqht", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    valid = jnp.arange(Sc)[None, :] <= positions[:, None]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    o = jnp.einsum("bqht,bthd->bqhd", jax.nn.softmax(s, axis=-1),
                   v.astype(jnp.float32))
    return o.astype(q.dtype)


def decode_attention_gqa(q, ck, cv, positions, *, groups: int, scale=None):
    """Grouped decode attention WITHOUT expanding the KV cache to H heads:
    q (B,1,H,D) reshaped to (B,KV,G,D) against cache (B,S,KV,D).  The
    cache is read once in its storage dtype (f32 *accumulation* via
    preferred_element_type, no f32 materialisation of the cache) and its
    sharding is pinned so the scan-carried value never gets re-sharded —
    the decode-path fixes measured in §Perf."""
    B, _, H, Dq = q.shape
    Sc = ck.shape[1]
    KV = H // groups
    scale = scale if scale is not None else 1.0 / math.sqrt(Dq)
    ck = ck.reshape(B, Sc, KV, Dq)
    cv = cv.reshape(B, Sc, KV, Dq)
    qg = q.reshape(B, KV, groups, Dq)
    s = jnp.einsum("bkgd,btkd->bkgt", qg, ck,
                   preferred_element_type=jnp.float32) * scale
    valid = jnp.arange(Sc)[None, :] <= positions[:, None]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(cv.dtype)
    o = jnp.einsum("bkgt,btkd->bkgd", p, cv,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, 1, H, Dq).astype(q.dtype)


def cache_update(cache, new, positions):
    """Write (B,1,...) entries into (B,S,...) caches at per-example pos.

    Masked elementwise update (not dynamic_update_slice): every device
    rewrites only its own shard, so the update is collective-free under
    any (batch, seq) sharding — vmap(DUS) made GSPMD all-gather the whole
    cache (§Perf, command-r decode).
    """
    S = cache.shape[1]
    hit = (jnp.arange(S)[None, :] == positions[:, None])      # (B,S)
    hit = hit.reshape(hit.shape + (1,) * (cache.ndim - 2))
    return jnp.where(hit, new.astype(cache.dtype), cache)


# ---------------------------------------------------------------------------
# Self-attention layer (GQA, optional qk-norm)
# ---------------------------------------------------------------------------

def attn_specs(cfg):
    d, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    s = {
        "wq": P((d, H * hd), ("embed", "heads")),
        "wk": P((d, KV * hd), ("embed", "kv")),
        "wv": P((d, KV * hd), ("embed", "kv")),
        "wo": P((H * hd, d), ("heads", "embed")),
    }
    if cfg.qk_norm:
        s["q_norm"] = P((hd,), ("head_dim",), "ones")
        s["k_norm"] = P((hd,), ("head_dim",), "ones")
    return s


def _split_heads(x, n, hd):
    return x.reshape(x.shape[:-1] + (n, hd))


def attention(p, x, cfg, *, positions, mode: str, cache=None):
    """Self-attention for 'train' / 'prefill' / 'decode'.

    Returns (y, new_cache): {} for train, full-sequence KV for prefill,
    updated KV for decode.
    """
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    B, S, _ = x.shape
    q = _split_heads(x @ p["wq"], H, hd)                          # (B,S,H,hd)
    k = _split_heads(x @ p["wk"], KV, hd)
    v = _split_heads(x @ p["wv"], KV, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    rope_pos = positions[:, None] if mode == "decode" else positions
    q = apply_rope(q, rope_pos, cfg.rope_theta)
    k = apply_rope(k, rope_pos, cfg.rope_theta)
    q = constrain(q, "batch", "seq", "heads", None)

    if mode in ("train", "prefill"):
        o = causal_attention(q, expand_kv(k, H), expand_kv(v, H),
                             flash_block=cfg.flash_block)
        o = o.reshape(B, S, H * hd)
        if mode == "prefill":
            flat = lambda t: constrain(t.reshape(B, S, KV * hd),
                                       "batch", "kv_seq", "kv")
            new_cache = {"k": flat(k), "v": flat(v)}
        else:
            new_cache = {}
    else:
        ck = cache_update(cache["k"], k.reshape(B, 1, KV * hd), positions)
        cv = cache_update(cache["v"], v.reshape(B, 1, KV * hd), positions)
        ck = constrain(ck, "batch", "kv_seq", "kv")
        cv = constrain(cv, "batch", "kv_seq", "kv")
        o = decode_attention_gqa(q, ck, cv, positions, groups=H // KV)
        o = o.reshape(B, 1, H * hd)
        new_cache = {"k": ck, "v": cv}
    y = o @ p["wo"]
    return constrain(y, "batch", "seq", None), new_cache


def cross_attn_specs(cfg):
    s = attn_specs(cfg)
    s.pop("q_norm", None), s.pop("k_norm", None)
    return s


def cross_attention(p, x, image_embeds, cfg, *, mode: str, cache=None):
    """Gated cross-attention over image patch embeddings (VLM).  KV is
    position-free; prefill caches the projected image KV, decode reuses it.
    """
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    B, S, _ = x.shape
    q = _split_heads(x @ p["wq"], H, hd)
    q = constrain(q, "batch", "seq", "heads", None)
    if mode == "decode":
        k, v = cache["xk"], cache["xv"]
        new_cache = {"xk": k, "xv": v}
    else:
        k = _split_heads(image_embeds.astype(x.dtype) @ p["wk"], KV, hd)
        v = _split_heads(image_embeds.astype(x.dtype) @ p["wv"], KV, hd)
        new_cache = {"xk": k, "xv": v} if mode == "prefill" else {}
    kh, vh = expand_kv(k, H), expand_kv(v, H)
    s = jnp.einsum("bqhd,bthd->bqht", q.astype(jnp.float32),
                   kh.astype(jnp.float32)) / math.sqrt(hd)
    o = jnp.einsum("bqht,bthd->bqhd", jax.nn.softmax(s, axis=-1),
                   vh.astype(jnp.float32)).astype(x.dtype)
    y = o.reshape(B, S, H * hd) @ p["wo"]
    return constrain(y, "batch", "seq", None), new_cache


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp_specs(cfg, ff: int):
    d = cfg.d_model
    s = {
        "wi": P((d, ff), ("embed", "mlp")),
        "wo": P((ff, d), ("mlp", "embed")),
    }
    if cfg.mlp_act == "swiglu":
        s["wg"] = P((d, ff), ("embed", "mlp"))
    return s


def mlp_apply(p, x):
    if "wg" in p:
        h = jax.nn.silu(x @ p["wi"]) * (x @ p["wg"])
    else:
        h = jax.nn.gelu(x @ p["wi"])
    h = constrain(h, "batch", "seq", "mlp")
    return constrain(h @ p["wo"], "batch", "seq", None)
