"""DeepSeek-V3 Multi-head Latent Attention.

Train/prefill expand the latent to full per-head K/V; decode uses the
weight-absorption trick and attends directly in latent space, so the KV
cache stores only (kv_lora_rank + qk_rope_dim) floats per token.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.distributed.meshes import constrain
from repro.models.layers import (NEG_INF, apply_rope, cache_update,
                                 causal_attention, rms_norm)
from repro.models.params import P


def mla_specs(cfg):
    m, d, H = cfg.mla, cfg.d_model, cfg.num_heads
    qk = m.qk_nope_dim + m.qk_rope_dim
    return {
        "wq_a": P((d, m.q_lora_rank), ("embed", "lora")),
        "q_norm": P((m.q_lora_rank,), ("lora",), "ones"),
        "wq_b": P((m.q_lora_rank, H * qk), ("lora", "heads")),
        "wkv_a": P((d, m.kv_lora_rank + m.qk_rope_dim), ("embed", "lora")),
        "kv_norm": P((m.kv_lora_rank,), ("lora",), "ones"),
        "wkv_b": P((m.kv_lora_rank, H * (m.qk_nope_dim + m.v_dim)),
                   ("lora", "heads")),
        "wo": P((H * m.v_dim, d), ("heads", "embed")),
    }


def mla_attention(p, x, cfg, *, positions, mode: str, cache=None):
    m, H = cfg.mla, cfg.num_heads
    B, S, _ = x.shape
    nope, rope_d, vd, r = m.qk_nope_dim, m.qk_rope_dim, m.v_dim, m.kv_lora_rank
    scale = 1.0 / math.sqrt(nope + rope_d)
    rope_pos = positions[:, None] if mode == "decode" else positions

    q = rms_norm(x @ p["wq_a"], p["q_norm"], cfg.norm_eps) @ p["wq_b"]
    q = q.reshape(B, S, H, nope + rope_d)
    q = constrain(q, "batch", "seq", "heads", None)
    q_nope, q_pe = q[..., :nope], q[..., nope:]
    q_pe = apply_rope(q_pe, rope_pos, cfg.rope_theta)

    ckv_full = x @ p["wkv_a"]                                   # (B,S,r+rope)
    ckv = rms_norm(ckv_full[..., :r], p["kv_norm"], cfg.norm_eps)
    kpe = apply_rope(ckv_full[..., None, r:], rope_pos, cfg.rope_theta)
    kpe = kpe[..., 0, :]                                        # (B,S,rope)

    wkv_b = p["wkv_b"].reshape(r, H, nope + vd)
    w_k = wkv_b[..., :nope]                                     # (r,H,nope)
    w_v = wkv_b[..., nope:]                                     # (r,H,vd)

    if mode in ("train", "prefill"):
        k_nope = jnp.einsum("bsr,rhn->bshn", ckv, w_k)
        v = jnp.einsum("bsr,rhv->bshv", ckv, w_v)
        v = constrain(v, "batch", "seq", "heads", None)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(kpe[:, :, None, :], (B, S, H, rope_d))],
            axis=-1)
        k = constrain(k, "batch", "seq", "heads", None)
        qc = jnp.concatenate([q_nope, q_pe], axis=-1)
        o = causal_attention(qc, k, v, flash_block=cfg.flash_block,
                             scale=scale)
        o = o.reshape(B, S, H * vd)
        new_cache = {"ckv": ckv, "kpe": kpe} if mode == "prefill" else {}
    else:
        # weight absorption: score = (q_nope·W_k)·ckv_t + q_pe·kpe_t.
        # Caches stay in storage dtype with f32 accumulation, and their
        # sharding is pinned across the layer scan (see layers.py §Perf).
        cc = cache_update(cache["ckv"], ckv, positions)          # (B,Sc,r)
        ck = cache_update(cache["kpe"], kpe, positions)          # (B,Sc,rope)
        cc = constrain(cc, "batch", "kv_seq", "lora")
        ck = constrain(ck, "batch", "kv_seq", None)
        Sc = cc.shape[1]
        q_abs = jnp.einsum("bqhn,rhn->bqhr", q_nope, w_k)
        s = (jnp.einsum("bqhr,btr->bqht", q_abs, cc,
                        preferred_element_type=jnp.float32)
             + jnp.einsum("bqhe,bte->bqht", q_pe, ck,
                          preferred_element_type=jnp.float32)) * scale
        valid = jnp.arange(Sc)[None, :] <= positions[:, None]
        s = jnp.where(valid[:, None, None, :], s, NEG_INF)
        probs = jax.nn.softmax(s, axis=-1).astype(cc.dtype)
        o_lat = jnp.einsum("bqht,btr->bqhr", probs, cc,
                           preferred_element_type=jnp.float32)   # (B,1,H,r)
        o = jnp.einsum("bqhr,rhv->bqhv", o_lat.astype(x.dtype), w_v)
        o = o.reshape(B, 1, H * vd)
        new_cache = {"ckv": cc, "kpe": ck}
    y = o @ p["wo"]
    return constrain(y, "batch", "seq", None), new_cache
