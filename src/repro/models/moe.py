"""Token-choice top-k MoE with capacity-based dispatch.

Dispatch is done per example (vmap over batch) with a static capacity
C = ceil(S * top_k * capacity_factor / E), scatter into an (E, C, d)
buffer, batched expert SwiGLU matmuls (EP-sharded over the 'model' axis),
and gather-combine.  Overflow tokens are dropped (standard capacity MoE).
FLOPs scale with E*C ≈ top_k*S*capacity_factor — i.e. with *active*
parameters, which is what the roofline MODEL_FLOPS ratio checks.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.distributed.meshes import constrain
from repro.models.params import P

if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
else:                                        # jax < 0.5: experimental home,
    from jax.experimental.shard_map import shard_map as _exp_shard_map

    def _shard_map(f, *, mesh, in_specs, out_specs, check_vma=True):
        return _exp_shard_map(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs,   # check_vma was check_rep
                              check_rep=bool(check_vma))


def moe_specs(cfg):
    e, d = cfg.moe, cfg.d_model
    # expert weight dims use their own logical axes ('expert_embed' stays
    # unsharded; 'expert_mlp' -> data) so dense-FSDP 'embed' rules never
    # shard expert tensors — the EP body keeps weights stationary and
    # moves activations instead (§Perf change 9)
    s = {
        "router": P((d, e.num_experts), ("embed", None), scale=0.02),
        "wi": P((e.num_experts, d, e.d_expert),
                ("experts", "expert_embed", "expert_mlp")),
        "wg": P((e.num_experts, d, e.d_expert),
                ("experts", "expert_embed", "expert_mlp")),
        "wo": P((e.num_experts, e.d_expert, d),
                ("experts", "expert_mlp", "expert_embed")),
    }
    if e.num_shared:
        f = e.num_shared * e.d_expert
        s["shared_wi"] = P((d, f), ("embed", "mlp"))
        s["shared_wg"] = P((d, f), ("embed", "mlp"))
        s["shared_wo"] = P((f, d), ("mlp", "embed"))
    return s


def capacity(S: int, top_k: int, E: int, factor: float) -> int:
    c = math.ceil(S * top_k * factor / E)
    if S >= 8:
        c = max(8, ((c + 7) // 8) * 8)
    return max(1, c)


def _dispatch_one(x, idx, w, keep, pos, E, C):
    """Per-example scatter.  x: (S,d) idx/w/keep/pos: (S*k,)."""
    S, d = x.shape
    k = idx.shape[0] // S
    xr = jnp.repeat(x, k, axis=0)                                # (S*k, d)
    vals = xr * keep[:, None].astype(x.dtype)
    pos_c = jnp.minimum(pos, C - 1)
    buf = jnp.zeros((E, C, d), x.dtype).at[idx, pos_c].add(vals)
    return buf


def _combine_one(out, idx, w, keep, pos, S, k):
    pos_c = jnp.minimum(pos, out.shape[1] - 1)
    y = out[idx, pos_c]                                          # (S*k, d)
    y = y * (w * keep.astype(w.dtype))[:, None]
    return y.reshape(S, k, -1).sum(axis=1)


def moe_apply(p, x, cfg):
    """x: (B, S, d) -> (B, S, d).  Under an active mesh with a 'model'
    axis that divides the expert count, dispatch runs through the
    shard_map expert-parallel path (explicit all_to_all); otherwise the
    pjit einsum path below."""
    from repro.distributed.meshes import active_mesh
    e = cfg.moe
    mesh = active_mesh()
    if mesh is not None and "model" in mesh.shape:
        m = mesh.shape["model"]
        total = m * mesh.shape.get("data", 1)
        full_ep = e.num_experts % total == 0
        tokens = x.shape[0] * x.shape[1]
        # EP always wins for fine-grained MoE (whole experts per device,
        # zero weight movement) and for low-token serving steps; for
        # small-E training the token gather/psum costs more than the
        # einsum dispatch (measured in §Perf change 9), so fall through.
        if e.num_experts % m == 0 and (full_ep or tokens <= 65_536):
            return moe_apply_ep(p, x, cfg, mesh)
    return _moe_apply_einsum(p, x, cfg)


def _moe_apply_einsum(p, x, cfg):
    e = cfg.moe
    B, S, d = x.shape
    E, k = e.num_experts, e.top_k
    C = capacity(S, k, E, e.capacity_factor)

    logits = jnp.einsum("bsd,de->bse", x, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, k)                             # (B,S,k)
    w = (w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)).astype(x.dtype)

    idx_f = idx.reshape(B, S * k)
    w_f = w.reshape(B, S * k)
    oh = jax.nn.one_hot(idx_f, E, dtype=jnp.int32)               # (B,S*k,E)
    pos_e = jnp.cumsum(oh, axis=1) - oh
    pos = (pos_e * oh).sum(-1)                                   # (B,S*k)
    keep = pos < C

    buf = jax.vmap(_dispatch_one, in_axes=(0, 0, 0, 0, 0, None, None))(
        x, idx_f, w_f, keep, pos, E, C)                          # (B,E,C,d)
    buf = constrain(buf, "batch", "experts", None, None)
    h = jax.nn.silu(jnp.einsum("becd,edf->becf", buf, p["wi"]))
    h = h * jnp.einsum("becd,edf->becf", buf, p["wg"])
    h = constrain(h, "batch", "experts", None, "expert_mlp")
    out = jnp.einsum("becf,efd->becd", h, p["wo"])
    out = constrain(out, "batch", "experts", None, None)
    y = jax.vmap(_combine_one, in_axes=(0, 0, 0, 0, 0, None, None))(
        out, idx_f, w_f, keep, pos, S, k)

    if e.num_shared:
        hs = jax.nn.silu(x @ p["shared_wi"]) * (x @ p["shared_wg"])
        y = y + hs @ p["shared_wo"]
    # aux metrics for load-balance loss (computed, cheap, used by train loop)
    me = probs.mean(axis=(0, 1))                                 # (E,)
    ce = (oh.sum(axis=1).astype(jnp.float32) / (S * k)).mean(0)  # (E,)
    aux = E * jnp.sum(me * ce)
    return constrain(y, "batch", "seq", None), aux


# ---------------------------------------------------------------------------
# Expert-parallel path: shard_map + explicit all_to_all (§Perf)
# ---------------------------------------------------------------------------
#
# The einsum/scatter dispatch above leaves GSPMD no way to prove that each
# token only visits top_k experts, so it materialises and ALL-REDUCES the
# full (B,E,C,d) dispatch buffer across the model group (28 GiB per MoE
# layer on deepseek-v3 train_4k).  The textbook fix is explicit expert
# parallelism: tokens stay data-sharded, each model shard owns E/m experts,
# and two all_to_alls move only the routed token activations —
# O(tokens*d) wire bytes instead of O(B*E*C*d).

def _ep_specs(mesh, cfg, S: int, B: int):
    from jax.sharding import PartitionSpec as P
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    prod = 1
    got = []
    for ax in batch_axes:                 # drop axes that don't divide B
        if B % (prod * mesh.shape[ax]) == 0:
            got.append(ax)
            prod *= mesh.shape[ax]
    b = tuple(got) if len(got) > 1 else (got[0] if got else None)
    # shard the token (sequence) dim over 'model' so each shard dispatches
    # a distinct token slice — otherwise expert compute is redundant xm
    m = mesh.shape["model"]
    seq_ax = "model" if S % m == 0 else None
    xs = P(b, seq_ax, None)
    E = cfg.moe.num_experts
    data = mesh.shape.get("data", 1)
    from repro.distributed.meshes import current_rules
    rules = current_rules()
    if E % (m * data) == 0:
        # full-mesh EP: each device owns whole experts — zero weight
        # movement; the all_to_all spans (data, model)
        return xs, P(("data", "model"), None, None), ("data", "model"), "none"
    # experts over model, ffn columns over data ('ff'): weights stay put;
    # tokens are co-located across the expert's data group by an
    # all_gather, partial outputs psum'd, own tokens sliced back
    if "data" in mesh.shape and "data" in (rules.get("expert_mlp") or ()):
        return xs, P("model", None, "data"), ("model",), "ff"
    return xs, P("model", None, None), ("model",), "none"


def moe_apply_ep(p, x, cfg, mesh):
    e = cfg.moe
    B, S, d = x.shape
    E, k = e.num_experts, e.top_k
    m = mesh.shape["model"]
    xs, ws, ep_axes, wshard = _ep_specs(mesh, cfg, S, B)
    full_ep = len(ep_axes) > 1
    from jax.sharding import PartitionSpec as P

    # routing outside the shard_map (small, dense)
    logits = jnp.einsum("bsd,de->bse", x, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, k)
    w = (w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)).astype(x.dtype)

    def body(x_loc, w_loc, idx_loc, wi_loc, wg_loc, wo_loc):
        Bl, Sl, dl = x_loc.shape
        T = Bl * Sl
        xf = x_loc.reshape(T, dl)
        idx_f = idx_loc.reshape(T * k)
        w_f = w_loc.reshape(T * k)
        C = capacity(T, k, E, e.capacity_factor)
        oh = jax.nn.one_hot(idx_f, E, dtype=jnp.int32)
        pos = ((jnp.cumsum(oh, axis=0) - oh) * oh).sum(-1)
        keep = pos < C
        pos_c = jnp.minimum(pos, C - 1)
        vals = jnp.repeat(xf, k, axis=0) * keep[:, None].astype(xf.dtype)
        buf = jnp.zeros((E, C, dl), xf.dtype).at[idx_f, pos_c].add(vals)
        # dispatch: every shard sends each expert-group to its owner
        a2a_ax = ep_axes if full_ep else "model"
        buf = jax.lax.all_to_all(buf, a2a_ax, split_axis=0, concat_axis=1,
                                 tiled=True)               # (E/g, C*g, d)
        # expert FFN with stationary weights: activations move, weights
        # don't (§Perf change 9 — replaces in-body FSDP weight gathers)
        if wshard == "ff":
            # each expert's ffn columns are spread over the data axis;
            # co-locate the expert's tokens across that group, compute the
            # local f-slice, psum the d-sized partials, take own slice
            Tl = buf.shape[1]
            buf_g = jax.lax.all_gather(buf, "data", axis=1, tiled=True)
            h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf_g, wi_loc))
            h = h * jnp.einsum("ecd,edf->ecf", buf_g, wg_loc)
            out_g = jax.lax.psum(
                jnp.einsum("ecf,efd->ecd", h, wo_loc), "data")
            di = jax.lax.axis_index("data")
            out = jax.lax.dynamic_slice_in_dim(out_g, di * Tl, Tl, axis=1)
        else:
            h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wi_loc))
            h = h * jnp.einsum("ecd,edf->ecf", buf, wg_loc)
            out = jnp.einsum("ecf,efd->ecd", h, wo_loc)
        # return trip
        out = jax.lax.all_to_all(out, a2a_ax, split_axis=1, concat_axis=0,
                                 tiled=True)                  # (E, C, d)
        y = out[idx_f, pos_c] * (w_f * keep.astype(w_f.dtype))[:, None]
        return y.reshape(Bl, Sl, k, dl).sum(2)

    if full_ep:
        wo_spec = P(ep_axes, None, None)
    elif wshard == "ff":
        wo_spec = P("model", "data", None)      # f rows sharded
    else:
        wo_spec = P("model", None, None)
    y = _shard_map(
        body, mesh=mesh,
        in_specs=(xs, P(xs[0], xs[1], None), P(xs[0], xs[1], None),
                  ws, ws, wo_spec),
        out_specs=xs, check_vma=False,
    )(x, w, idx, p["wi"], p["wg"], p["wo"])

    if e.num_shared:
        hs = jax.nn.silu(x @ p["shared_wi"]) * (x @ p["shared_wg"])
        y = y + hs @ p["shared_wo"]
    oh_g = jax.nn.one_hot(idx.reshape(B, S * k), E, dtype=jnp.float32)
    me = probs.mean(axis=(0, 1))
    ce = (oh_g.sum(axis=1) / (S * k)).mean(0)
    aux = E * jnp.sum(me * ce)
    return constrain(y, "batch", "seq", None), aux
