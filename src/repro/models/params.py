"""Parameter spec trees: shapes + logical axes + initializers.

A layer is described by a dict of ``P`` specs; ``init_tree`` materialises
parameters, ``axes_tree`` extracts the logical-axes pytree used to derive
shardings, ``abstract_tree`` gives ShapeDtypeStructs for allocation-free
AOT lowering.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np


class P(NamedTuple):
    shape: tuple
    axes: tuple                     # logical axis names, len == len(shape)
    init: str = "normal"            # normal | zeros | ones | a_log | dt_bias
    scale: Optional[float] = None   # stddev override for "normal"


def is_spec(x) -> bool:
    return isinstance(x, P)


def _init_leaf(spec: P, key, dtype):
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    if spec.init == "a_log":        # mamba2 A_log: log U(1, 16)
        u = jax.random.uniform(key, spec.shape, jnp.float32, 1.0, 16.0)
        return jnp.log(u).astype(dtype)
    if spec.init == "dt_bias":      # softplus^-1 of U(1e-3, 1e-1)
        u = jax.random.uniform(key, spec.shape, jnp.float32, 1e-3, 1e-1)
        return jnp.log(jnp.expm1(u)).astype(dtype)
    if spec.init == "normal":
        fan_in = spec.shape[0] if len(spec.shape) >= 2 else spec.shape[-1]
        std = spec.scale if spec.scale is not None else 1.0 / math.sqrt(fan_in)
        return (jax.random.normal(key, spec.shape, jnp.float32) * std).astype(dtype)
    raise ValueError(spec.init)


def init_tree(specs, key, dtype):
    leaves, treedef = jax.tree.flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    out = [_init_leaf(s, k, dtype) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, out)


def axes_tree(specs):
    return jax.tree.map(lambda s: s.axes, specs, is_leaf=is_spec)


def abstract_tree(specs, dtype):
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, dtype),
                        specs, is_leaf=is_spec)


def stacked(specs, n: int):
    """Add a leading (n,)-'layers' axis to every spec (for scan segments)."""
    return jax.tree.map(
        lambda s: P((n,) + s.shape, ("layers",) + s.axes, s.init, s.scale),
        specs, is_leaf=is_spec)


def count_params(specs) -> int:
    return sum(int(np.prod(s.shape))
               for s in jax.tree.leaves(specs, is_leaf=is_spec))
