"""Mamba2 / SSD (state-space duality) mixer.

Training/prefill uses the chunked SSD algorithm (intra-chunk quadratic
attention-like term + inter-chunk state recurrence); decode is the O(1)
recurrent state update.  Follows Dao & Gu 2024 (arXiv:2405.21060).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.meshes import constrain
from repro.models.layers import rms_norm
from repro.models.params import P

NEG_INF = -1e30


def ssm_specs(cfg):
    s, d = cfg.ssm, cfg.d_model
    di = cfg.d_inner
    g = s.n_groups * s.d_state
    H = cfg.ssm_heads
    conv_dim = di + 2 * g
    return {
        "wz": P((d, di), ("embed", "mlp")),
        "wxbc": P((d, conv_dim), ("embed", "mlp")),
        "wdt": P((d, H), ("embed", "heads")),
        "conv_w": P((s.d_conv, conv_dim), ("conv", "mlp"), scale=0.2),
        "conv_b": P((conv_dim,), ("mlp",), "zeros"),
        "a_log": P((H,), ("heads",), "a_log"),
        "d_skip": P((H,), ("heads",), "ones"),
        "dt_bias": P((H,), ("heads",), "dt_bias"),
        "norm": P((di,), ("mlp",), "ones"),
        "out": P((di, d), ("mlp", "embed")),
    }


def _segsum(x):
    """x: (..., Q) -> (..., Q, Q); out[i,j] = sum_{j<k<=i} x[k], -inf for i<j."""
    Q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    d = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    return jnp.where(mask, d, NEG_INF)


def ssd_chunked(xs, dt, A, B_, C_, chunk: int, init_state=None):
    """Chunked SSD scan.

    xs: (B,L,H,P) inputs; dt: (B,L,H) f32; A: (H,) negative; B_,C_: (B,L,H,N)
    (already broadcast from groups to heads).  Returns (y (B,L,H,P),
    final_state (B,H,P,N)).
    """
    Bb, L, H, Pd = xs.shape
    N = B_.shape[-1]
    if L % chunk:
        # pad with dt=0 steps: zero contribution, unit decay — exact
        pad = chunk - L % chunk
        padt = lambda t: jnp.pad(t, [(0, 0), (0, pad)] +
                                 [(0, 0)] * (t.ndim - 2))
        y, final = ssd_chunked(padt(xs), padt(dt), A, padt(B_), padt(C_),
                               chunk, init_state)
        return y[:, :L], final
    Cn, Q = L // chunk, chunk

    r = lambda t: t.reshape((Bb, Cn, Q) + t.shape[2:])
    xc, dtc, Bc, Cc = r(xs), r(dt), r(B_), r(C_)
    dA = dtc * A[None, None, None, :]                            # (B,Cn,Q,H)
    dA = jnp.moveaxis(dA, -1, 2)                                 # (B,Cn,H,Q)
    cs = jnp.cumsum(dA, axis=-1)                                 # inclusive

    # intra-chunk (diagonal blocks)
    Lmat = jnp.exp(_segsum(dA))                                  # (B,Cn,H,Q,Q)
    dtx = xc * dtc[..., None]                                    # (B,Cn,Q,H,P)
    Ydiag = jnp.einsum("bcqhn,bcshn,bchqs,bcshp->bcqhp",
                       Cc.astype(jnp.float32), Bc.astype(jnp.float32),
                       Lmat, dtx.astype(jnp.float32))

    # end-of-chunk states
    decay = jnp.exp(cs[..., -1:] - cs)                           # (B,Cn,H,Q)
    states = jnp.einsum("bcshn,bchs,bcshp->bchpn",
                        Bc.astype(jnp.float32),
                        decay, dtx.astype(jnp.float32))          # (B,Cn,H,P,N)

    # inter-chunk recurrence
    total = jnp.exp(cs[..., -1])                                 # (B,Cn,H)
    s0 = (jnp.zeros((Bb, H, Pd, N), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def step(s_prev, xs_):
        st, tot = xs_
        return st + tot[..., None, None] * s_prev, s_prev

    final, prev_states = jax.lax.scan(
        step, s0, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(total, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)                # (B,Cn,H,P,N)

    Yoff = jnp.einsum("bcqhn,bchpn,bchq->bcqhp",
                      Cc.astype(jnp.float32), prev_states, jnp.exp(cs))
    y = (Ydiag + Yoff).reshape(Bb, L, H, Pd)
    return y.astype(xs.dtype), final.astype(xs.dtype)


def _causal_conv(x, w, b):
    """Depthwise causal conv: x (B,L,C), w (K,C) -> (B,L,C)."""
    K = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    y = sum(pad[:, i:i + x.shape[1], :] * w[i][None, None, :] for i in range(K))
    return y + b[None, None, :]


def _expand_groups(t, H):
    """(B,...,G,N) -> (B,...,H,N)."""
    G = t.shape[-2]
    return jnp.repeat(t, H // G, axis=-2)


def mamba_mixer(p, x, cfg, *, mode: str, cache=None):
    """Mamba2 block mixer.  x: (B,S,d).  Returns (y, new_cache)."""
    s = cfg.ssm
    B, S, d = x.shape
    di, H, Pd, N, G = cfg.d_inner, cfg.ssm_heads, s.head_dim, s.d_state, s.n_groups
    gdim = G * N

    z = x @ p["wz"]                                              # (B,S,di)
    xbc_raw = x @ p["wxbc"]                                      # (B,S,di+2g)
    xbc_raw = constrain(xbc_raw, "batch", "seq", "mlp")
    dt_raw = x @ p["wdt"]                                        # (B,S,H)

    if mode in ("train", "prefill"):
        xbc = jax.nn.silu(_causal_conv(xbc_raw, p["conv_w"], p["conv_b"]))
        xs = xbc[..., :di].reshape(B, S, H, Pd)
        B_ = _expand_groups(xbc[..., di:di + gdim].reshape(B, S, G, N), H)
        C_ = _expand_groups(xbc[..., di + gdim:].reshape(B, S, G, N), H)
        dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                             + p["dt_bias"].astype(jnp.float32))
        A = -jnp.exp(p["a_log"].astype(jnp.float32))
        y, final = ssd_chunked(xs, dt, A, B_, C_, min(s.chunk, S))
        y = y + p["d_skip"].astype(x.dtype)[None, None, :, None] * xs
        y = y.reshape(B, S, di)
        if mode == "prefill":
            conv_cache = xbc_raw[:, S - (s.d_conv - 1):, :]       # (B,K-1,C)
            new_cache = {"conv": conv_cache, "ssm": final}
        else:
            new_cache = {}
    else:                                                        # decode, S == 1
        conv_cache, state = cache["conv"], cache["ssm"]
        full = jnp.concatenate([conv_cache, xbc_raw], axis=1)     # (B,K,C)
        w = p["conv_w"]
        conv_out = jnp.einsum("bkc,kc->bc", full, w) + p["conv_b"]
        xbc = jax.nn.silu(conv_out)                               # (B,C)
        xs = xbc[..., :di].reshape(B, H, Pd)
        B_ = _expand_groups(xbc[..., di:di + gdim].reshape(B, G, N), H)
        C_ = _expand_groups(xbc[..., di + gdim:].reshape(B, G, N), H)
        dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32)
                             + p["dt_bias"].astype(jnp.float32))  # (B,H)
        A = -jnp.exp(p["a_log"].astype(jnp.float32))
        dA = jnp.exp(dt * A[None, :])                             # (B,H)
        state = (state.astype(jnp.float32) * dA[..., None, None]
                 + jnp.einsum("bh,bhp,bhn->bhpn", dt,
                              xs.astype(jnp.float32), B_.astype(jnp.float32)))
        y = jnp.einsum("bhpn,bhn->bhp", state, C_.astype(jnp.float32))
        y = y.astype(x.dtype) + p["d_skip"].astype(x.dtype)[None, :, None] * xs
        y = y.reshape(B, 1, di)
        new_cache = {"conv": full[:, 1:, :], "ssm": state.astype(x.dtype)}

    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    return constrain(y @ p["out"], "batch", "seq", None), new_cache
