"""Composable decoder: layer segments, scan-over-periods, KV/SSM caches.

The layer stack is compiled as a list of *segments*; each segment is a
period of heterogeneous *slots* (mixer + ffn) repeated ``n`` times and
executed with ``lax.scan`` over stacked parameters, keeping the HLO small
for 61-72 layer models.  Caches are pytrees scanned alongside parameters.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.meshes import constrain
from repro.models import layers as L
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.params import P, abstract_tree, axes_tree, init_tree, stacked


class Slot(NamedTuple):
    kind: str            # 'A' | 'M' | 'X'
    ffn: str             # 'mlp' | 'moe' | 'none'
    ff: int              # mlp hidden size (unused for moe/none)


class Segment(NamedTuple):
    slots: tuple
    n: int


def _lcm(a, b):
    import math
    return a * b // math.gcd(a, b)


def build_segments(cfg: ModelConfig) -> list[Segment]:
    kinds = cfg.pattern_layers()

    def slot_for(i):
        kind = kinds[i]
        if kind == "M" and cfg.family == "ssm":
            return Slot(kind, "none", 0)
        if cfg.is_moe_layer(i):
            return Slot(kind, "moe", 0)
        ff = (cfg.dense_prefix_ff
              if (cfg.moe is not None and i < cfg.dense_prefix
                  and cfg.dense_prefix_ff) else cfg.d_ff)
        return Slot(kind, "mlp", ff)

    segs = []
    start = 0
    if cfg.dense_prefix:
        slots = tuple(slot_for(i) for i in range(cfg.dense_prefix))
        assert len(set(slots)) == 1, "dense prefix must be homogeneous"
        segs.append(Segment((slots[0],), cfg.dense_prefix))
        start = cfg.dense_prefix
    period = _lcm(len(cfg.layer_pattern),
                  cfg.moe.every_k_layers if cfg.moe else 1)
    rest = cfg.num_layers - start
    assert rest % period == 0, (cfg.name, rest, period)
    slots = tuple(slot_for(start + j) for j in range(period))
    # verify periodicity
    for i in range(start, cfg.num_layers):
        assert slot_for(i) == slots[(i - start) % period], (cfg.name, i)
    segs.append(Segment(slots, rest // period))
    return segs


# ---------------------------------------------------------------------------
# Param specs
# ---------------------------------------------------------------------------

def _mixer_specs(cfg, slot: Slot):
    if slot.kind == "A":
        return mla_mod.mla_specs(cfg) if cfg.mla is not None else L.attn_specs(cfg)
    if slot.kind == "M":
        return ssm_mod.ssm_specs(cfg)
    if slot.kind == "X":
        return L.cross_attn_specs(cfg)
    raise ValueError(slot.kind)


def _slot_specs(cfg, slot: Slot):
    d = cfg.d_model
    s = {"norm1": P((d,), ("embed",), "ones"), "mixer": _mixer_specs(cfg, slot)}
    if slot.kind == "X":
        s["gate_attn"] = P((), (), "zeros")
        s["gate_ffn"] = P((), (), "zeros")
    if slot.ffn == "mlp":
        s["norm2"] = P((d,), ("embed",), "ones")
        s["ffn"] = L.mlp_specs(cfg, slot.ff)
    elif slot.ffn == "moe":
        s["norm2"] = P((d,), ("embed",), "ones")
        s["ffn"] = moe_mod.moe_specs(cfg)
    return s


def param_specs(cfg: ModelConfig):
    d = cfg.d_model
    specs = {
        "embed": P((cfg.vocab_size, d), ("vocab", "embed"), scale=0.02),
        "final_norm": P((d,), ("embed",), "ones"),
    }
    if not cfg.tie_embeddings:
        specs["unembed"] = P((d, cfg.vocab_size), ("embed", "vocab"))
    specs["segments"] = [
        {f"slot{j}": stacked(_slot_specs(cfg, slot), seg.n)
         for j, slot in enumerate(seg.slots)}
        for seg in build_segments(cfg)
    ]
    return specs


# ---------------------------------------------------------------------------
# Cache specs
# ---------------------------------------------------------------------------

def _slot_cache_spec(cfg, slot: Slot, B: int, S: int):
    f = jnp.dtype(cfg.compute_dtype)
    if slot.kind == "A":
        if cfg.mla is not None:
            m = cfg.mla
            return {"ckv": ((B, S, m.kv_lora_rank), ("batch", "kv_seq", "lora"), f),
                    "kpe": ((B, S, m.qk_rope_dim), ("batch", "kv_seq", None), f)}
        kv, hd = cfg.num_kv_heads, cfg.head_dim
        # flattened (kv*hd) layout: shards exactly like the K/V projection
        # outputs, so the scan-carried cache is never re-sharded (§Perf)
        return {"k": ((B, S, kv * hd), ("batch", "kv_seq", "kv"), f),
                "v": ((B, S, kv * hd), ("batch", "kv_seq", "kv"), f)}
    if slot.kind == "M":
        s = cfg.ssm
        conv_dim = cfg.d_inner + 2 * s.n_groups * s.d_state
        return {"conv": ((B, s.d_conv - 1, conv_dim), ("batch", None, "mlp"), f),
                "ssm": ((B, cfg.ssm_heads, s.head_dim, s.d_state),
                        ("batch", "heads", None, "state"), f)}
    if slot.kind == "X":
        kv, hd = cfg.num_kv_heads, cfg.head_dim
        T = cfg.num_image_tokens
        return {"xk": ((B, T, kv, hd), ("batch", "img", "kv", "head_dim"), f),
                "xv": ((B, T, kv, hd), ("batch", "img", "kv", "head_dim"), f)}
    raise ValueError(slot.kind)


def cache_specs(cfg: ModelConfig, B: int, S: int):
    """Returns (ShapeDtypeStruct tree, axes tree) for the decode cache."""
    shapes, axes = [], []
    for seg in build_segments(cfg):
        sh, ax = {}, {}
        for j, slot in enumerate(seg.slots):
            spec = _slot_cache_spec(cfg, slot, B, S)
            sh[f"slot{j}"] = {k: jax.ShapeDtypeStruct((seg.n,) + s, d)
                              for k, (s, a, d) in spec.items()}
            ax[f"slot{j}"] = {k: ("layers",) + a
                              for k, (s, a, d) in spec.items()}
        shapes.append(sh)
        axes.append(ax)
    return shapes, axes


def init_cache(cfg: ModelConfig, B: int, S: int):
    shapes, _ = cache_specs(cfg, B, S)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shapes)


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _apply_slot(cfg, slot: Slot, p, x, *, positions, mode, cache, image_embeds):
    h = L.rms_norm(x, p["norm1"], cfg.norm_eps)
    aux = jnp.float32(0.0)
    if slot.kind == "A":
        fn = mla_mod.mla_attention if cfg.mla is not None else L.attention
        y, nc = fn(p["mixer"], h, cfg, positions=positions, mode=mode,
                   cache=cache)
    elif slot.kind == "M":
        y, nc = ssm_mod.mamba_mixer(p["mixer"], h, cfg, mode=mode, cache=cache)
    elif slot.kind == "X":
        y, nc = L.cross_attention(p["mixer"], h, image_embeds, cfg,
                                  mode=mode, cache=cache)
        y = y * jnp.tanh(p["gate_attn"]).astype(y.dtype)
    x = x + y
    if slot.ffn != "none":
        h2 = L.rms_norm(x, p["norm2"], cfg.norm_eps)
        if slot.ffn == "moe":
            f, aux = moe_mod.moe_apply(p["ffn"], h2, cfg)
        else:
            f = L.mlp_apply(p["ffn"], h2)
        if slot.kind == "X":
            f = f * jnp.tanh(p["gate_ffn"]).astype(f.dtype)
        x = x + f
    return x, nc, aux


def _run_segment(cfg, seg: Segment, seg_params, x, *, positions, mode,
                 caches, image_embeds):
    nslots = len(seg.slots)

    def body(carry, per_layer):
        xx, aux_sum = carry
        lp, lc = per_layer
        new_c = {}
        for j, slot in enumerate(seg.slots):
            c = lc.get(f"slot{j}") if lc else None
            xx, nc, aux = _apply_slot(cfg, slot, lp[f"slot{j}"], xx,
                                      positions=positions, mode=mode,
                                      cache=c, image_embeds=image_embeds)
            new_c[f"slot{j}"] = nc
        return (xx, aux_sum + aux), new_c

    if cfg.remat and mode == "train":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    lc_in = caches if caches is not None else {f"slot{j}": {} for j in range(nslots)}
    (x, aux), new_caches = jax.lax.scan(body, (x, jnp.float32(0.0)),
                                        (seg_params, lc_in))
    return x, new_caches, aux


def forward(cfg: ModelConfig, params, inputs, *, mode: str,
            positions=None, caches=None, image_embeds=None):
    """Full decoder forward.

    mode='train'/'prefill': inputs (B,S) ids or (B,S,d) embeddings.
    mode='decode': inputs (B,1)/(B,1,d), positions (B,), caches required.
    Returns (logits, new_caches, aux).
    """
    f = jnp.dtype(cfg.compute_dtype)
    if cfg.input_mode == "embeddings" and inputs.ndim == 3:
        x = inputs.astype(f)
    else:
        x = jnp.take(params["embed"], inputs, axis=0).astype(f)
    B, S = x.shape[0], x.shape[1]
    x = constrain(x, "batch", "seq", None)
    if positions is None:
        positions = jnp.arange(S)
    if image_embeds is not None:
        image_embeds = image_embeds.astype(f)

    segs = build_segments(cfg)
    new_caches, aux_total = [], jnp.float32(0.0)
    for i, seg in enumerate(segs):
        c = caches[i] if caches is not None else None
        x, nc, aux = _run_segment(cfg, seg, params["segments"][i], x,
                                  positions=positions, mode=mode,
                                  caches=c, image_embeds=image_embeds)
        new_caches.append(nc)
        aux_total = aux_total + aux

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(f))
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["unembed"].astype(f))
    logits = constrain(logits, "batch", "seq", "vocab")
    return logits, (new_caches if mode != "train" else None), aux_total


# ---------------------------------------------------------------------------
# Public model handle
# ---------------------------------------------------------------------------

class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.specs = param_specs(cfg)

    def init(self, key):
        return init_tree(self.specs, key, jnp.dtype(self.cfg.param_dtype))

    def abstract_params(self):
        return abstract_tree(self.specs, jnp.dtype(self.cfg.param_dtype))

    def param_axes(self):
        return axes_tree(self.specs)

    def __call__(self, params, inputs, **kw):
        return forward(self.cfg, params, inputs, **kw)
