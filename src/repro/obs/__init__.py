"""Observability: plan-execution tracing, unified metrics, drift
accounting.

Three pieces, all zero-dependency (stdlib; jax only behind a lazy
fence):

* ``trace`` — ``Tracer``/``Span``: per-node span trees over plan
  execution, exportable as JSON or Chrome ``chrome://tracing`` format.
  Attach with ``compiled_plan.tracer = Tracer()``; disabled (the
  default) costs one ``is None`` check per node eval.
* ``metrics`` — the process-wide ``MetricsRegistry`` (labelled
  counters/gauges/histograms) behind module-level helpers, plus
  ``StatsView``, the dict-shaped facade that keeps every pre-existing
  ``.stats`` consumer working while mirroring increments into the
  registry.
* ``drift`` — pairs each node's APCT *predicted* cost with its traced
  measured self time and aggregates a calibration report (rank
  correlation + per-class ratio spread) per node class × cut size ×
  route — the measurement layer the ROADMAP autotune item builds on.

Typical use::

    from repro import obs
    tr = obs.Tracer()
    cp = compiler.compile(p, g)
    cp.tracer = tr
    cp.count(p)
    tr.save("out.json")                      # or out.chrome.json
    report = obs.drift.aggregate(obs.drift.pairs_from_trace(tr.to_dict()))

    obs.counter("my.events", kind="x")       # unified metrics
    print(obs.dump())
"""
from __future__ import annotations

from repro.obs import drift
from repro.obs.metrics import REGISTRY, MetricsRegistry, StatsView
from repro.obs.trace import Span, Tracer, fence

__all__ = ["Tracer", "Span", "fence", "MetricsRegistry", "StatsView",
           "REGISTRY", "drift", "counter", "gauge", "observe", "get",
           "snapshot", "dump", "reset"]


def counter(name: str, value: float = 1, **labels) -> float:
    """Increment a labelled counter on the process registry."""
    return REGISTRY.counter(name, value, **labels)


def gauge(name: str, value: float, **labels):
    REGISTRY.gauge(name, value, **labels)


def observe(name: str, value: float, **labels):
    REGISTRY.observe(name, value, **labels)


def get(name: str, default=0.0, **labels):
    return REGISTRY.get(name, default, **labels)


def snapshot() -> dict:
    return REGISTRY.snapshot()


def dump(indent=1) -> str:
    return REGISTRY.dump(indent)


def reset():
    REGISTRY.reset()
