"""Cost-model drift accounting: predicted APCT cost vs measured time.

Every compiled plan records the per-node costs the APCT model charged at
selection time (``plan.meta["node_costs"]``); every traced execution
records each node's measured self time.  This module pairs the two and
aggregates per (node class × cut size × route) into a calibration
report.  Routes are free-form span attributes, so the mesh tier's
``kernel-sharded`` / ``xla-sharded`` / ``kernel-sharded-keep`` /
``xla-sharded-keep`` join executions and the sharded-adjacency
``einsum-sharded`` contractions group into their own rows
automatically — a sharded route whose measured/predicted ratio drifts
from its single-device sibling is the signal that the cost model's
per-device collective term (``costing._kernel_join_cost(devices=)``,
``costing._contract_cost(devices=)``) needs recalibration:

* **rank correlation** (Spearman) — the quantity DwarvesGraph actually
  relies on: the model only has to *order* candidates correctly, so a
  rank correlation near 1 means the plan picker is trustworthy even if
  the absolute scale is off;
* **ratio spread** — max/min of measured/predicted within one class: a
  tight spread means one per-class scale factor calibrates the model
  (the autotune on-ramp); a wide spread means the class's cost formula
  is structurally wrong, not just unscaled.

Consumes either trace-tree JSON (``Tracer.to_json``) or the
``drift_pairs`` table ``benchmarks/bench_obs.py`` embeds in
``BENCH_obs.json``:

    python -m repro.obs.drift out.json
    python -m repro.obs.drift benchmarks/results/BENCH_obs.json

Stdlib-only on purpose — it must run anywhere a trace file lands.
"""
from __future__ import annotations

import argparse
import json
from typing import Dict, List, Optional

#: span kinds that are IR node evaluations (everything the tracer emits
#: except the per-read "execute" roots)
NODE_KINDS = ("Contract", "Intersect", "MobiusCombine", "CutJoin",
              "ShrinkageCorrect", "LocalCount")


# -- statistics (stdlib implementations) -------------------------------------------

def _ranks(xs: List[float]) -> List[float]:
    """Average ranks (1-based), ties averaged — Spearman's convention."""
    order = sorted(range(len(xs)), key=lambda i: xs[i])
    ranks = [0.0] * len(xs)
    i = 0
    while i < len(order):
        j = i
        while j + 1 < len(order) and xs[order[j + 1]] == xs[order[i]]:
            j += 1
        avg = (i + j) / 2 + 1
        for k in range(i, j + 1):
            ranks[order[k]] = avg
        i = j + 1
    return ranks


def spearman(xs: List[float], ys: List[float]) -> Optional[float]:
    """Spearman rank correlation; None for fewer than two pairs or a
    degenerate (constant) side."""
    if len(xs) != len(ys) or len(xs) < 2:
        return None
    rx, ry = _ranks(list(xs)), _ranks(list(ys))
    mx = sum(rx) / len(rx)
    my = sum(ry) / len(ry)
    sxx = sum((a - mx) ** 2 for a in rx)
    syy = sum((b - my) ** 2 for b in ry)
    if sxx == 0.0 or syy == 0.0:
        return None
    sxy = sum((a - mx) * (b - my) for a, b in zip(rx, ry))
    return sxy / (sxx * syy) ** 0.5


# -- pair extraction ---------------------------------------------------------------

def _walk(span: dict):
    yield span
    for c in span.get("children", ()):
        yield from _walk(c)


def pairs_from_trace(trace: dict) -> List[dict]:
    """(predicted, measured) pairs from one trace-tree dict: every node
    span whose plan recorded a predicted cost, measured by *self* time
    (children's work is their own nodes' pairs)."""
    backend = trace.get("meta", {}).get("backend", "unknown")
    out = []
    for root in trace.get("spans", ()):
        for s in _walk(root):
            if s.get("kind") not in NODE_KINDS:
                continue
            pred = s.get("attrs", {}).get("predicted")
            if pred is None:
                continue
            out.append({"key": s.get("name"), "cls": s["kind"],
                        "cut": s.get("attrs", {}).get("cut_size"),
                        "route": s.get("attrs", {}).get("route", "host"),
                        "backend": backend,
                        "predicted": float(pred),
                        "measured_us": float(s.get("self_us", 0.0))})
    return out


def group_key(pair: dict) -> str:
    cut = pair.get("cut")
    cut_s = f"cut={cut}" if cut is not None else "cut=-"
    return f"{pair['cls']}|{cut_s}|{pair.get('route', 'host')}"


# -- aggregation -------------------------------------------------------------------

def aggregate(pairs: List[dict]) -> dict:
    """Calibration report over (predicted, measured) pairs, grouped per
    node class × cut size × route (the backend rides in each pair and is
    reported per group — one smoke run is single-backend)."""
    groups: Dict[str, List[dict]] = {}
    for pr in pairs:
        groups.setdefault(group_key(pr), []).append(pr)
    out_groups = {}
    for key, prs in sorted(groups.items()):
        preds = [p["predicted"] for p in prs]
        meas = [p["measured_us"] for p in prs]
        ratios = [m / p for m, p in zip(meas, preds) if p > 0 and m > 0]
        spread = (max(ratios) / min(ratios)
                  if len(ratios) >= 2 and min(ratios) > 0 else None)
        med = sorted(ratios)[len(ratios) // 2] if ratios else None
        out_groups[key] = {
            "n": len(prs),
            "backends": sorted({p.get("backend", "unknown") for p in prs}),
            "rank_corr": spearman(preds, meas),
            "ratio_median": med,
            "ratio_spread": spread,
            "predicted_sum": sum(preds),
            "measured_us_sum": sum(meas),
        }
    return {"n_pairs": len(pairs),
            "overall_rank_corr": spearman([p["predicted"] for p in pairs],
                                          [p["measured_us"] for p in pairs]),
            "groups": out_groups}


def bench_summary(report: dict) -> dict:
    """Compact per-group summary for ``BENCH_obs.json``'s ``drift`` key
    (what ``render_trend`` folds into the cross-commit table)."""
    return {key: {"n": g["n"], "rank_corr": g["rank_corr"],
                  "ratio_spread": g["ratio_spread"]}
            for key, g in report["groups"].items()}


def render(report: dict) -> str:
    """Human-readable calibration table."""
    lines = ["# Cost-model drift report",
             f"pairs: {report['n_pairs']}, overall rank correlation: "
             f"{_fmt(report['overall_rank_corr'])}", "",
             "| class|cut|route | n | rank corr | ratio median "
             "(us/cost) | ratio spread (max/min) |",
             "|---|---|---|---|---|"]
    for key, g in report["groups"].items():
        lines.append(f"| {key} | {g['n']} | {_fmt(g['rank_corr'])} | "
                     f"{_fmt(g['ratio_median'])} | "
                     f"{_fmt(g['ratio_spread'])} |")
    return "\n".join(lines) + "\n"


def _fmt(v) -> str:
    return "-" if v is None else f"{v:.3f}"


def load_pairs(path: str) -> List[dict]:
    """Pairs from one file: a ``BENCH_obs.json`` (embedded
    ``drift_pairs``) or a trace-tree JSON (``spans``)."""
    with open(path) as fh:
        d = json.load(fh)
    if "drift_pairs" in d:
        return list(d["drift_pairs"])
    if "spans" in d:
        return pairs_from_trace(d)
    raise ValueError(f"{path}: neither a trace (spans) nor a bench "
                     f"result (drift_pairs)")


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("files", nargs="+",
                    help="trace JSONs and/or BENCH_obs.json files")
    ap.add_argument("--json", action="store_true",
                    help="emit the report as JSON instead of a table")
    args = ap.parse_args(argv)
    pairs = []
    for f in args.files:
        pairs.extend(load_pairs(f))
    report = aggregate(pairs)
    if args.json:
        print(json.dumps(report, indent=1, sort_keys=True))
    else:
        print(render(report), end="")
    return report


if __name__ == "__main__":
    main()
