"""Unified metrics registry: labelled counters, gauges, and histograms.

One process-wide ``MetricsRegistry`` (``repro.obs.REGISTRY``) is the
home for every counter the system used to keep in ad-hoc ``.stats``
dicts — ``PatternQueryBatcher``, ``PlanCache``, ``CompiledPlan`` — plus
the kernel wrappers and the partial-embedding API.  Series are keyed by
(name, sorted label items), so ``counter("cutjoin.kernel_fallbacks",
cut=3)`` and ``cut=2`` are distinct series that still aggregate under
one name.

``StatsView`` preserves every pre-existing ``.stats`` consumer: it is a
dict-shaped ``MutableMapping`` whose reads are instance-local and exact
(what the old dicts gave), while positive writes mirror into the
registry's cumulative series — so process-wide telemetry aggregates
across instances without per-instance label leaks, and a local reset
(``clear()``, or assigning a smaller value) never decrements the
registry: registry counters are monotonic, instance views are not.

Zero-dependency by design (stdlib only): the registry must be importable
from every layer — kernels included — without cycles or heavyweight
imports.
"""
from __future__ import annotations

import json
import threading
from collections.abc import MutableMapping
from typing import Dict, Optional, Tuple

_Key = Tuple[str, Tuple[Tuple[str, object], ...]]


class _Series:
    """One labelled series.  ``kind`` is fixed at first touch: counters
    accumulate, gauges overwrite, histograms keep count/sum/min/max/last
    (enough for rate, mean, and envelope without storing samples)."""
    __slots__ = ("kind", "value", "count", "total", "vmin", "vmax", "last")

    def __init__(self, kind: str):
        self.kind = kind
        self.value = 0.0                 # counter / gauge
        self.count = 0                   # histogram
        self.total = 0.0
        self.vmin = None
        self.vmax = None
        self.last = None

    def summary(self):
        if self.kind == "histogram":
            return {"count": self.count, "sum": self.total,
                    "min": self.vmin, "max": self.vmax,
                    "mean": (self.total / self.count) if self.count else None,
                    "last": self.last}
        return self.value


class MetricsRegistry:
    """Labelled counter/gauge/histogram store.  Thread-safe: the serving
    batcher and background benchmark loops may increment concurrently."""

    def __init__(self):
        self._series: Dict[_Key, _Series] = {}
        self._lock = threading.Lock()

    @staticmethod
    def _key(name: str, labels: dict) -> _Key:
        return (name, tuple(sorted(labels.items())))

    def _get_series(self, name: str, labels: dict, kind: str) -> _Series:
        key = self._key(name, labels)
        s = self._series.get(key)
        if s is None:
            with self._lock:
                s = self._series.setdefault(key, _Series(kind))
        return s

    def counter(self, name: str, value: float = 1, **labels) -> float:
        """Increment (default +1) and return the series' new total."""
        s = self._get_series(name, labels, "counter")
        with self._lock:
            s.value += value
            return s.value

    def gauge(self, name: str, value: float, **labels):
        """Set a point-in-time value (overwrites)."""
        s = self._get_series(name, labels, "gauge")
        s.value = value

    def observe(self, name: str, value: float, **labels):
        """Record one histogram sample."""
        s = self._get_series(name, labels, "histogram")
        with self._lock:
            s.count += 1
            s.total += value
            s.vmin = value if s.vmin is None else min(s.vmin, value)
            s.vmax = value if s.vmax is None else max(s.vmax, value)
            s.last = value

    def get(self, name: str, default=0.0, **labels):
        """Value of one series (counter/gauge total, histogram summary
        dict), or ``default`` when the series does not exist."""
        s = self._series.get(self._key(name, labels))
        return default if s is None else s.summary()

    def series(self, name: str) -> dict:
        """Every labelled series under one name: {label tuple: summary}."""
        return {lbl: s.summary() for (n, lbl), s in self._series.items()
                if n == name}

    def snapshot(self) -> dict:
        """JSON-ready dump of every series: {name: {label string: summary}}
        where the label string is "k=v,k=v" ("" for unlabelled)."""
        out: dict = {}
        for (name, lbl), s in sorted(self._series.items(),
                                     key=lambda kv: kv[0]):
            key = ",".join(f"{k}={v}" for k, v in lbl)
            out.setdefault(name, {})[key] = s.summary()
        return out

    def dump(self, indent: Optional[int] = 1) -> str:
        return json.dumps(self.snapshot(), indent=indent, sort_keys=True,
                          default=str)

    def reset(self):
        """Drop every series (tests; a fresh process state)."""
        with self._lock:
            self._series.clear()


class StatsView(MutableMapping):
    """Dict-shaped stats facade backed by a ``MetricsRegistry``.

    Reads (``stats["x"]``) come from an instance-local table, exact per
    consumer — the contract the old ad-hoc dicts gave their tests and
    callers.  Writes flow through ``__setitem__`` (so ``stats["x"] += 1``
    works unchanged) and mirror any *positive* delta into the registry
    counter ``<prefix>.<key>`` with the view's bound labels; negative
    deltas (resets) only touch the local table, keeping registry
    counters monotonic across instance lifetimes.

    Integral values read back as ``int`` so reprs and equality checks
    match the old integer dicts."""

    def __init__(self, prefix: str, keys=(), registry=None, **labels):
        self._prefix = prefix
        self._reg = registry if registry is not None else REGISTRY
        self._labels = labels
        self._local: dict = {k: 0 for k in keys}

    def __getitem__(self, key):
        v = self._local[key]
        return int(v) if isinstance(v, float) and v.is_integer() else v

    def __setitem__(self, key, value):
        delta = value - self._local.get(key, 0)
        self._local[key] = value
        if delta > 0:
            self._reg.counter(f"{self._prefix}.{key}", delta,
                              **self._labels)

    def __delitem__(self, key):
        del self._local[key]

    def __iter__(self):
        return iter(self._local)

    def __len__(self):
        return len(self._local)

    def __repr__(self):
        return repr({k: self[k] for k in self._local})

    def __eq__(self, other):
        """Equal to any mapping with the same items (the old dicts were
        compared with literal dicts in tests and call sites)."""
        if isinstance(other, (dict, MutableMapping)):
            return dict(self.items()) == dict(other.items())
        return NotImplemented


# the process-wide default registry; module-level helpers in
# ``repro.obs`` delegate here
REGISTRY = MetricsRegistry()
