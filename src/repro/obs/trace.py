"""Plan-execution tracer: span trees over IR node evaluation.

``Tracer`` records one ``Span`` per evaluated plan node (plus one root
"execute" span per public read), nested exactly as the evaluation
recursion nests — a CutJoin span contains the Contract spans of the
factor tensors it had to materialise, a MobiusCombine span contains its
term evaluations, and a node served from the plan's value memo opens no
span at all.  Each span carries the node key, node class, cut size,
the kernel-vs-XLA route actually taken, the ``exact_block`` guard
outcome, factor shapes, and wall time from ``time.perf_counter``.

JAX dispatch is asynchronous, so a span that closed the instant the
kernel call returned would time the *enqueue*, not the work: callers
fence the evaluated value with ``fence`` (``jax.block_until_ready``)
before the span closes.  Lowering already converts node values to host
floats/arrays (which forces a sync), so the fence is a cheap no-op on
the common path and a correctness backstop everywhere else.

Exports: ``to_dict``/``to_json`` (the span tree, with per-span self
time and a root-coverage summary) and ``to_chrome`` (the Chrome
``chrome://tracing`` / Perfetto "traceEvents" format — load the file at
chrome://tracing to see the plan execute on a timeline).

Zero-dependency: stdlib only, jax imported lazily inside ``fence``.
"""
from __future__ import annotations

import json
import time
from contextlib import contextmanager
from typing import List, Optional


def fence(value):
    """Block until ``value`` is materialised on the host (no-op for
    host floats/ndarrays and when jax is absent); returns ``value``."""
    try:
        import jax
        jax.block_until_ready(value)
    except Exception:
        pass
    return value


class Span:
    """One timed node evaluation.  ``t0``/``t1`` are perf_counter
    seconds relative to the tracer's epoch; ``self_s`` (duration minus
    child durations) is the node's *own* work — the quantity the drift
    report pairs against its predicted cost."""
    __slots__ = ("name", "kind", "attrs", "t0", "t1", "children")

    def __init__(self, name: str, kind: str, attrs: dict, t0: float):
        self.name = name
        self.kind = kind
        self.attrs = attrs
        self.t0 = t0
        self.t1 = t0
        self.children: List[Span] = []

    @property
    def duration_s(self) -> float:
        return self.t1 - self.t0

    @property
    def self_s(self) -> float:
        return max(0.0, self.duration_s
                   - sum(c.duration_s for c in self.children))

    def to_dict(self) -> dict:
        return {"name": self.name, "kind": self.kind,
                "start_us": self.t0 * 1e6,
                "dur_us": self.duration_s * 1e6,
                "self_us": self.self_s * 1e6,
                "attrs": dict(self.attrs),
                "children": [c.to_dict() for c in self.children]}


class Tracer:
    """Collects span trees across one or more plan executions.  Attach
    with ``compiled_plan.tracer = tracer``; every subsequent public read
    (``count`` / ``local_counts`` / ``exists`` / ``domains``) opens a
    root span and nests node spans beneath it."""

    def __init__(self, meta: Optional[dict] = None):
        self.roots: List[Span] = []
        self._stack: List[Span] = []
        self.epoch = time.perf_counter()
        self.meta = dict(meta or {})
        if "backend" not in self.meta:
            try:
                import jax
                self.meta["backend"] = jax.default_backend()
            except Exception:
                self.meta["backend"] = "unknown"

    # -- recording ---------------------------------------------------------------
    @contextmanager
    def span(self, name: str, kind: str = "node", **attrs):
        s = Span(name, kind, attrs, time.perf_counter() - self.epoch)
        if self._stack:
            self._stack[-1].children.append(s)
        else:
            self.roots.append(s)
        self._stack.append(s)
        try:
            yield s
        except BaseException as e:
            s.attrs["error"] = type(e).__name__
            raise
        finally:
            s.t1 = time.perf_counter() - self.epoch
            self._stack.pop()

    def annotate(self, **attrs):
        """Attach attributes to the innermost open span (no-op outside
        any span, so instrumented code paths also run untraced)."""
        if self._stack:
            self._stack[-1].attrs.update(attrs)

    def current(self) -> Optional[Span]:
        return self._stack[-1] if self._stack else None

    # -- analysis ----------------------------------------------------------------
    def walk(self):
        """Every span, depth-first, roots first."""
        stack = list(reversed(self.roots))
        while stack:
            s = stack.pop()
            yield s
            stack.extend(reversed(s.children))

    def coverage(self) -> Optional[float]:
        """Fraction of root-span ("execute") wall time covered by their
        immediate child node spans — how much of a measured end-to-end
        read the per-node accounting explains.  None without roots or
        with zero-duration roots."""
        execs = [r for r in self.roots if r.kind == "execute"] or self.roots
        total = sum(r.duration_s for r in execs)
        if total <= 0.0:
            return None
        inside = sum(c.duration_s for r in execs for c in r.children)
        return inside / total

    # -- export ------------------------------------------------------------------
    def to_dict(self) -> dict:
        cov = self.coverage()
        return {"meta": dict(self.meta),
                "coverage": cov,
                "spans": [r.to_dict() for r in self.roots]}

    def to_json(self, indent: Optional[int] = 1) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    def to_chrome(self) -> dict:
        """Chrome ``chrome://tracing`` "traceEvents" JSON: one complete
        ("ph": "X") event per span, all on one pid/tid so nesting renders
        as flame-graph depth."""
        events = []
        for s in self.walk():
            events.append({"name": s.name, "cat": s.kind, "ph": "X",
                           "ts": s.t0 * 1e6, "dur": s.duration_s * 1e6,
                           "pid": 0, "tid": 0,
                           "args": {k: repr(v) if not isinstance(
                               v, (int, float, str, bool, type(None)))
                               else v for k, v in s.attrs.items()}})
        return {"traceEvents": events, "displayTimeUnit": "ms",
                "otherData": dict(self.meta)}

    def save(self, path: str, fmt: Optional[str] = None) -> str:
        """Write the trace to ``path``.  ``fmt`` is "json" (the span
        tree) or "chrome"; default infers chrome for paths ending in
        ``.chrome.json``, span-tree JSON otherwise."""
        if fmt is None:
            fmt = "chrome" if path.endswith(".chrome.json") else "json"
        with open(path, "w") as fh:
            if fmt == "chrome":
                json.dump(self.to_chrome(), fh, indent=1)
            else:
                fh.write(self.to_json())
        return path
