"""Continuous batching: fixed-slot decode engine with per-slot admission.

Requests arrive with prompts; free slots are filled by prefilling the
prompt (single-request prefill) and splicing its KV into the batch cache
at the slot index; every engine step decodes all active slots at their
own positions; finished sequences (EOS or max_tokens) retire and free
their slot.  This is the vLLM-style serving loop reduced to its essential
batching mechanics on top of ``serve.engine``.

``PatternQueryBatcher`` is the graph-mining counterpart: pattern-count
requests against one graph are drained in batches, grouped by canonical
pattern set, and served through ``repro.compiler`` — the first query of
a pattern set pays compilation (candidate search + costing), every later
query hits the plan cache and goes straight to the lowered executable.
"""
from __future__ import annotations

import collections
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.transformer import Model, init_cache
from repro.serve.engine import greedy_sample, make_decode_step


@dataclass
class Request:
    uid: int
    prompt: np.ndarray                  # (T,) int32
    max_new_tokens: int = 16
    eos_id: int = -1
    generated: list = field(default_factory=list)
    done: bool = False


class ContinuousBatcher:
    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4,
                 capacity: int = 128):
        assert cfg.input_mode == "tokens", "batching driver uses token ids"
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.capacity = capacity
        self.model = Model(cfg)
        self.decode = jax.jit(make_decode_step(cfg), donate_argnums=(1,))
        self.cache = init_cache(cfg, slots, capacity)
        self.positions = np.zeros(slots, np.int32)
        self.last_token = np.zeros(slots, np.int32)
        self.active: dict = {}
        self.queue: collections.deque = collections.deque()
        self.finished: list = []

    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self, slot: int, req: Request) -> bool:
        """Prefill the prompt, sample the first token from the prefill
        logits, and splice the prompt KV into the batch cache.  A request
        already finished by its first token (EOS, or max_new_tokens == 1)
        retires immediately and leaves the slot free: returns False."""
        from repro.models.transformer import cache_specs
        prompt = jnp.asarray(req.prompt[None, :])
        logits, caches, _ = self.model(self.params, prompt, mode="prefill")
        T = req.prompt.shape[0]
        first = int(np.asarray(greedy_sample(logits[0, -1:]))[0])
        req.generated.append(first)
        if first == req.eos_id or len(req.generated) >= req.max_new_tokens:
            req.done = True
            self.finished.append(req)
            return False
        _, ax_tree = cache_specs(self.cfg, 1, T)
        is_axes = lambda t: (isinstance(t, tuple) and
                             all(isinstance(e, (str, type(None)))
                                 for e in t))
        ax_leaves = jax.tree.leaves(ax_tree, is_leaf=is_axes)
        c_leaves, treedef = jax.tree.flatten(caches)
        b_leaves, _ = jax.tree.flatten(self.cache)
        out = []
        for one_c, batch_c, axes in zip(c_leaves, b_leaves, ax_leaves):
            if "kv_seq" in axes:
                sa = axes.index("kv_seq")
                pad = [(0, 0)] * one_c.ndim
                pad[sa] = (0, self.capacity - T)
                one_c = jnp.pad(one_c, pad)
            idx = [slice(None)] * batch_c.ndim
            idx[1] = slice(slot, slot + 1)
            out.append(batch_c.at[tuple(idx)].set(one_c))
        self.cache = jax.tree.unflatten(treedef, out)
        self.positions[slot] = T
        self.last_token[slot] = first
        self.active[slot] = req
        return True

    def step(self):
        # admissions: a request that finishes at prefill frees its slot
        # for the next queued request within the same step
        for slot in range(self.slots):
            while slot not in self.active and self.queue:
                if self._admit(slot, self.queue.popleft()):
                    break
        if not self.active:
            return False
        toks = jnp.asarray(self.last_token[:, None])
        pos = jnp.asarray(self.positions)
        logits, self.cache = self.decode(self.params, self.cache, toks, pos)
        nxt = np.asarray(greedy_sample(logits))
        for slot, req in list(self.active.items()):
            t = int(nxt[slot])
            req.generated.append(t)
            self.positions[slot] += 1
            self.last_token[slot] = t
            if (t == req.eos_id or len(req.generated) >= req.max_new_tokens
                    or self.positions[slot] >= self.capacity - 1):
                req.done = True
                self.finished.append(req)
                del self.active[slot]
        return True

    def run_to_completion(self, max_steps: int = 10_000):
        steps = 0
        while (self.active or self.queue) and steps < max_steps:
            self.step()
            steps += 1
        return steps


# -- graph-mining query serving ---------------------------------------------------

@dataclass
class PatternRequest:
    """One mining query: count every pattern of ``patterns`` in the
    batcher's graph (edge-induced), or — with ``support=True`` — their
    FSM MINI supports (labelled patterns, served off the same compiled
    plan via its domain nodes), or — with ``local=True`` — their
    partial-embedding local counts (the anchored (N,) completion-count
    vector when ``anchor`` names a pattern vertex, else the full local
    tensor over the plan's cutting set; patterns without a cutting set
    fill ``local_counts[p] = None`` for unanchored queries).  With
    ``top_k=K`` the request instead fills ``hotspots[p]`` with the K
    hottest vertices by per-vertex embedding participation as (value,
    vertex) pairs — served off the same partial-embedding plan, without
    ever handing the host a full (N,) vector."""
    uid: int
    patterns: tuple
    support: bool = False               # MINI support instead of counts
    local: bool = False                 # partial-embedding tensors
    anchor: int | None = None           # pattern vertex pin (local=True)
    top_k: int | None = None            # hottest-vertex reader
    counts: dict = field(default_factory=dict)
    supports: dict = field(default_factory=dict)
    local_counts: dict = field(default_factory=dict)
    hotspots: dict = field(default_factory=dict)
    from_cache: bool = False
    done: bool = False
    error: bool = False                 # served neither compiled nor direct


class PatternQueryBatcher:
    """Compile-once-execute-many serving loop for pattern counts.

    Queued requests are drained up to ``max_batch`` per step and grouped
    by (canonical pattern-set signature, support flag, local flag); each
    group compiles (or cache-hits) one joint plan and executes it for
    every request in the group.  Labelled patterns ride the same path —
    decomposition joins included — ``support=True`` requests are served
    off the plan's MINI-domain nodes, and ``local=True`` requests off
    its partial-embedding ``LocalCount`` outputs (anchored vectors pin
    ``req.anchor``; different anchors share one plan — every orbit's
    vector is compiled).  ``top_k=K`` requests return only the K
    hottest vertices by embedding participation as (value, vertex)
    pairs, reduced off the same anchored orbit vectors — serving hosts
    never receive a full (N,) vector.  A shared ``CountingEngine`` keeps the hom
    memo warm across plans, so even distinct pattern sets reuse
    overlapping quotient contractions.
    """

    def __init__(self, graph, *, cache=None, apct=None, max_batch: int = 8,
                 verify_plans: bool = True, mesh=None, morph=False):
        from repro.compiler import PlanCache
        from repro.core.counting import CountingEngine
        self.graph = graph
        self.cache = cache if cache is not None else PlanCache()
        self.apct = apct
        self.max_batch = max_batch
        # morphing count algebra (compiler.morph): False off, True the
        # process store, or a CountStore instance — every compile this
        # batcher issues feeds and reads it, so clustered query traffic
        # (motif families) serves algebraically after a few warm plans
        self.morph = morph
        # layer-1 mesh execution: plans compile against the mesh (their
        # CutJoin/LocalCount routes shard over it) and each step's
        # requests fan out round-robin over the mesh's device slots —
        # concurrent queries stop queueing behind one device.  None
        # keeps the single-device serving loop bit-for-bit unchanged.
        self.mesh = mesh
        self._executor = None
        if mesh is not None:
            from repro.distributed.cutjoin import MeshExecutor
            self._executor = MeshExecutor(mesh)
        # statically verify every plan this batcher compiles (and, via
        # the cache's own verify pass, every plan it loads from disk) —
        # a malformed plan becomes a compile-phase fallback, never a
        # wrong count served to a request
        self.verify_plans = verify_plans
        self.counter = CountingEngine(graph)
        self.queue: collections.deque = collections.deque()
        self.finished: list = []
        self._plans: dict = {}          # pattern-set signature -> CompiledPlan
        # dict-shaped view backed by the metrics registry ("batcher.*"):
        # fallbacks/errors carry per-phase splits — "compile" means the
        # group never got a plan (compilation failed), "execute" means a
        # lowered plan refused at run time (e.g. PlanTooWide) — the
        # plain totals remain for every pre-existing consumer
        from repro import obs
        self.stats = obs.StatsView(
            "batcher", keys=("steps", "compiles", "cache_hits",
                             "fallbacks", "fallbacks_compile",
                             "fallbacks_execute", "errors",
                             "errors_compile", "errors_execute"))

    def submit(self, req: PatternRequest):
        self.queue.append(req)

    def _plan_for(self, sig, patterns: tuple, domains: bool, local: bool):
        """CompiledPlan for one group, memoised per (signature, domains,
        local) so repeat steps reuse the lowered plan (and its
        node-value memo) instead of re-lowering on every plan-cache hit.
        None when compilation fails — callers serve the group via the
        direct path.  ``domains`` compiles MINI-domain nodes for support
        queries; ``local`` compiles partial-embedding outputs."""
        cp = self._plans.get((sig, domains, local))
        if cp is not None:
            self.stats["cache_hits"] += 1
            return cp
        from repro import compiler
        key = compiler.plan_key(patterns, self.graph)
        if key not in self.cache and self.apct is None:
            from repro.core.apct import APCT
            self.apct = APCT(self.graph)       # one profile, all compiles
        try:
            cp = compiler.compile(patterns, self.graph, apct=self.apct,
                                  counter=self.counter, cache=self.cache,
                                  domains=domains, local=local,
                                  verify=self.verify_plans, mesh=self.mesh,
                                  morph=self.morph)
        except Exception:
            return None
        self.stats["cache_hits" if cp.from_cache else "compiles"] += 1
        self._plans[(sig, domains, local)] = cp
        return cp

    def _local_direct(self, p, anchor):
        """Direct-path partial-embedding fallback over the shared
        engine; None for an unanchored query on a cut-less pattern."""
        from repro.api import local_counts as api_local
        try:
            return api_local(p, self.graph, anchor=anchor,
                             counter=self.counter,
                             use_compiler=False).counts
        except ValueError:
            return None

    def _hotspots(self, p, cp, k: int) -> list:
        """Top-k (value, vertex) pairs of per-vertex embedding
        participation, read off the compiled plan's anchored orbit
        vectors through the shared reduction."""
        from repro.api import plan_vertex_counts, top_vertices
        return top_vertices(plan_vertex_counts(cp, p), k)

    def _serve(self, req: PatternRequest, cp):
        """Fill one request: compiled plan first, legacy direct second;
        a request is always finished, never silently dropped.  Fallbacks
        and errors are counted under the phase that failed: ``compile``
        when no plan exists for the group, ``execute`` when the lowered
        plan raised — distinguishing "the compiler can't plan this" from
        "the plan refused this graph" (e.g. PlanTooWide)."""
        from repro.core.fsm import mini_support
        phase = "compile" if cp is None else "execute"
        try:
            if cp is None:
                raise RuntimeError("no compiled plan")
            if req.support:
                req.supports = {p: cp.mini_support(p)
                                for p in req.patterns}
            elif req.top_k is not None:
                req.hotspots = {p: self._hotspots(p, cp, req.top_k)
                                for p in req.patterns}
            elif req.local:
                req.local_counts = {
                    p: (cp.local_counts(p, req.anchor)
                        if cp.has_local(p, req.anchor) else None)
                    for p in req.patterns}
            else:
                req.counts = {p: cp.count(p) for p in req.patterns}
            req.from_cache = cp.from_cache
        except Exception:
            try:                        # e.g. PlanTooWide at execution
                if req.support:
                    req.supports = {p: mini_support(self.counter, p)
                                    for p in req.patterns}
                elif req.top_k is not None:
                    from repro.api import vertex_counts
                    req.hotspots = {
                        p: vertex_counts(p, self.graph,
                                         counter=self.counter,
                                         use_compiler=False,
                                         top_k=req.top_k)
                        for p in req.patterns}
                elif req.local:
                    req.local_counts = {
                        p: self._local_direct(p, req.anchor)
                        for p in req.patterns}
                else:
                    req.counts = {p: self.counter.edge_induced(p)
                                  for p in req.patterns}
                req.from_cache = False
                self.stats["fallbacks"] += 1
                self.stats[f"fallbacks_{phase}"] += 1
            except Exception:
                req.error = True
                self.stats["errors"] += 1
                self.stats[f"errors_{phase}"] += 1
        req.done = True
        self.finished.append(req)

    def step(self) -> bool:
        from repro.compiler.cache import patterns_signature
        if not self.queue:
            return False
        batch = [self.queue.popleft()
                 for _ in range(min(self.max_batch, len(self.queue)))]
        groups: dict = {}
        for req in batch:
            # hottest-vertex requests ride the partial-embedding plan
            # (anchored orbit vectors), so they group with local=True
            groups.setdefault(
                (patterns_signature(req.patterns), req.support,
                 req.local or req.top_k is not None), []).append(req)
        for (sig, support, local), reqs in groups.items():
            cp = self._plan_for(sig, reqs[0].patterns, support, local)
            if self._executor is not None and len(reqs) > 1:
                self._executor.map(lambda req: self._serve(req, cp), reqs)
            else:
                for req in reqs:
                    self._serve(req, cp)
        self.stats["steps"] += 1
        return True

    def run_to_completion(self, max_steps: int = 10_000) -> int:
        steps = 0
        while self.queue and steps < max_steps:
            self.step()
            steps += 1
        return steps
