"""Continuous batching: fixed-slot decode engine with per-slot admission.

Requests arrive with prompts; free slots are filled by prefilling the
prompt (single-request prefill) and splicing its KV into the batch cache
at the slot index; every engine step decodes all active slots at their
own positions; finished sequences (EOS or max_tokens) retire and free
their slot.  This is the vLLM-style serving loop reduced to its essential
batching mechanics on top of ``serve.engine``.
"""
from __future__ import annotations

import collections
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.transformer import Model, init_cache
from repro.serve.engine import greedy_sample, make_decode_step


@dataclass
class Request:
    uid: int
    prompt: np.ndarray                  # (T,) int32
    max_new_tokens: int = 16
    eos_id: int = -1
    generated: list = field(default_factory=list)
    done: bool = False


class ContinuousBatcher:
    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4,
                 capacity: int = 128):
        assert cfg.input_mode == "tokens", "batching driver uses token ids"
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.capacity = capacity
        self.model = Model(cfg)
        self.decode = jax.jit(make_decode_step(cfg), donate_argnums=(1,))
        self.cache = init_cache(cfg, slots, capacity)
        self.positions = np.zeros(slots, np.int32)
        self.last_token = np.zeros(slots, np.int32)
        self.active: dict = {}
        self.queue: collections.deque = collections.deque()
        self.finished: list = []

    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self, slot: int, req: Request):
        """Prefill the prompt, sample the first token from the prefill
        logits, and splice the prompt KV into the batch cache."""
        from repro.models.transformer import cache_specs
        prompt = jnp.asarray(req.prompt[None, :])
        logits, caches, _ = self.model(self.params, prompt, mode="prefill")
        T = req.prompt.shape[0]
        _, ax_tree = cache_specs(self.cfg, 1, T)
        is_axes = lambda t: (isinstance(t, tuple) and
                             all(isinstance(e, (str, type(None)))
                                 for e in t))
        ax_leaves = jax.tree.leaves(ax_tree, is_leaf=is_axes)
        c_leaves, treedef = jax.tree.flatten(caches)
        b_leaves, _ = jax.tree.flatten(self.cache)
        out = []
        for one_c, batch_c, axes in zip(c_leaves, b_leaves, ax_leaves):
            if "kv_seq" in axes:
                sa = axes.index("kv_seq")
                pad = [(0, 0)] * one_c.ndim
                pad[sa] = (0, self.capacity - T)
                one_c = jnp.pad(one_c, pad)
            idx = [slice(None)] * batch_c.ndim
            idx[1] = slice(slot, slot + 1)
            out.append(batch_c.at[tuple(idx)].set(one_c))
        self.cache = jax.tree.unflatten(treedef, out)
        first = int(np.asarray(greedy_sample(logits[0, -1:]))[0])
        req.generated.append(first)
        self.positions[slot] = T
        self.last_token[slot] = first
        self.active[slot] = req

    def step(self):
        # admissions
        for slot in range(self.slots):
            if slot not in self.active and self.queue:
                self._admit(slot, self.queue.popleft())
        if not self.active:
            return False
        toks = jnp.asarray(self.last_token[:, None])
        pos = jnp.asarray(self.positions)
        logits, self.cache = self.decode(self.params, self.cache, toks, pos)
        nxt = np.asarray(greedy_sample(logits))
        for slot, req in list(self.active.items()):
            t = int(nxt[slot])
            req.generated.append(t)
            self.positions[slot] += 1
            self.last_token[slot] = t
            if (t == req.eos_id or len(req.generated) >= req.max_new_tokens
                    or self.positions[slot] >= self.capacity - 1):
                req.done = True
                self.finished.append(req)
                del self.active[slot]
        return True

    def run_to_completion(self, max_steps: int = 10_000):
        steps = 0
        while (self.active or self.queue) and steps < max_steps:
            self.step()
            steps += 1
        return steps
