"""Serving steps: prefill (build KV cache from a prompt batch) and decode
(one token against the cache).  Shapes follow the assignment sheet:
``decode_*`` / ``long_*`` cells lower ``decode_step``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.transformer import Model, cache_specs, init_cache


def make_prefill_step(cfg: ModelConfig):
    model = Model(cfg)

    def prefill_step(params, inputs, image_embeds=None):
        logits, caches, _ = model(params, inputs, mode="prefill",
                                  image_embeds=image_embeds)
        last = logits[:, -1, :]
        return last, caches

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    model = Model(cfg)

    def decode_step(params, caches, inputs, positions, image_embeds=None):
        logits, new_caches, _ = model(params, inputs, mode="decode",
                                      positions=positions, caches=caches,
                                      image_embeds=image_embeds)
        return logits[:, 0, :], new_caches

    return decode_step


def greedy_sample(logits):
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


__all__ = ["make_prefill_step", "make_decode_step", "greedy_sample",
           "cache_specs", "init_cache"]
