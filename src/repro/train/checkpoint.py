"""Sharded checkpointing with async save and atomic commit.

Layout: <dir>/step_<N>/
    manifest.json        tree structure, shapes, dtypes, mesh shape
    arr_<i>.npy          one file per leaf (host-gathered; on a real
                         multi-host cluster each host writes its shard —
                         the manifest records the layout either way)

Writes go to ``step_<N>.tmp`` and are renamed only after fsync — a crash
mid-save never corrupts the latest checkpoint (restore picks the newest
committed step).  ``AsyncCheckpointer`` runs saves on a background thread
(double-buffered: the train loop keeps stepping while the previous state
serialises).  ``restore_resharded`` re-slices a checkpoint onto a
different mesh (elastic scaling).
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil
import threading
from typing import Optional

import jax
import numpy as np


def _flatten(state):
    leaves, treedef = jax.tree.flatten(state)
    return leaves, treedef


def save(directory, step: int, state, extra: Optional[dict] = None):
    d = pathlib.Path(directory)
    tmp = d / f"step_{step}.tmp"
    final = d / f"step_{step}"
    if final.exists():
        return final
    shutil.rmtree(tmp, ignore_errors=True)
    tmp.mkdir(parents=True)
    leaves, treedef = _flatten(state)
    meta = {
        "step": step,
        "treedef": str(treedef),
        "num_leaves": len(leaves),
        "leaves": [],
        "extra": extra or {},
    }
    for i, leaf in enumerate(leaves):
        arr = np.asarray(leaf)
        np.save(tmp / f"arr_{i}.npy", arr)
        meta["leaves"].append({"shape": list(arr.shape),
                               "dtype": str(arr.dtype)})
    with open(tmp / "manifest.json", "w") as f:
        json.dump(meta, f)
        f.flush()
        os.fsync(f.fileno())
    tmp.rename(final)                      # atomic commit
    return final


def latest_step(directory) -> Optional[int]:
    d = pathlib.Path(directory)
    if not d.exists():
        return None
    steps = []
    for p in d.iterdir():
        if p.is_dir() and p.name.startswith("step_") and \
                not p.name.endswith(".tmp") and (p / "manifest.json").exists():
            steps.append(int(p.name.split("_")[1]))
    return max(steps) if steps else None


def restore(directory, step: int, like_state, shardings=None):
    """Restore into the structure of ``like_state`` (shapes/dtypes checked).
    ``shardings``: optional matching tree of NamedShardings to place leaves
    directly (supports restoring onto a different mesh — elastic)."""
    d = pathlib.Path(directory) / f"step_{step}"
    meta = json.loads((d / "manifest.json").read_text())
    leaves, treedef = _flatten(like_state)
    assert meta["num_leaves"] == len(leaves), "structure mismatch"
    sh_leaves = (jax.tree.leaves(
        shardings, is_leaf=lambda x: hasattr(x, "spec"))
        if shardings is not None else [None] * len(leaves))
    out = []
    for i, (ref, sh) in enumerate(zip(leaves, sh_leaves)):
        arr = np.load(d / f"arr_{i}.npy")
        assert tuple(arr.shape) == tuple(np.shape(ref)), (i, arr.shape)
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jax.device_put(arr.astype(ref.dtype)))
    return jax.tree.unflatten(treedef, out)


def restore_latest(directory, like_state, shardings=None):
    s = latest_step(directory)
    if s is None:
        return None, None
    return restore(directory, s, like_state, shardings), s


class AsyncCheckpointer:
    """Background-thread checkpoint writer (one in flight at a time)."""

    def __init__(self, directory):
        self.directory = directory
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, state, extra=None):
        self.wait()
        # snapshot to host before returning control to the train loop
        host_state = jax.tree.map(lambda x: np.asarray(x), state)

        def _work():
            try:
                save(self.directory, step, host_state, extra)
            except BaseException as e:      # noqa: BLE001
                self._error = e

        self._thread = threading.Thread(target=_work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
