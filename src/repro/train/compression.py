"""Gradient compression for cross-pod all-reduces.

Two standard schemes, both with error feedback (the residual from this
step is added to the next step's gradient, so compression error does not
accumulate in expectation):

  * int8 block quantisation: per-block absmax scales, 4x over f32 (2x over
    bf16) wire bytes;
  * top-k sparsification: keep the k largest-magnitude entries per tensor.

``compressed_psum`` shows the intended collective pattern: quantise ->
all-reduce the int8 payload (summing quantised values, one scale psum) ->
dequantise; in pjit programs the quantise/dequantise pair around the
gradient all-reduce achieves the same wire-byte reduction (the hillclimb
quantifies it on the collective roofline term).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class Int8Blocks(NamedTuple):
    q: jax.Array          # int8 payload
    scale: jax.Array      # f32 per-block scales
    shape: tuple


def quantize_int8(x, block: int = 256):
    flat = x.reshape(-1).astype(jnp.float32)
    pad = (-flat.size) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return Int8Blocks(q, scale[:, 0], x.shape)


def dequantize_int8(c: Int8Blocks):
    blocks = c.q.astype(jnp.float32) * c.scale[:, None]
    flat = blocks.reshape(-1)
    import numpy as np
    n = int(np.prod(c.shape)) if c.shape else 1
    return flat[:n].reshape(c.shape)


def topk_sparsify(x, frac: float = 0.01):
    flat = x.reshape(-1).astype(jnp.float32)
    k = max(1, int(flat.size * frac))
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    kept = flat[idx]
    out = jnp.zeros_like(flat).at[idx].set(kept)
    return out.reshape(x.shape), idx, kept


def compress_with_feedback(grads, residuals, scheme: str = "int8",
                           block: int = 256, frac: float = 0.01):
    """Returns (compressed-approx grads, new residuals)."""
    def one(g, r):
        gf = g.astype(jnp.float32) + r
        if scheme == "int8":
            approx = dequantize_int8(quantize_int8(gf, block))
        elif scheme == "topk":
            approx, _, _ = topk_sparsify(gf, frac)
        else:
            raise ValueError(scheme)
        return approx.astype(g.dtype), gf - approx

    out = jax.tree.map(one, grads, residuals)
    new_g = jax.tree.map(lambda t: t[0], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_r = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_g, new_r


def init_residuals(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def wire_bytes(x, scheme: str = "int8", block: int = 256,
               frac: float = 0.01) -> int:
    n = x.size
    if scheme == "int8":
        return n + 4 * ((n + block - 1) // block)
    if scheme == "topk":
        k = max(1, int(n * frac))
        return 8 * k
    return 4 * n
