"""Deterministic, seekable synthetic token pipeline.

Every batch is a pure function of (seed, step), so a restarted job
resumes mid-epoch exactly (no data-order drift after preemption) and any
worker can regenerate any shard — the property a 1000-node input pipeline
needs.  A Zipf-ish unigram mixture with injected n-gram structure gives a
loss surface a 100M model can actually descend.
"""
from __future__ import annotations

import numpy as np


class TokenPipeline:
    def __init__(self, vocab_size: int, seq_len: int, global_batch: int,
                 seed: int = 0, ngram: int = 3):
        self.vocab = vocab_size
        self.seq = seq_len
        self.batch = global_batch
        self.seed = seed
        self.ngram = ngram
        rng = np.random.default_rng(seed)
        # fixed "language": transition tables biasing next-token choices
        self._uni = (1.0 / (np.arange(vocab_size) + 10.0))
        self._uni /= self._uni.sum()
        self._shift = rng.integers(1, vocab_size, size=vocab_size)

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        x = np.empty((self.batch, self.seq + 1), np.int32)
        x[:, 0] = rng.choice(self.vocab, size=self.batch, p=self._uni)
        noise = rng.random((self.batch, self.seq))
        fresh = rng.choice(self.vocab, size=(self.batch, self.seq),
                           p=self._uni)
        for t in range(1, self.seq + 1):
            follow = self._shift[x[:, t - 1]] % self.vocab
            x[:, t] = np.where(noise[:, t - 1] < 0.75, follow,
                               fresh[:, t - 1])
        return {"inputs": x[:, :-1], "labels": x[:, 1:]}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
