"""Fault tolerance: preemption handling, restart, elastic resharding,
straggler watchdog.

Designed for 1000+ node fleets where preemptions and stragglers are the
steady state, not exceptions:

  * ``PreemptionGuard`` — converts SIGTERM/SIGINT into a "save now" flag
    checked once per step; the last completed step is always recoverable.
  * ``resume_or_init`` — restart-from-latest on boot (idempotent relaunch:
    the scheduler can just re-exec the same command on a fresh node set).
  * ``elastic_reshard`` — re-slice a checkpoint onto a new mesh (grow or
    shrink the data axis between runs); parameter shardings are recomputed
    from the same logical rules, so only the device placement changes.
  * ``StepWatchdog`` — per-step wall-time tracker; steps slower than
    ``threshold_x`` times the trailing median are recorded as straggler
    events (on real fleets this feeds the scheduler's drain list; here it
    feeds metrics and tests).
"""
from __future__ import annotations

import signal
import statistics
import time
from typing import Optional

import jax

from repro.distributed.meshes import tree_shardings
from repro.train import checkpoint as ckpt


class PreemptionGuard:
    def __init__(self, signals=(signal.SIGTERM,)):
        self.requested = False
        self._prev = {}
        for s in signals:
            self._prev[s] = signal.signal(s, self._handler)

    def _handler(self, signum, frame):
        self.requested = True

    def restore_handlers(self):
        for s, h in self._prev.items():
            signal.signal(s, h)


def resume_or_init(directory, init_fn, like_state=None, shardings=None):
    """Returns (state, start_step).  Restores the newest committed
    checkpoint if present, else calls init_fn()."""
    like = like_state if like_state is not None else init_fn()
    restored, step = ckpt.restore_latest(directory, like, shardings)
    if restored is None:
        return like, 0
    return restored, step


def elastic_reshard(directory, step, like_state, axes_tree, new_mesh,
                    rules=None):
    """Load a checkpoint and place it onto ``new_mesh`` using the same
    logical sharding rules — the elastic-scaling path (e.g. 256 -> 128
    chips after losing a pod slice)."""
    sh = tree_shardings(axes_tree, jax.tree.map(lambda x: x, like_state),
                        new_mesh, rules)
    return ckpt.restore(directory, step, like_state, sh)


class StepWatchdog:
    def __init__(self, threshold_x: float = 2.5, window: int = 32):
        self.threshold_x = threshold_x
        self.window = window
        self.times: list = []
        self.straggler_events: list = []
        self._t0: Optional[float] = None

    def start(self):
        self._t0 = time.perf_counter()

    def stop(self, step: int):
        dt = time.perf_counter() - self._t0
        hist = self.times[-self.window:]
        if len(hist) >= 5:
            med = statistics.median(hist)
            if dt > self.threshold_x * med:
                self.straggler_events.append((step, dt, med))
        self.times.append(dt)
        return dt
