"""AdamW with cosine schedule, global-norm clipping, and configurable
moment dtype (f32 / bf16) for memory-constrained very-large models.

States are plain pytrees with the same structure (and sharding) as the
parameters, so FSDP/TP sharding of the optimizer comes for free (ZeRO-style
state sharding follows the parameter sharding rules).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: str = "float32"      # "bfloat16" halves optimizer memory


def schedule(c: OptConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(c.warmup_steps, 1), 1.0)
    t = jnp.clip((step - c.warmup_steps)
                 / jnp.maximum(c.total_steps - c.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    frac = c.min_lr_frac + (1 - c.min_lr_frac) * cos
    return c.lr * warm * frac


def init(c: OptConfig, params):
    dt = jnp.dtype(c.state_dtype)
    z = lambda p: jnp.zeros(p.shape, dt)
    return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def update(c: OptConfig, grads, state, params):
    """Returns (new_params, new_state, stats)."""
    step = state["step"] + 1
    lr = schedule(c, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, c.clip_norm / jnp.maximum(gnorm, 1e-9))
    dt = jnp.dtype(c.state_dtype)
    b1, b2 = c.b1, c.b2
    c1 = 1 - b1 ** step.astype(jnp.float32)
    c2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        mh, vh = m32 / c1, v32 / c2
        step_ = mh / (jnp.sqrt(vh) + c.eps) + c.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * step_
        return newp.astype(p.dtype), m32.astype(dt), v32.astype(dt)

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    # unzip the 3-tuples
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_state = {"m": new_m, "v": new_v, "step": step}
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}
