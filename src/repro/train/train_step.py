"""Training step: CE loss, microbatched gradient accumulation, AdamW.

``make_train_step(cfg, opt_cfg, microbatches)`` returns a pure function
``(state, batch) -> (state, metrics)`` suitable for jit/pjit with the
sharding trees from ``state_shardings``.  Gradient accumulation scans over
microbatch slices so the activation peak scales with batch/microbatches —
the knob that lets 100B+ configs fit HBM on the dry-run meshes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.meshes import constrain
from repro.models.transformer import Model


def cross_entropy(logits, labels):
    """logits (B,S,V), labels (B,S) -> mean loss (f32)."""
    lf = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - ll)


def make_loss_fn(cfg: ModelConfig, model: Model, aux_weight: float = 0.01):
    def loss_fn(params, mb):
        logits, _, aux = model(params, mb["inputs"], mode="train",
                               image_embeds=mb.get("image_embeds"))
        ce = cross_entropy(logits, mb["labels"])
        return ce + aux_weight * aux, ce
    return loss_fn


def make_train_step(cfg: ModelConfig, opt_cfg, microbatches: int = 1):
    from repro.train import optimizer as opt

    model = Model(cfg)
    loss_fn = make_loss_fn(cfg, model)

    def split_micro(batch):
        def r(x):
            x = x.reshape((microbatches, x.shape[0] // microbatches)
                          + x.shape[1:])
            return constrain(x, None, "batch", *([None] * (x.ndim - 2)))
        return jax.tree.map(r, batch)

    def train_step(state, batch):
        params = state["params"]

        if microbatches == 1:
            (loss, ce), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            mbs = split_micro(batch)

            def acc(carry, mb):
                gsum, lsum = carry
                (_, ce), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, mb)
                gsum = jax.tree.map(jnp.add, gsum, g)
                return (gsum, lsum + ce), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32
                                                  if p.dtype == jnp.float32
                                                  else jnp.bfloat16), params)
            (grads, ce_sum), _ = jax.lax.scan(acc, (g0, jnp.float32(0.0)), mbs)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = ce = ce_sum / microbatches

        new_params, new_opt, stats = opt.update(
            opt_cfg, grads, state["opt"], params)
        new_state = {"params": new_params, "opt": new_opt}
        metrics = {"loss": loss.astype(jnp.float32),
                   "ce": jnp.asarray(ce, jnp.float32), **stats}
        return new_state, metrics

    return train_step


def init_state(cfg: ModelConfig, opt_cfg, key):
    from repro.train import optimizer as opt

    model = Model(cfg)
    params = model.init(key)
    return {"params": params, "opt": opt.init(opt_cfg, params)}


def abstract_state(cfg: ModelConfig, opt_cfg):
    """ShapeDtypeStruct state for AOT lowering (no allocation)."""
    model = Model(cfg)
    params = model.abstract_params()
    dt = jnp.dtype(opt_cfg.state_dtype)
    mom = jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, dt), params)
    return {"params": params,
            "opt": {"m": mom, "v": mom,
                    "step": jax.ShapeDtypeStruct((), jnp.int32)}}


def state_axes(cfg: ModelConfig):
    """Logical-axes tree matching abstract_state/init_state."""
    model = Model(cfg)
    axes = model.param_axes()
    return {"params": axes, "opt": {"m": axes, "v": axes, "step": ()}}
