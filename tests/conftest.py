"""Shared test configuration.

Hypothesis profiles: property tests in this suite must be reproducible
in CI — a nondeterministic seed that finds a counterexample on one run
and not the next is a flake, not a signal.  The ``ci`` profile
(``derandomize=True``) makes every hypothesis suite draw the same
examples on every run; it activates automatically under ``CI=...`` or
explicitly via ``HYPOTHESIS_PROFILE=ci``.  Local runs keep randomised
search (``dev``) so new counterexamples can still be discovered, with
deadlines off — contraction warm-up easily exceeds the default 200ms.
"""
import os

try:
    from hypothesis import settings
except ImportError:                     # hypothesis optional (importorskip)
    settings = None

if settings is not None:
    settings.register_profile("ci", derandomize=True, deadline=None,
                              print_blob=True)
    settings.register_profile("dev", deadline=None)
    settings.load_profile(os.environ.get(
        "HYPOTHESIS_PROFILE", "ci" if os.environ.get("CI") else "dev"))
