"""Static-analysis layer: mutation-tests the plan-IR verifier (every
seeded corruption rejected with its own diagnostic, golden plans verify
with zero false positives), cache corruption recovery through the
verifier, the exact_block precertification path (no runtime guard scan,
bit-for-bit with the XLA oracle), and the AST lint rules."""
import dataclasses
import json
import math

import numpy as np
import pytest

from repro import analysis, compiler, obs
from repro.analysis import lint as lint_mod
from repro.analysis.verify import GraphInfo, PlanVerifyError, _hom_free_bound
from repro.compiler import frontend, lowering
from repro.compiler.cache import PlanCache
from repro.compiler.ir import (Contract, CutJoin, Intersect, LocalCount,
                               MobiusCombine, Plan, PlanFormatError,
                               ShrinkageCorrect, local_key, pattern_key)
from repro.core import homomorphism as H
from repro.core.counting import CountingEngine
from repro.core.decomposition import cutting_sets
from repro.core.pattern import (Pattern, chain, clique, cycle, mark_free,
                                tailed_triangle)
from repro.graph.generators import erdos_renyi
from repro.graph.storage import Graph

K5_MINUS_EDGE = Pattern(5, [(u, v) for u in range(5)
                            for v in range(u + 1, 5) if (u, v) != (3, 4)])

G = erdos_renyi(24, 4.0, seed=1)


def _compile(pats, g=G, **kw):
    return compiler.compile(pats, g, counter=CountingEngine(g),
                            cache=False, **kw)


# -- plan factories (fresh per corruption — corruptions mutate) --------------------

def _decomposed_plan(p=None):
    """Legacy full-cut |cut| = 2 plan for cycle(4)."""
    p = p or cycle(4)
    cand = frontend.decomposed_candidate(p, frozenset({0, 2}), graph_n=G.n,
                                         budget=1 << 27, max_cut=3)
    assert cand is not None
    return frontend.assemble([(p, cand)])


def _subset_plan():
    """Axis-subset |cut| = 3 plan for K5-minus-edge."""
    p = K5_MINUS_EDGE
    cut = min((c for c in cutting_sets(p) if len(c) == 3), key=sorted)
    cand = frontend.decomposed_candidate(p, cut, graph_n=G.n,
                                         budget=1 << 27, max_cut=3)
    assert cand is not None and cand.style == "decomposed-subset"
    return frontend.assemble([(p, cand)])


def _local_plan():
    """Anchored keep-axis LocalCount plan for cycle(4)."""
    p = cycle(4)
    cand = frontend.local_candidate(p, frozenset({0, 2}), graph_n=G.n,
                                    anchor=0, budget=1 << 27, max_cut=3)
    assert cand is not None
    plan = Plan()
    for node in cand.nodes:
        plan.add(node)
    plan.set_local_output(p, cand.out_key, anchor=0)
    return plan


def _direct_clique_plan():
    cand = frontend.direct_candidate(clique(4))
    return frontend.assemble([(clique(4), cand)])


def _free_contract(p, free, key):
    """A well-formed marker-encoded free-hom Contract over ``p``."""
    _, qc, free_c = mark_free(p, free)
    return Contract(key, qc, H.greedy_plan(qc, free_c), free_c)


def _node_of(plan, cls):
    return next(k for k, n in plan.nodes.items() if isinstance(n, cls))


def _replace(plan, key, **repl):
    plan.nodes[key] = dataclasses.replace(plan.nodes[key], **repl)
    return plan


# -- the mutation corpus -----------------------------------------------------------
#
# Each entry seeds ONE corruption class and names the diagnostic code
# that must reject it.  Expected codes are pairwise distinct across the
# corpus — the verifier distinguishes every failure class, not just
# "invalid".  Entries return (plan, verify_kwargs).

def _c_dangling_ref():
    plan = _decomposed_plan()
    key = _node_of(plan, ShrinkageCorrect)
    return _replace(plan, key, corrections=((1.0, "ghost:node"),)), {}


def _c_cycle():
    plan = _decomposed_plan()
    plan.nodes["a:x"] = MobiusCombine("a:x", ((1.0, "b:x"),))
    plan.nodes["b:x"] = MobiusCombine("b:x", ((1.0, "a:x"),))
    return plan, {}


def _c_key_mismatch():
    plan = _decomposed_plan()
    key = _node_of(plan, Contract)
    plan.nodes["not:" + key] = plan.nodes[key]
    return plan, {}


def _c_output_missing():
    plan = _decomposed_plan()
    plan.outputs["9.99"] = "ghost:node"
    return plan, {}


def _c_unknown_node_class():
    plan = _decomposed_plan()
    plan.nodes["alien"] = object()
    return plan, {}


def _c_axis_out_of_range():
    plan = _subset_plan()
    key = _node_of(plan, CutJoin)
    join = plan.nodes[key]
    i = next(i for i, a in enumerate(join.axes) if len(a) == 2)
    axes = tuple((0, 7) if j == i else a for j, a in enumerate(join.axes))
    return _replace(plan, key, axes=axes), {}


def _c_axes_arity():
    plan = _subset_plan()
    key = _node_of(plan, CutJoin)
    join = plan.nodes[key]
    return _replace(plan, key, axes=join.axes[:-1]), {}


def _c_cut_uncovered():
    plan = _decomposed_plan()
    ref = _node_of(plan, Contract)          # rank-2 free-hom tensor
    plan.nodes["cj:test"] = CutJoin(
        "cj:test", 3, (((1.0, ref),), ((1.0, ref),)),
        axes=((0, 1), (0, 1)))              # rank 2 never spanned
    return plan, {}


def _c_illegal_subset_axes():
    plan = _decomposed_plan()
    vec = _free_contract(chain(2), (0,), "homf:vec-test")
    plan.nodes[vec.key] = vec
    plan.nodes["cj:test"] = CutJoin(
        "cj:test", 2, (((1.0, vec.key),), ((1.0, vec.key),)),
        axes=((0,), (1,)))                  # subsets at |cut| = 2
    return plan, {}


def _c_keep_outside_cut():
    plan = _local_plan()
    return _replace(plan, _node_of(plan, LocalCount), keep=(5,)), {}


def _c_illegal_keep():
    plan = _subset_plan()
    ref3 = next(k for k, n in plan.nodes.items()
                if isinstance(n, Contract) and len(n.free) == 3)
    plan.nodes["lc:test"] = LocalCount("lc:test", 3, (0, 1),
                                       (((1.0, ref3),),))
    return plan, {}


def _c_illegal_route():
    plan = _decomposed_plan()
    r4 = _free_contract(chain(5), (0, 1, 2, 3), "homf:r4-test")
    plan.nodes[r4.key] = r4
    plan.nodes["lc:test"] = LocalCount("lc:test", 4, (0,),
                                       (((1.0, r4.key),),))
    return plan, {}


def _c_budget_overflow():
    # a committed 3-cut join whose factor elements blow 4x a tiny budget
    return _subset_plan(), {"graph_info": GraphInfo(24, 8, 2), "budget": 10}


def _c_bad_label_encoding():
    plan = _decomposed_plan()
    key = _node_of(plan, Contract)
    node = plan.nodes[key]
    stripped = Pattern(node.pattern.n, node.pattern.edges)   # markers gone
    return _replace(plan, key, pattern=stripped), {}


def _c_bad_divisor():
    plan = _decomposed_plan()
    return _replace(plan, _node_of(plan, ShrinkageCorrect), divisor=0), {}


def _c_bad_intersect():
    plan = _direct_clique_plan()
    return _replace(plan, _node_of(plan, Intersect), k=2), {}


def _c_shape_mismatch():
    plan = _decomposed_plan()
    key = _node_of(plan, CutJoin)
    join = plan.nodes[key]
    scalar = Contract("hom:scalar-test", cycle(4),
                      H.greedy_plan(cycle(4)))
    plan.nodes[scalar.key] = scalar
    factors = (((1.0, scalar.key),),) + join.factors[1:]
    return _replace(plan, key, factors=factors), {}


def _c_bad_shrinkage_base():
    plan = _decomposed_plan()
    tensor = _node_of(plan, Contract)       # rank-2, not a scalar join
    return _replace(plan, _node_of(plan, ShrinkageCorrect), base=tensor), {}


def _c_bad_coefficient():
    plan = _decomposed_plan()
    key = _node_of(plan, CutJoin)
    join = plan.nodes[key]
    (c0, r0), *rest = join.factors[0]
    factors = ((((float("nan"), r0),) + tuple(rest)),) + join.factors[1:]
    return _replace(plan, key, factors=factors), {}


def _c_empty_join():
    plan = _decomposed_plan()
    return _replace(plan, _node_of(plan, CutJoin), factors=()), {}


def _c_bad_cut_size():
    plan = _decomposed_plan()
    return _replace(plan, _node_of(plan, CutJoin), cut_size=0), {}


def _c_output_shape():
    plan = _decomposed_plan()
    plan.outputs[pattern_key(cycle(4))] = _node_of(plan, Contract)
    return plan, {}


def _c_bad_free():
    plan = _decomposed_plan()
    key = _node_of(plan, Contract)
    node = plan.nodes[key]
    return _replace(plan, key, free=(node.free[0],) * 2), {}


CORPUS = [
    ("dangling-ref", _c_dangling_ref),
    ("cycle", _c_cycle),
    ("key-mismatch", _c_key_mismatch),
    ("output-missing", _c_output_missing),
    ("unknown-node-class", _c_unknown_node_class),
    ("axis-out-of-range", _c_axis_out_of_range),
    ("axes-arity", _c_axes_arity),
    ("cut-uncovered", _c_cut_uncovered),
    ("illegal-subset-axes", _c_illegal_subset_axes),
    ("keep-outside-cut", _c_keep_outside_cut),
    ("illegal-keep", _c_illegal_keep),
    ("illegal-route", _c_illegal_route),
    ("budget-overflow", _c_budget_overflow),
    ("bad-label-encoding", _c_bad_label_encoding),
    ("bad-divisor", _c_bad_divisor),
    ("bad-intersect", _c_bad_intersect),
    ("shape-mismatch", _c_shape_mismatch),
    ("bad-shrinkage-base", _c_bad_shrinkage_base),
    ("bad-coefficient", _c_bad_coefficient),
    ("empty-join", _c_empty_join),
    ("bad-cut-size", _c_bad_cut_size),
    ("output-shape", _c_output_shape),
    ("bad-free", _c_bad_free),
]


def test_corpus_codes_pairwise_distinct():
    codes = [code for code, _ in CORPUS]
    assert len(set(codes)) == len(codes)
    assert len(codes) >= 10                  # the issue's floor, 2x over


@pytest.mark.parametrize("expected,build",
                         CORPUS, ids=[c for c, _ in CORPUS])
def test_mutation_rejected_with_its_diagnostic(expected, build):
    plan, kw = build()
    res = analysis.verify(plan, **kw)
    assert not res.ok, expected
    assert expected in {d.code for d in res.errors}, \
        (expected, [str(d) for d in res.errors])


def test_uncorrupted_factories_verify_clean():
    """The corpus factories start from valid plans — the rejection is
    the corruption's doing, not the construction's."""
    for plan in (_decomposed_plan(), _subset_plan(), _local_plan(),
                 _direct_clique_plan()):
        res = analysis.verify(plan)
        assert res.ok, str(res)


# -- golden plans: zero false positives --------------------------------------------

GOLDEN = [
    ((cycle(4),), {}),
    ((chain(5),), {}),
    ((K5_MINUS_EDGE,), {}),
    ((clique(3), clique(4)), {}),
    ((cycle(4), chain(4)), {"local": True}),
    ((K5_MINUS_EDGE,), {"local": True}),     # locd: Möbius-fallback orbit
    ((chain(4),), {"domains": True}),
]


@pytest.mark.parametrize("pats,kw", GOLDEN,
                         ids=[f"golden{i}" for i in range(len(GOLDEN))])
def test_golden_plans_verify_clean(pats, kw):
    cp = _compile(pats, **kw)
    res = analysis.verify(cp.plan)           # meta carries graph_info/budget
    assert res.ok and not res.warnings, str(res)


def test_golden_labelled_plan_verifies_clean():
    g = erdos_renyi(24, 4.0, seed=1, num_labels=3)
    p = Pattern(4, [(0, 1), (1, 2), (2, 3), (3, 0)], (0, 1, 0, 1))
    cp = _compile((p,), g=g, local=True)
    res = analysis.verify(cp.plan)
    assert res.ok, str(res)


def test_infer_shapes_matches_execution():
    cp = _compile((cycle(4), chain(4)), local=True)
    shapes = analysis.infer_shapes(cp.plan, G.n)
    for name, target in cp.plan.outputs.items():
        got = cp.value(target)
        assert np.shape(np.asarray(got)) == shapes[target][0], name


# -- PlanFormatError / cache corruption --------------------------------------------

def test_plan_format_error_is_typed_valueerror():
    d = _decomposed_plan().to_dict()
    d["version"] = 999
    with pytest.raises(PlanFormatError):
        Plan.from_dict(d)
    with pytest.raises(ValueError):          # existing handlers keep working
        Plan.from_dict(d)
    with pytest.raises(PlanFormatError):
        from repro.compiler.ir import op_from_dict
        op_from_dict({"op": "nonsense"})


def _seed_cache(tmp_path):
    cache = PlanCache(str(tmp_path))
    cache.put("k1", _decomposed_plan())
    return tmp_path / "plan-k1.json"


def test_cache_truncated_entry_misses_cleanly(tmp_path):
    f = _seed_cache(tmp_path)
    f.write_text(f.read_text()[:40])
    fresh = PlanCache(str(tmp_path))
    assert fresh.get("k1") is None
    assert fresh.misses == 1 and fresh.format_misses == 1
    assert fresh.verify_rejects == 0


def test_cache_field_dropped_entry_misses_cleanly(tmp_path):
    f = _seed_cache(tmp_path)
    d = json.loads(f.read_text())
    node = next(n for n in d["nodes"] if n["op"] == "shrinkage")
    del node["divisor"]
    f.write_text(json.dumps(d))
    fresh = PlanCache(str(tmp_path))
    assert fresh.get("k1") is None
    assert fresh.format_misses == 1 and fresh.verify_rejects == 0


def test_cache_bit_flipped_entry_rejected_by_verifier(tmp_path):
    """A single-bit flip the schema can't see: cut_size 2 -> 3 still
    parses, but the verifier catches the rank mismatch — without it this
    entry would lower and serve garbage."""
    f = _seed_cache(tmp_path)
    data = bytearray(f.read_bytes())
    i = bytes(data).index(b'"cut_size": 2') + len(b'"cut_size": ')
    data[i] ^= 0x01                           # ASCII '2' -> '3'
    f.write_bytes(bytes(data))
    assert json.loads(f.read_text())          # parses fine
    fresh = PlanCache(str(tmp_path))
    assert fresh.get("k1") is None
    assert fresh.verify_rejects == 1 and fresh.format_misses == 0
    assert fresh.misses == 1


def test_cache_verify_opt_out_loads_corrupt_entry(tmp_path):
    f = _seed_cache(tmp_path)
    data = bytearray(f.read_bytes())
    i = bytes(data).index(b'"cut_size": 2') + len(b'"cut_size": ')
    data[i] ^= 0x01
    f.write_bytes(bytes(data))
    trusting = PlanCache(str(tmp_path), verify=False)
    assert trusting.get("k1") is not None     # the gap verify=True closes


def test_cache_valid_entry_still_hits_through_verifier(tmp_path):
    _seed_cache(tmp_path)
    fresh = PlanCache(str(tmp_path))
    assert fresh.get("k1") is not None
    assert fresh.hits == 1 and fresh.verify_rejects == 0
    assert fresh.format_misses == 0


def test_compile_roundtrip_verifies_hypothesis():
    """Property: compile a random small pattern set, serialize,
    deserialize, verify — the frontend only emits plans the verifier
    accepts, through a JSON round-trip."""
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    pool = [cycle(4), chain(3), chain(5), tailed_triangle(), clique(3),
            cycle(5)]
    eng = CountingEngine(G)

    @settings(max_examples=6, deadline=None)
    @given(st.lists(st.integers(0, len(pool) - 1), min_size=1, max_size=2,
                    unique=True), st.booleans())
    def check(idx, local):
        pats = tuple(pool[i] for i in idx)
        cp = compiler.compile(pats, G, counter=eng, cache=False,
                              local=local)
        back = Plan.from_json(cp.plan.to_json())
        assert back == cp.plan
        res = analysis.verify(back)
        assert res.ok, str(res)
        assert res.precert == cp.plan.meta["precert"]

    check()


# -- exact_block precertification --------------------------------------------------

def test_hom_free_bound_is_sound():
    eng = CountingEngine(G)
    for p, free in [(chain(3), (0, 2)), (chain(4), (0, 3)),
                    (cycle(4), (0, 2))]:
        actual = float(np.max(np.abs(
            np.asarray(eng.hom_free_tensor(p, free)))))
        bound = _hom_free_bound(p, free, GraphInfo.from_graph(G))
        assert bound >= actual, (p, bound, actual)


def test_precertified_plan_skips_guard_scan_bit_for_bit():
    cp = _compile((cycle(4),))
    assert cp.plan.meta["precert"], "2-cut join on a sparse graph " \
        "should precertify"
    tr = obs.Tracer()
    cp.tracer = tr
    got = cp.count(cycle(4))
    kinds = [s.kind for s in tr.walk()]
    assert "guard-scan" not in kinds, kinds
    joins = [s for s in tr.walk() if s.kind == "CutJoin"]
    assert joins
    for s in joins:
        assert s.attrs["route"] == "kernel"
        assert s.attrs["precertified"] and s.attrs["exact_block"] is not None
    oracle = _compile((cycle(4),), cutjoin_kernel=False)
    assert got == oracle.count(cycle(4))      # bit-for-bit vs XLA


def test_unprecertified_plan_still_guard_scans():
    n = 40
    dense = Graph(n, np.array([(u, v) for u in range(n)
                               for v in range(u + 1, n)]))
    cp = _compile((chain(6),), g=dense)
    assert cp.plan.meta["precert"] == {}      # degree bound blows the limit
    tr = obs.Tracer()
    cp.tracer = tr
    got = cp.count(chain(6))
    assert "guard-scan" in [s.kind for s in tr.walk()]
    oracle = _compile((chain(6),), g=dense, cutjoin_kernel=False)
    assert got == oracle.count(chain(6))


def test_always_refused_flagged_at_verify_time():
    plan = _compile((cycle(4),)).plan
    huge = GraphInfo(n=4096, max_degree=4095, min_degree=4000)
    res = analysis.verify(plan, graph_info=huge)
    assert res.ok
    assert "always-refused" in {d.code for d in res.warnings}
    assert analysis.precertify(plan, huge) == {}


def test_lower_verify_flag_rejects_corrupt_plan():
    plan, _ = _c_shape_mismatch()
    with pytest.raises(PlanVerifyError):
        lowering.lower(plan, G, verify=True)
    lowering.lower(plan, G)                   # binding alone stays lazy


def test_batcher_verify_plans_param_threads_through():
    from repro.serve.batching import PatternQueryBatcher, PatternRequest
    b = PatternQueryBatcher(G, cache=PlanCache(), verify_plans=True)
    b.submit(PatternRequest(uid=1, patterns=(chain(3),)))
    b.run_to_completion()
    (done,) = b.finished
    assert done.counts and not done.error


# -- lint rules --------------------------------------------------------------------

def _findings(src):
    return lint_mod.lint_source(src, "t.py")


def test_lint_time_time_and_suppression():
    bad = "import time\nt0 = time.time()\n"
    assert [f.rule for f in _findings(bad)] == ["no-time-time"]
    ok = "import time\nt0 = time.time()  # lint: allow=no-time-time\n"
    assert _findings(ok) == []
    fine = "import time\nt0 = time.perf_counter()\n"
    assert _findings(fine) == []


def test_lint_mutable_default():
    bad = "def f(x, acc=[]):\n    return acc\n"
    assert [f.rule for f in _findings(bad)] == ["no-mutable-default"]
    bad2 = "def f(*, memo=dict()):\n    return memo\n"
    assert [f.rule for f in _findings(bad2)] == ["no-mutable-default"]
    ok = "def f(x, acc=None, k=()):\n    return acc\n"
    assert _findings(ok) == []


def test_lint_kernel_guard_protocol():
    bad = ("from repro.kernels import ops\n"
           "def join(Ms):\n"
           "    return ops.cutjoin_reduce(Ms, bm=128, bn=128)\n")
    assert [f.rule for f in _findings(bad)] == ["kernel-guard"]
    ok = ("from repro.kernels import ops\n"
          "def join(Ms):\n"
          "    block = ops.cutjoin_exact_block(Ms)\n"
          "    if block is None:\n"
          "        return None\n"
          "    return ops.cutjoin_reduce(Ms, bm=block, bn=block)\n")
    assert _findings(ok) == []
    # class scope counts: a guard helper method covers sibling methods
    ok2 = ("from repro.kernels import ops\n"
           "class P:\n"
           "    def guard(self, Ms):\n"
           "        return ops.cutjoin_exact_block(Ms)\n"
           "    def join(self, Ms):\n"
           "        b = self.guard(Ms)\n"
           "        return ops.cutjoin_reduce(Ms, bm=b, bn=b)\n")
    assert _findings(ok2) == []


def test_lint_ir_dict_complete():
    bad = ("from dataclasses import dataclass\n"
           "@dataclass(frozen=True)\n"
           "class Op:\n"
           "    key: str\n"
           "    extra: int\n"
           "    def refs(self):\n"
           "        return ()\n"
           "    def to_dict(self):\n"
           "        return {'key': self.key}\n"
           "def op_from_dict(d):\n"
           "    return Op(d['key'], 0)\n")
    rules = sorted(f.rule for f in _findings(bad))
    assert rules == ["ir-dict-complete", "ir-dict-complete"]  # both sides
    # plain dataclasses without the IR-op shape are out of scope
    ok = ("from dataclasses import dataclass\n"
          "@dataclass\n"
          "class Cfg:\n"
          "    key: str\n"
          "    extra: int\n")
    assert _findings(ok) == []


def test_lint_clean_over_src_repro():
    """The CI gate, as a test: the lint runs clean over the package."""
    import repro
    from pathlib import Path
    pkg = Path(next(iter(repro.__path__)))
    findings = lint_mod.lint_paths([pkg])
    assert findings == [], "\n".join(str(f) for f in findings)


def test_lint_cli_exit_codes(tmp_path):
    bad = tmp_path / "bad.py"
    bad.write_text("import time\nt0 = time.time()\n")
    assert lint_mod.main([str(bad)]) == 1
    good = tmp_path / "good.py"
    good.write_text("x = 1\n")
    assert lint_mod.main([str(good)]) == 0
    assert lint_mod.main(["--list-rules"]) == 0
