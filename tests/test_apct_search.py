"""APCT estimator, cost model, and decomposition-space search."""
import math

import numpy as np
import pytest

from repro.core import cost_model as CM
from repro.core import search as S
from repro.core.apct import APCT, estimate_inj
from repro.core.counting import CountingEngine
from repro.core.motifs import motif_patterns
from repro.core.pattern import chain, clique
from repro.graph.generators import erdos_renyi, triangle_rich

G = triangle_rich(120, 8, seed=4)


@pytest.fixture(scope="module")
def apct():
    return APCT(G, num_samples=20_000)


def test_apct_accurate_on_frequent_patterns(apct):
    eng = CountingEngine(G)
    for p in [chain(3), clique(3), chain(4)]:
        exact = eng.inj(p)
        est = apct.query(p)
        if exact > 100:
            assert 0.5 * exact <= est <= 2.0 * exact, (p, est, exact)


def test_apct_miss_insertion(apct):
    before = apct.misses
    p6 = motif_patterns(6)[3]
    apct.query(p6)                        # size-6: not profiled
    assert apct.misses == before + 1
    apct.query(p6)                        # now cached
    assert apct.misses == before + 1


def test_apct_unbiased_estimator():
    eng = CountingEngine(G)
    exact = eng.inj(clique(3))
    ests = [estimate_inj(G, clique(3), 40_000, seed=s) for s in range(5)]
    assert abs(np.mean(ests) - exact) / exact < 0.25


def test_cost_model_prefers_cheap_patterns(apct):
    # chain counting costs more than clique counting at equal size (paper §2.4)
    c_chain = CM.pattern_cost(chain(5), None, apct, G.n)
    c_clique = CM.pattern_cost(clique(5), None, apct, G.n)
    assert c_chain > c_clique


def test_cost_model_reuse_reduces_joint_cost(apct):
    pats = motif_patterns(4)
    sep = sum(CM.pattern_cost(p, None, apct, G.n) for p in pats)
    joint = CM.application_cost([(p, None) for p in pats], apct, G.n)
    assert joint <= sep


def test_circulant_no_worse_than_separate(apct):
    pats = motif_patterns(4)
    r_sep = S.separate_tuning(pats, apct, G.n)
    r_circ = S.circulant_tuning(pats, apct, G.n)
    assert r_circ.cost <= r_sep.cost + 1e-9
    assert len(r_circ.cuts) == len(pats)


def test_search_methods_return_valid_cuts(apct):
    pats = motif_patterns(4)
    for name, fn in S.METHODS.items():
        r = fn(pats, apct, G.n)
        assert len(r.cuts) == len(pats), name
        from repro.core.decomposition import candidates
        for p, cut in zip(pats, r.cuts):
            assert cut in candidates(p), (name, p, cut)


def test_automine_model_underestimates_clustered_graphs(apct):
    """Fig 19 argument: the random-graph model misses structural locality,
    so its clique trip-count estimate falls far below the APCT estimate on
    a clustered graph."""
    d = float(np.mean(G.degrees))
    am = CM.plan_cost_automine(clique(4), tuple(range(4)), G.n, d)
    ours = CM.plan_cost_apct(clique(4), tuple(range(4)), apct, G.n)
    eng = CountingEngine(G)
    exact_k4 = eng.inj(clique(4))
    if exact_k4 > 0:
        assert ours > am
