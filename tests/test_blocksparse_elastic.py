"""Block-sparse counting backend + elastic resharding + genetic search."""
import jax
import numpy as np
import pytest

from repro.core.blocksparse import (BlockSparseAdjacency, blocksparse_flops,
                                    dense_flops, triangle_count_blocksparse,
                                    wedge_count_blocksparse)
from repro.core.counting import CountingEngine
from repro.core.pattern import chain, clique
from repro.graph.generators import erdos_renyi, triangle_rich


@pytest.mark.parametrize("g", [erdos_renyi(300, 6.0, seed=1),
                               triangle_rich(400, 10, seed=2)])
def test_blocksparse_triangles_match_engine(g):
    bsa = BlockSparseAdjacency(g, tile=64)
    eng = CountingEngine(g)
    want = eng.edge_induced(clique(3))
    assert abs(triangle_count_blocksparse(bsa) - want) < 1e-6


def test_blocksparse_kernel_path_matches():
    g = erdos_renyi(256, 8.0, seed=3)
    bsa = BlockSparseAdjacency(g, tile=64)
    plain = triangle_count_blocksparse(bsa, use_kernel=False)
    kern = triangle_count_blocksparse(bsa, use_kernel=True)
    assert abs(plain - kern) < 1e-3


def test_blocksparse_wedges_match():
    g = erdos_renyi(1024, 6.0, seed=4)
    bsa = BlockSparseAdjacency(g, tile=128)
    eng = CountingEngine(g)
    want = eng.edge_induced(chain(3))
    assert abs(wedge_count_blocksparse(bsa) - want) < 1e-6


def test_blocksparse_flops_saving_on_clustered_graphs():
    # block-sparsity needs locality: a community graph is near-diagonal,
    # uniform ER at this size touches every tile (occupancy 1)
    from repro.graph.storage import Graph
    rng = np.random.default_rng(0)
    n, csize = 4096, 128
    edges = []
    for c in range(n // csize):
        lo = c * csize
        u = rng.integers(lo, lo + csize, 4 * csize)
        v = rng.integers(lo, lo + csize, 4 * csize)
        edges.append(np.stack([u, v], 1))
    g = Graph(n, np.concatenate(edges))
    bsa = BlockSparseAdjacency(g, tile=128)
    assert bsa.occupancy < 0.1
    assert blocksparse_flops(bsa) < 0.1 * dense_flops(bsa.nb * bsa.tile)
    er = BlockSparseAdjacency(erdos_renyi(1024, 6.0, seed=4), tile=128)
    assert er.occupancy == 1.0


def test_elastic_reshard_to_new_mesh(tmp_path):
    """Checkpoint on one mesh shape, restore onto another (elastic)."""
    import os
    import subprocess
    import sys
    import textwrap
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    code = f"""
        import jax, numpy as np
        from repro.configs.base import reduced_config
        from repro.configs.registry import get_config
        from repro.train import checkpoint as ckpt
        from repro.train.fault_tolerance import elastic_reshard
        from repro.train.optimizer import OptConfig
        from repro.train.train_step import init_state, state_axes
        from repro.distributed.meshes import tree_shardings
        cfg = reduced_config(get_config("qwen3-4b"), num_layers=2)
        oc = OptConfig()
        state = init_state(cfg, oc, jax.random.PRNGKey(0))
        ckpt.save(r"{tmp_path}", 5, state)
        # restore onto a (4,2) mesh, then onto a (2,4) mesh — the elastic
        # path re-slices the same logical shardings
        for shp in ((4, 2), (2, 4)):
            from repro.launch.mesh import make_host_mesh
            mesh = make_host_mesh(shp, ("data", "model"))
            restored = elastic_reshard(r"{tmp_path}", 5, state,
                                       state_axes(cfg), mesh)
            a = jax.tree.leaves(restored)[0]
            b = jax.tree.leaves(state)[0]
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
            assert len(a.sharding.device_set) >= 2
        print("OK")
    """
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, timeout=560)
    assert "OK" in r.stdout, r.stdout + r.stderr


def test_genetic_search_valid():
    from repro.core import search as S
    from repro.core.apct import APCT
    from repro.core.motifs import motif_patterns
    g = erdos_renyi(128, 6.0, seed=5)
    apct = APCT(g, num_samples=2048)
    pats = motif_patterns(4)
    r = S.genetic(pats, apct, g.n, pop=8, gens=4)
    from repro.core.decomposition import candidates
    assert len(r.cuts) == len(pats)
    for p, cut in zip(pats, r.cuts):
        assert cut in candidates(p)
