"""Clique path: ordered enumeration vs tensor engine vs brute force."""
import itertools

import numpy as np
import pytest

from repro.core.cliques import (clique_count, clique_minus_edge_count,
                                pseudo_clique_count)
from repro.core.counting import CountingEngine, brute_force_vertex_induced
from repro.core.pattern import Pattern, clique
from repro.graph.generators import erdos_renyi, triangle_rich

GRAPHS = [erdos_renyi(25, 6.0, seed=1), triangle_rich(30, 4, seed=2)]


@pytest.mark.parametrize("gi", range(len(GRAPHS)))
@pytest.mark.parametrize("k", [3, 4, 5])
def test_clique_count_matches_bruteforce(gi, k):
    g = GRAPHS[gi]
    want = 0
    for vs in itertools.combinations(range(g.n), k):
        if all(g.has_edge(a, b) for a, b in itertools.combinations(vs, 2)):
            want += 1
    assert clique_count(g, k) == want


@pytest.mark.parametrize("k", [3, 4])
def test_clique_minus_edge_matches_bruteforce(k):
    g = GRAPHS[0]
    p = Pattern(k, set(clique(k).edges) - {(0, 1)})
    want = brute_force_vertex_induced(g, p)
    assert clique_minus_edge_count(g, k) == want


def test_engine_routes_cliques_consistently():
    """hom(K_k) via the clique path equals the paper's identity and the
    tensor path on a small graph."""
    import math
    g = GRAPHS[0]
    eng = CountingEngine(g)
    for k in (3, 4):
        assert eng.hom(clique(k)) == math.factorial(k) * clique_count(g, k)
    # triangle double-check against the tensor engine directly
    import jax.numpy as jnp
    from repro.core import homomorphism as H
    A = jnp.asarray(g.dense_adjacency(np.float64, pad=False))
    assert float(H.hom_count(clique(3), A)) == eng.hom(clique(3))


def test_plan_too_wide_raises():
    from repro.core import homomorphism as H
    from repro.core.homomorphism import PlanTooWide
    import jax.numpy as jnp
    g = erdos_renyi(64, 6.0, seed=3)
    A = jnp.asarray(g.dense_adjacency(np.float32, pad=False))
    with pytest.raises(PlanTooWide):
        H.hom_count(clique(5), A, budget=1 << 8)


def test_pseudo_clique_count_large_graph():
    g = erdos_renyi(300, 10.0, seed=4)
    total = pseudo_clique_count(g, 4)
    eng = CountingEngine(g)
    from repro.core.pattern import pseudo_clique
    want = eng.vertex_induced(clique(4))
    for p in pseudo_clique(4, 1):
        want += eng.vertex_induced(p)
    assert total == want
