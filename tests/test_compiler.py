"""Compiler subsystem: golden plan IR, plan cache, compiled-vs-engine
equivalence, decomposition-join exactness, serving batcher."""
import numpy as np
import pytest

from repro import compiler
from repro.compiler import costing, frontend, lowering
from repro.compiler.cache import PlanCache, graph_signature, plan_key
from repro.compiler.ir import (Contract, CutJoin, Intersect, MobiusCombine,
                               Plan, ShrinkageCorrect, pattern_key)
from repro.core.counting import CountingEngine, brute_force_edge_induced
from repro.core.decomposition import cutting_sets
from repro.core.engine import MiningEngine
from repro.core.pattern import Pattern, chain, clique, cycle, tailed_triangle
from repro.graph.generators import erdos_renyi, triangle_rich

HOUSE = Pattern(5, [(0, 1), (1, 2), (2, 3), (3, 0), (0, 4), (1, 4)])

G = erdos_renyi(24, 4.0, seed=1)


@pytest.fixture(scope="module")
def eng():
    return CountingEngine(G)


# -- golden plan IR ---------------------------------------------------------------

def test_golden_plan_triangle():
    """K3: every nontrivial quotient has a self-loop, so the plan is one
    Intersect (the clique route) combined with divisor |Aut| = 6."""
    cand = frontend.direct_candidate(clique(3))
    plan = frontend.assemble([(clique(3), cand)])
    assert plan.op_counts() == {"Intersect": 1, "MobiusCombine": 1}
    out = plan.nodes[plan.output_for(clique(3))]
    assert isinstance(out, MobiusCombine)
    assert out.divisor == 6
    assert out.terms == ((1.0, f"hom:{pattern_key(clique(3))}"),)
    assert isinstance(plan.nodes[out.terms[0][1]], Intersect)
    assert plan.nodes[out.terms[0][1]].k == 3


def test_golden_plan_4clique():
    cand = frontend.direct_candidate(clique(4))
    plan = frontend.assemble([(clique(4), cand)])
    assert plan.op_counts() == {"Intersect": 1, "MobiusCombine": 1}
    out = plan.nodes[plan.output_for(clique(4))]
    assert out.divisor == 24                       # |Aut(K4)|


def test_golden_plan_house():
    """House pattern: one Contract per canonical quotient of the Möbius
    expansion, triangle quotients routed to Intersect."""
    from repro.core.quotient import quotient_terms
    cand = frontend.direct_candidate(HOUSE)
    plan = frontend.assemble([(HOUSE, cand)])
    terms = quotient_terms(HOUSE)
    homs = [k for k in plan.nodes if k.startswith("hom:")]
    assert len(homs) == len(terms)
    out = plan.nodes[plan.output_for(HOUSE)]
    assert out.divisor == HOUSE.aut_order() == 2
    got = {ref: coeff for coeff, ref in out.terms}
    for coeff, q in terms:
        assert got[f"hom:{pattern_key(q)}"] == coeff


def test_golden_decomposed_tailed_triangle():
    """Tailed triangle with cut {2}: two subpatterns (triangle + edge),
    one shrinkage quotient, CutJoin over a size-1 cut."""
    p = tailed_triangle()
    cand = frontend.decomposed_candidate(p, frozenset({2}), graph_n=G.n)
    assert cand is not None and cand.style == "decomposed"
    plan = frontend.assemble([(p, cand)])
    ops = plan.op_counts()
    assert ops["CutJoin"] == 1 and ops["ShrinkageCorrect"] == 1
    join = next(n for n in plan.nodes.values() if isinstance(n, CutJoin))
    assert join.cut_size == 1
    assert len(join.factors) == 2                  # one M_i per subpattern
    out = plan.nodes[plan.output_for(p)]
    assert isinstance(out, ShrinkageCorrect)
    assert out.divisor == p.aut_order()
    assert len(out.corrections) >= 1               # triangle shrinkage


def test_plan_serialization_roundtrip():
    pats = [clique(3), clique(4), HOUSE, tailed_triangle(), chain(4)]
    cp = compiler.compile(pats, G, cache=False)
    rt = Plan.from_json(cp.plan.to_json())
    assert rt == cp.plan
    # the deserialised plan lowers and executes identically
    cp2 = lowering.lower(rt, G)
    for p in pats:
        assert cp2.count(p) == cp.count(p)


# -- cross-pattern CSE ------------------------------------------------------------

@pytest.mark.slow
def test_cross_pattern_cse_shares_quotients():
    """Joint plan of several patterns is strictly smaller than the sum of
    their individual plans (shared quotient contractions appear once)."""
    pats = [chain(4), chain(5), cycle(4), tailed_triangle(), HOUSE]
    joint = compiler.compile(pats, G, cache=False).plan
    separate = sum(
        len(compiler.compile((p,), G, cache=False).plan.nodes)
        for p in pats)
    assert len(joint.nodes) < separate
    # chain(3) is a quotient of several of these patterns: exactly one node
    key = f"hom:{pattern_key(chain(3))}"
    assert sum(1 for k in joint.nodes if k == key) == 1


# -- plan cache -------------------------------------------------------------------

def test_plan_cache_hit_miss():
    cache = PlanCache()
    pats = (chain(4), cycle(4))
    cp1 = compiler.compile(pats, G, cache=cache)
    assert not cp1.from_cache
    assert (cache.hits, cache.misses) == (0, 1)
    cp2 = compiler.compile(pats, G, cache=cache)
    assert cp2.from_cache
    assert (cache.hits, cache.misses) == (1, 1)
    assert cp2.plan == cp1.plan
    # different pattern set or different graph: miss
    assert plan_key(pats, G) != plan_key((chain(4),), G)
    g2 = erdos_renyi(24, 4.0, seed=2)
    assert graph_signature(G) != graph_signature(g2)
    cp3 = compiler.compile(pats, g2, cache=cache)
    assert not cp3.from_cache


def test_plan_cache_on_disk(tmp_path):
    cache = PlanCache(str(tmp_path))
    pats = (tailed_triangle(),)
    compiler.compile(pats, G, cache=cache)
    # a fresh cache instance over the same directory hits via disk
    cache2 = PlanCache(str(tmp_path))
    assert plan_key(pats, G) in cache2
    cp = compiler.compile(pats, G, cache=cache2)
    assert cp.from_cache
    assert cp.count(tailed_triangle()) == \
        brute_force_edge_induced(G, tailed_triangle())


def test_plan_cache_put_is_atomic(tmp_path):
    """put writes via temp + os.replace: no temp debris, and a reader
    that races a writer only ever sees a complete file."""
    import os
    cache = PlanCache(str(tmp_path))
    pats = (chain(4),)
    compiler.compile(pats, G, cache=cache)
    files = os.listdir(tmp_path)
    assert files and all(f.endswith(".json") for f in files)


def test_plan_cache_truncated_entry_misses_then_heals(tmp_path):
    """A truncated on-disk entry (writer killed mid-write, pre-fix
    behaviour) is a clean miss; the next put replaces it with a valid
    file that subsequent readers hit."""
    cache = PlanCache(str(tmp_path))
    pats = (chain(4),)
    key = plan_key(pats, G)
    cp = compiler.compile(pats, G, cache=cache)
    full = open(cache._file(key)).read()
    with open(cache._file(key), "w") as fh:
        fh.write(full[: len(full) // 2])       # simulate a torn write
    fresh = PlanCache(str(tmp_path))
    assert fresh.get(key) is None
    assert fresh.misses == 1
    fresh.put(key, cp.plan)
    again = PlanCache(str(tmp_path))
    assert again.get(key) == cp.plan


def test_plan_cache_stale_version_misses(tmp_path):
    """Serialized plans carry PLAN_FORMAT_VERSION; an entry written by an
    older format (or missing the field entirely) misses cleanly instead
    of half-loading."""
    import json
    from repro.compiler.ir import PLAN_FORMAT_VERSION
    cache = PlanCache(str(tmp_path))
    pats = (chain(4),)
    key = plan_key(pats, G)
    cp = compiler.compile(pats, G, cache=cache)
    d = json.loads(open(cache._file(key)).read())
    assert d["version"] == PLAN_FORMAT_VERSION
    for stale in (1, PLAN_FORMAT_VERSION + 1, None):
        if stale is None:
            d.pop("version", None)
        else:
            d["version"] = stale
        with open(cache._file(key), "w") as fh:
            fh.write(json.dumps(d))
        fresh = PlanCache(str(tmp_path))
        assert fresh.get(key) is None, stale
    with pytest.raises(ValueError):
        Plan.from_dict({"version": 1, "nodes": [], "outputs": {}})


def test_plan_cache_config_mismatch_recompiles():
    """A stored plan is only valid under the (budget, max_cutjoin_cut)
    that selected it: candidate eligibility depends on both, so a
    cross-config lookup recompiles instead of returning a plan the
    executor might refuse."""
    cache = PlanCache()
    pats = (chain(4), tailed_triangle())
    cp1 = compiler.compile(pats, G, cache=cache)
    assert cp1.plan.meta["budget"] == 1 << 27
    cp2 = compiler.compile(pats, G, cache=cache)
    assert cp2.from_cache
    small = CountingEngine(G, budget=1 << 12)
    cp3 = compiler.compile(pats, G, cache=cache, counter=small)
    assert not cp3.from_cache                  # budget differs: recompile
    assert cp3.plan.meta["budget"] == 1 << 12
    cp4 = compiler.compile(pats, G, cache=cache, max_cutjoin_cut=1)
    assert not cp4.from_cache                  # cut cap differs: recompile
    for p in pats:
        assert cp3.count(p) == cp1.count(p) == cp4.count(p)


def test_engine_does_not_cache_failing_plan(monkeypatch):
    """A compiled plan whose execution raises must not be memoised: the
    query falls back to the legacy path and later queries retry a fresh
    compile rather than replaying the known-bad plan."""
    from repro import compiler as compiler_mod
    m = MiningEngine(G)
    p = chain(4)

    class _Boom:
        from_cache = False

        def count(self, _):
            raise RuntimeError("plan refused at execution")

    monkeypatch.setattr(compiler_mod, "compile",
                        lambda *a, **k: _Boom())
    want = brute_force_edge_induced(G, p)
    assert m.get_pattern_count(p) == want      # legacy fallback served it
    assert m.compiler_fallbacks == 1
    assert p.canonical() not in m._compiled    # bad plan not memoised
    monkeypatch.undo()
    assert m.get_pattern_count(p) == want      # fresh compile succeeds
    assert p.canonical() in m._compiled


# -- equivalence ------------------------------------------------------------------

EQ_PATTERNS = [chain(3), clique(3), chain(4), cycle(4), clique(4),
               tailed_triangle(), HOUSE, chain(5),
               Pattern(5, [(0, 1), (0, 2), (1, 2), (1, 3), (2, 4)])]


@pytest.mark.parametrize("gi,g", enumerate(
    [G, triangle_rich(26, 4, seed=3), erdos_renyi(30, 5.0, seed=7)]))
def test_compiled_counts_match_engine(gi, g):
    eng = CountingEngine(g)
    cp = compiler.compile(EQ_PATTERNS, g, cache=False, counter=eng)
    for p in EQ_PATTERNS:
        assert abs(cp.count(p) - eng.edge_induced(p)) < 1e-6, p


def test_compiled_counts_match_brute_force(eng):
    cp = compiler.compile(EQ_PATTERNS, G, cache=False)
    for p in EQ_PATTERNS:
        assert cp.count(p) == brute_force_edge_induced(G, p), p


@pytest.mark.parametrize("p", EQ_PATTERNS)
def test_every_decomposed_candidate_exact(eng, p):
    """CutJoin/ShrinkageCorrect plans are exact for *every* cutting set,
    not just the cost-model winner (plan invariance for the compiler)."""
    want = brute_force_edge_induced(G, p)
    for cut in cutting_sets(p):
        cand = frontend.decomposed_candidate(p, cut, graph_n=G.n)
        if cand is None:
            continue
        plan = frontend.assemble([(p, cand)])
        got = lowering.lower(plan, G, counter=eng).count(p)
        assert abs(got - want) < 1e-6, (p, sorted(cut))


def test_engine_path_through_compiler(eng):
    m = MiningEngine(G)
    for p in (chain(4), HOUSE):
        got = m.get_pattern_count(p)
        assert got == brute_force_edge_induced(G, p)
        legacy = m.get_pattern_count(p, use_compiler=False)
        assert got == legacy
    # the compiler path actually ran (no silent fallback) and repeat
    # queries reuse the lowered plan
    assert m.compiler_fallbacks == 0
    assert len(m._compiled) == 2
    m.get_pattern_count(chain(4))
    assert len(m._compiled) == 2


# -- costing ----------------------------------------------------------------------

def test_costing_never_selects_too_wide(eng):
    """Candidate selection must skip plans the executor would refuse."""
    from repro.core.apct import APCT
    apct = APCT(G, num_samples=1024)
    cands = frontend.pattern_candidates(chain(5), graph_n=G.n,
                                        budget=1 << 27)
    sel, _ = costing.select_candidates([(chain(5), cands)], apct, G.n)
    assert len(sel) == 1
    import math
    shared = {}
    assert costing.candidate_cost(sel[0][1], apct, G.n, shared) < math.inf


def test_choose_cut_matches_cost_model():
    """Engine choose_cut (now compiler-hosted) still minimises the
    cost_model over decomposition candidates."""
    import math
    from repro.core import cost_model as CM
    from repro.core.decomposition import candidates
    m = MiningEngine(G)
    for p in (chain(4), tailed_triangle(), clique(4)):
        got = m.choose_cut(p)
        best, bc = None, math.inf
        for cand in candidates(p):
            c = CM.pattern_cost(p, cand, m.apct, G.n)
            if c < bc:
                best, bc = cand, c
        assert got == best
    assert m.choose_cut(clique(4)) is None         # cliques: direct fallback


# -- serving ----------------------------------------------------------------------

def test_pattern_query_batcher(eng):
    from repro.serve.batching import PatternQueryBatcher, PatternRequest
    b = PatternQueryBatcher(G, max_batch=3)
    pats = (chain(4), clique(3))
    for i in range(5):
        b.submit(PatternRequest(uid=i, patterns=pats))
    b.run_to_completion()
    assert len(b.finished) == 5
    assert b.stats["compiles"] == 1                # compile once
    assert b.stats["cache_hits"] >= 1              # ... execute many
    assert len(b._plans) == 1                      # lowered plan reused
    ref = {p: eng.edge_induced(p) for p in pats}
    for req in b.finished:
        assert req.done and req.counts == ref


def test_pattern_query_batcher_survives_compile_failure(eng, monkeypatch):
    """A compile (or execute) failure must not drop in-flight requests:
    they finish through the legacy direct path instead."""
    from repro import compiler as compiler_mod
    from repro.serve.batching import PatternQueryBatcher, PatternRequest

    def boom(*a, **k):
        raise RuntimeError("compiler down")

    monkeypatch.setattr(compiler_mod, "compile", boom)
    b = PatternQueryBatcher(G, max_batch=2)
    pats = (chain(4), clique(3))
    for i in range(3):
        b.submit(PatternRequest(uid=i, patterns=pats))
    b.run_to_completion()
    assert len(b.finished) == 3                    # nothing dropped
    assert b.stats["fallbacks"] == 3
    assert b.stats["errors"] == 0
    ref = {p: eng.edge_induced(p) for p in pats}
    for req in b.finished:
        assert req.done and not req.error and req.counts == ref
