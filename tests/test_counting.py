"""Counting engine vs brute-force ground truth (+ property tests)."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.counting import (CountingEngine, brute_force_edge_induced,
                                 brute_force_vertex_induced, solve_overlay)
from repro.core.motifs import motif_patterns
from repro.core.pattern import (Pattern, chain, clique, cycle,
                                tailed_triangle)
from repro.graph.generators import erdos_renyi, small_world, triangle_rich
from repro.graph.storage import Graph

PATTERNS = [chain(3), clique(3), chain(4), cycle(4), clique(4),
            tailed_triangle(), chain(5), cycle(5),
            Pattern(5, [(0, 1), (0, 2), (1, 2), (1, 3), (2, 4)])]

GRAPHS = [erdos_renyi(22, 4.0, seed=1), small_world(24, 4, 0.3, seed=2),
          triangle_rich(24, 4, seed=3)]


@pytest.mark.parametrize("gi", range(len(GRAPHS)))
@pytest.mark.parametrize("pi", range(len(PATTERNS)))
def test_edge_induced_matches_brute_force(gi, pi):
    g, p = GRAPHS[gi], PATTERNS[pi]
    eng = CountingEngine(g)
    assert abs(eng.edge_induced(p) - brute_force_edge_induced(g, p)) < 1e-6


@pytest.mark.parametrize("p", [chain(3), clique(3), cycle(4), chain(4),
                               tailed_triangle()])
def test_vertex_induced_three_ways(p):
    g = GRAPHS[0]
    eng = CountingEngine(g)
    brute = brute_force_vertex_induced(g, p)
    assert abs(eng.vertex_induced(p) - brute) < 1e-6
    assert abs(eng.vind_inj_oracle(p) / p.aut_order() - brute) < 1e-6


def test_paper_running_example():
    # Figure 2 graph: vertices 0..3, edges 01,02,12,13,23
    g = Graph(4, [(0, 1), (0, 2), (1, 2), (1, 3), (2, 3)])
    eng = CountingEngine(g)
    assert eng.edge_induced(clique(3)) == 2          # two triangles
    assert eng.edge_induced(chain(3)) == 8           # paper: 8 edge-induced
    assert eng.vertex_induced(chain(3)) == 2         # paper: 8 - 3*2 = 2
    assert eng.vertex_induced(clique(3)) == 2


def test_decomposition_choice_does_not_change_counts():
    from repro.core.decomposition import cutting_sets
    g = GRAPHS[1]
    eng = CountingEngine(g)
    p = chain(5)
    base = eng.edge_induced(p, cut=None)
    for cut in cutting_sets(p)[:6]:
        assert abs(eng.edge_induced(p, cut=cut) - base) < 1e-9


def test_motif_table_sums():
    g = GRAPHS[0]
    eng = CountingEngine(g)
    table = eng.motif_table(3)
    total_subsets = 0
    import itertools
    for vs in itertools.combinations(range(g.n), 3):
        edges = sum(g.has_edge(a, b) for a, b in itertools.combinations(vs, 2))
        if edges >= 2:
            # connected 3-subgraph
            total_subsets += 1
    assert abs(sum(table.values()) - total_subsets) < 1e-6


def test_memoization_reuse_across_patterns():
    g = GRAPHS[0]
    eng = CountingEngine(g)
    for p in motif_patterns(4):
        eng.edge_induced(p)
    assert eng.stats["hom_hits"] > 0           # cross-pattern reuse


@given(st.integers(0, 10_000))
@settings(max_examples=15, deadline=None)
def test_property_random_graph_chain4(seed):
    g = erdos_renyi(16, 3.0, seed=seed)
    eng = CountingEngine(g)
    assert abs(eng.edge_induced(chain(4))
               - brute_force_edge_induced(g, chain(4))) < 1e-6


def test_counts_exact_at_large_magnitude():
    # x64 accumulation: star counts ~ sum(deg choose k) can exceed 2^24
    g = erdos_renyi(600, 40.0, seed=7)
    eng = CountingEngine(g)
    from repro.core.pattern import star
    deg = g.degrees.astype(object)
    want = sum(int(d) * int(d - 1) * int(d - 2) // 6 for d in deg)
    got = eng.edge_induced(star(4))
    assert got == want
