"""CutJoin Pallas kernel tier: primitive oracle tests plus golden-value
equivalence of the kernel path vs the XLA ``_join_reduce`` oracle vs
brute force — across cut sizes 1-2, graphs whose ``n`` is not a tile
multiple, and labelled graphs.  Everything runs in interpret mode (CPU
CI)."""
import numpy as np
import pytest

from repro.compiler import frontend, lowering
from repro.core.counting import CountingEngine, brute_force_edge_induced
from repro.core.decomposition import cutting_sets
from repro.core.pattern import Pattern, chain, clique, cycle, tailed_triangle
from repro.graph.generators import erdos_renyi, triangle_rich
from repro.kernels import ops

HOUSE = Pattern(5, [(0, 1), (1, 2), (2, 3), (3, 0), (0, 4), (1, 4)])
RNG = np.random.default_rng(7)


# -- primitive: prod_reduce vs numpy ----------------------------------------------

@pytest.mark.parametrize("n", [24, 128, 130, 200])
@pytest.mark.parametrize("k", [1, 2, 3])
def test_pair_join_matches_numpy(n, k):
    """Σ [x≠y]·Π F_i[x,y] — in-kernel mask, any n, k factors."""
    Fs = [RNG.integers(0, 9, size=(n, n)).astype(np.float64)
          for _ in range(k)]
    prod = np.prod(np.stack(Fs), axis=0)
    got = ops.cutjoin_reduce(Fs, distinct=True, interpret=True)
    assert got == (prod * (1.0 - np.eye(n))).sum()
    got = ops.cutjoin_reduce(Fs, distinct=False, interpret=True)
    assert got == prod.sum()


@pytest.mark.parametrize("n", [24, 130, 513])
@pytest.mark.parametrize("k", [1, 2, 3])
def test_vector_join_matches_numpy(n, k):
    """|cut| = 1 fast path: Σ_x Π F_i[x]."""
    vs = [RNG.integers(0, 9, size=(n,)).astype(np.float64)
          for _ in range(k)]
    got = ops.cutjoin_reduce(vs, interpret=True)
    assert got == np.prod(np.stack(vs), axis=0).sum()


def test_pair_join_never_needs_tile_multiple():
    """Regression: arbitrary n works via zero-padding (count-preserving:
    padded factor entries are zero)."""
    for n in (127, 129, 250):
        F = RNG.integers(0, 9, size=(n, n)).astype(np.float64)
        got = ops.cutjoin_reduce([F, F], distinct=True, interpret=True)
        assert got == ((F * F) * (1.0 - np.eye(n))).sum()


# -- golden-value equivalence through the compiler --------------------------------

CUT_PATTERNS = [chain(4), cycle(4), tailed_triangle(), HOUSE, chain(5)]


def _decomposed_counts(p, cut, g, eng):
    """(kernel count, XLA-oracle count) for one decomposed candidate, or
    None when the cut is ineligible."""
    cand = frontend.decomposed_candidate(p, cut, graph_n=g.n)
    if cand is None:
        return None
    plan = frontend.assemble([(p, cand)])
    kern = lowering.lower(plan, g, counter=eng, cutjoin_kernel=True)
    xla = lowering.lower(plan, g, counter=eng, cutjoin_kernel=False)
    return kern.count(p), xla.count(p)


@pytest.mark.parametrize("p", CUT_PATTERNS)
def test_kernel_matches_xla_and_brute_force(p):
    """Every decomposed candidate: kernel == _join_reduce bit-for-bit,
    both == brute force, across cut sizes 1-2."""
    g = erdos_renyi(24, 4.0, seed=1)
    eng = CountingEngine(g)
    want = brute_force_edge_induced(g, p)
    sizes = set()
    for cut in cutting_sets(p):
        got = _decomposed_counts(p, cut, g, eng)
        if got is None:
            continue
        kern, xla = got
        sizes.add(len(cut))
        assert kern == xla, (p, sorted(cut))          # bit-for-bit
        assert kern == want, (p, sorted(cut))
    assert sizes                                      # at least one cut ran


def test_kernel_covers_both_cut_sizes():
    """The sweep above must exercise |cut| = 1 and |cut| = 2 joins."""
    sizes = set()
    for p in CUT_PATTERNS:
        for cut in cutting_sets(p):
            if frontend.decomposed_candidate(p, cut, graph_n=24) is not None:
                sizes.add(len(cut))
    assert {1, 2} <= sizes


@pytest.mark.parametrize("g", [erdos_renyi(130, 4.0, seed=9),
                               triangle_rich(135, 5, seed=3)])
def test_kernel_non_tile_multiple_graph(g):
    """n deliberately not a multiple of the 128 tile: zero-padding keeps
    counts exact and the kernel still matches the XLA oracle."""
    eng = CountingEngine(g)
    for p in (cycle(4), tailed_triangle()):
        for cut in cutting_sets(p):
            got = _decomposed_counts(p, cut, g, eng)
            if got is None:
                continue
            kern, xla = got
            assert kern == xla, (g.n, p, sorted(cut))
            assert abs(kern - eng.edge_induced(p)) < 1e-6


def test_kernel_labelled_graph():
    """Vertex labels on the *graph* don't disturb the (unlabelled-
    pattern) decomposed path: cut tensors are label-free."""
    g = erdos_renyi(40, 4.0, seed=5, num_labels=3)
    assert g.labels is not None
    eng = CountingEngine(g)
    for p in (cycle(4), tailed_triangle()):
        want = brute_force_edge_induced(g, p)
        for cut in cutting_sets(p):
            got = _decomposed_counts(p, cut, g, eng)
            if got is None:
                continue
            kern, xla = got
            assert kern == xla == want, (p, sorted(cut))


# -- costing: materialised free-hom tensors are free ------------------------------

def test_costing_zero_costs_materialised_free_homs():
    from repro.compiler import costing
    from repro.compiler.ir import Contract
    from repro.core.apct import APCT
    g = erdos_renyi(24, 4.0, seed=1)
    eng = CountingEngine(g)
    apct = APCT(g, num_samples=512)
    cand = frontend.decomposed_candidate(cycle(4), frozenset({0, 2}),
                                         graph_n=g.n)
    node = next(n for n in cand.nodes
                if isinstance(n, Contract) and n.free)
    cold = costing.node_cost(node, apct, g.n, counter=eng)
    assert cold > 0.0
    skel = Pattern(node.pattern.n, node.pattern.edges)
    eng.hom_free_tensor(skel, node.free, order=node.order)
    assert costing.node_cost(node, apct, g.n, counter=eng) == 0.0
    # without the engine threaded in, the memo is invisible
    assert costing.node_cost(node, apct, g.n) == cold


# -- use_pallas triangle tier: non-multiple n regression --------------------------

@pytest.mark.parametrize("n", [150, 200])
def test_use_pallas_triangle_non_multiple_n(n):
    """Regression: the Pallas Intersect tier zero-pads to the tile
    multiple, so any n works and padding is count-preserving."""
    from repro import compiler
    g = erdos_renyi(n, 6.0, seed=4)
    assert g.n % 128 != 0
    cp = compiler.compile((clique(3),), g, cache=False, use_pallas=True)
    assert cp.count(clique(3)) == CountingEngine(g).edge_induced(clique(3))


def test_matreduce_direct_call_pads():
    """The raw kernel wrapper itself pads (it used to assert on shape)."""
    from repro.kernels.matreduce import matreduce
    from repro.kernels import ref
    rng = np.random.default_rng(3)
    lhs = rng.normal(size=(200, 70)).astype(np.float32)
    rhs = rng.normal(size=(130, 70)).astype(np.float32)
    mask = (rng.random((200, 130)) < 0.4).astype(np.float32)
    got = float(matreduce(lhs, rhs, mask, interpret=True))
    want = float(ref.matreduce_ref(lhs, rhs, mask))
    assert abs(got - want) < abs(want) * 3e-2 + 1.0
