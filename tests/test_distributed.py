"""Distributed counting + dry-run smoke on forced host devices.

These tests spawn subprocesses with XLA_FLAGS so the main pytest process
keeps its single CPU device (per the task sheet).
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, devices: int = 8):
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=SRC)
    return subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, env=env,
                          timeout=560)


def test_sharded_counting_matches_local():
    r = _run("""
        import jax, numpy as np
        from repro.graph.generators import erdos_renyi
        from repro.core.pattern import chain, clique
        from repro.core.counting import CountingEngine
        from repro.core.distributed import shard_adjacency, sharded_inj
        from repro.launch.mesh import make_host_mesh
        g = erdos_renyi(64, 6.0, seed=1)
        mesh = make_host_mesh((2, 4), ("data", "model"))
        A = shard_adjacency(g.dense_adjacency(np.float64, pad=False), mesh)
        eng = CountingEngine(g)
        for p in (chain(4), clique(3)):
            d = sharded_inj(p, A, mesh)
            l = eng.inj(p)
            assert abs(d - l) < 1e-6, (p, d, l)
        print("OK")
    """)
    assert "OK" in r.stdout, r.stdout + r.stderr


def test_blockwise_resume_after_failure(tmp_path):
    ck = tmp_path / "count.json"
    code = f"""
        import jax, numpy as np
        from repro.graph.generators import erdos_renyi
        from repro.core.pattern import chain
        from repro.core.counting import CountingEngine
        from repro.core.distributed import blockwise_hom_count
        g = erdos_renyi(48, 5.0, seed=3)
        A = __import__("jax.numpy", fromlist=["x"]).asarray(
            g.dense_adjacency(np.float64, pad=False))
        try:
            blockwise_hom_count(chain(4), A, None, num_blocks=4,
                                checkpoint=r"{ck}", fail_at_block=2)
            raise SystemExit("expected failure")
        except RuntimeError:
            pass
        # restart: resumes from checkpoint, finishes remaining blocks
        total = blockwise_hom_count(chain(4), A, None, num_blocks=4,
                                    checkpoint=r"{ck}")
        eng = CountingEngine(g)
        want = eng.hom(chain(4))
        assert abs(total - want) < 1e-6, (total, want)
        print("OK")
    """
    r = _run(code, devices=1)
    assert "OK" in r.stdout, r.stdout + r.stderr
    data = json.loads(ck.read_text())
    assert len(data) == 4


def test_dryrun_driver_small_mesh():
    """The dry-run driver itself works end-to-end on a small forced mesh."""
    r = _run("""
        import sys
        sys.argv = ["dryrun"]
        from repro.launch.dryrun import build_cell, rules_for
        from repro.configs.registry import get_config
        from repro.configs.base import SHAPES
        from repro.distributed.meshes import sharding_ctx
        import jax
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh((2, 4), ("data", "model"))
        import dataclasses
        from repro.configs.base import reduced_config
        cfg = dataclasses.replace(reduced_config(get_config("qwen3-4b")),
                                  num_layers=4)
        shape = dataclasses.replace(SHAPES["train_4k"], seq=128, batch=8)
        rules = rules_for(cfg, shape)
        with sharding_ctx(mesh, rules):
            fn, args, in_sh, out_sh, donate = build_cell(
                cfg, shape, mesh, rules, microbatches=2)
            c = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                        donate_argnums=donate).lower(*args).compile()
        assert c.memory_analysis() is not None
        print("OK")
    """)
    assert "OK" in r.stdout, r.stdout + r.stderr


def test_production_mesh_shapes():
    r = _run("""
        from repro.launch.mesh import make_production_mesh
        m1 = make_production_mesh()
        m2 = make_production_mesh(multi_pod=True)
        assert dict(m1.shape) == {"data": 16, "model": 16}
        assert dict(m2.shape) == {"pod": 2, "data": 16, "model": 16}
        print("OK")
    """, devices=512)
    assert "OK" in r.stdout, r.stdout + r.stderr
