"""Partial-embedding programming model: Algorithm 1, guarantees, FSM."""
import numpy as np
import pytest

from repro.core.counting import CountingEngine, brute_force_edge_induced
from repro.core.engine import UNDETERMINED, MiningEngine, PartialEmbedding
from repro.core.fsm import fsm, mini_support
from repro.core.pattern import Pattern, chain, clique, cycle, tailed_triangle
from repro.graph.generators import erdos_renyi

G = erdos_renyi(20, 3.5, seed=5)
PATTERNS = [chain(4), cycle(4), tailed_triangle(),
            Pattern(5, [(0, 1), (0, 2), (1, 2), (1, 3), (2, 4)])]


@pytest.fixture(scope="module")
def eng():
    return MiningEngine(G)


@pytest.mark.parametrize("p", PATTERNS)
def test_algorithm1_totals_match_inj(eng, p):
    """Summing UDF counts per subpattern recovers the inj tuple count."""
    totals = {}
    eng.run_partial_embeddings(
        p, lambda pe, c: totals.__setitem__(
            pe.subpattern_id, totals.get(pe.subpattern_id, 0) + c))
    want = brute_force_edge_induced(G, p) * p.aut_order()
    assert totals, "no partial embeddings processed"
    for sid, tot in totals.items():
        assert tot == want


@pytest.mark.parametrize("p", PATTERNS)
def test_coverage_guarantee(eng, p):
    """Subpatterns of processed partial embeddings cover all vertices."""
    covered = set()
    eng.run_partial_embeddings(
        p, lambda pe, c: covered.update(i for i, v in pe.determined))
    assert covered == set(range(p.n))


def test_completeness_guarantee(eng):
    """Every embedding of the processed subpattern appears as some pe."""
    p = chain(4)
    seen = set()
    eng.run_partial_embeddings(
        p, lambda pe, c: seen.add(pe.vertices) if pe.subpattern_id == 0
        else None)
    # reconstruct subpattern-0 embeddings independently via counting:
    # every pe seen must extend to >=1 embedding, and distinct pes cover
    # distinct prefixes whose multiplicity sums to the inj count
    assert len(seen) > 0
    for pe in list(seen)[:20]:
        det = [(i, v) for i, v in enumerate(pe) if v != UNDETERMINED]
        assert len(det) >= 2


@pytest.mark.parametrize("p", PATTERNS[:2])
def test_materialize_matches_counts(eng, p):
    pes = []
    eng.run_partial_embeddings(p, lambda pe, c: pes.append((pe, c)))
    for pe, c in pes[:25]:
        embs = eng.materialize(p, pe, num=10_000)
        assert len(embs) == c
        # each materialised embedding is a valid edge-induced embedding
        for emb in embs[:5]:
            assert len(set(emb)) == p.n
            for u, v in p.edges:
                assert G.has_edge(emb[u], emb[v])


def test_bounded_listing(eng):
    """Fig 13: list at most N embeddings while counting everything."""
    p = chain(4)
    listed, total = [], [0]

    def udf(pe, count):
        if pe.subpattern_id == 0:
            remain = 50 - len(listed)
            if remain > 0:
                listed.extend(eng.materialize(p, pe, min(remain, count)))
            total[0] += count

    eng.run_partial_embeddings(p, udf)
    assert len(listed) == 50
    assert total[0] == brute_force_edge_induced(G, p) * p.aut_order()


def test_pattern_existence(eng):
    assert eng.pattern_exists(chain(3))
    assert not eng.pattern_exists(clique(6))


def test_cost_model_falls_back_for_cliques(eng):
    assert eng.choose_cut(clique(4)) is None


# ---- FSM -----------------------------------------------------------------

GL = erdos_renyi(36, 4.0, seed=2, num_labels=3)


def _brute_domains(g, p):
    """Reference MINI support via explicit embedding enumeration."""
    from repro.core.engine import MiningEngine
    eng = MiningEngine(g)
    domains = [set() for _ in range(p.n)]
    for emb in eng._enumerate(p):
        for i, v in enumerate(emb):
            domains[i].add(v)
    return min((len(d) for d in domains), default=0)


@pytest.mark.parametrize("p", [
    Pattern(2, [(0, 1)], (0, 1)),
    Pattern(3, [(0, 1), (1, 2)], (0, 1, 0)),
    Pattern(3, [(0, 1), (1, 2), (0, 2)], (1, 1, 2)),
])
def test_mini_support_matches_bruteforce(p):
    counter = CountingEngine(GL)
    assert mini_support(counter, p) == _brute_domains(GL, p)


def test_fsm_downward_closure_and_thresholds():
    r1 = fsm(GL, min_support=2, max_vertices=3)
    r2 = fsm(GL, min_support=6, max_vertices=3)
    # higher threshold => subset of frequent patterns
    assert set(r2.frequent).issubset(set(r1.frequent))
    for p, s in r2.frequent.items():
        assert s >= 6
    # single-edge subpattern of any frequent 3-pattern is frequent
    for p in r1.frequent:
        if p.n == 3:
            for (u, v) in p.edges:
                e = Pattern(2, [(0, 1)],
                            (p.labels[u], p.labels[v])).canonical()
                assert e in r1.frequent


def test_fsm_udf_path_matches_tensor_path():
    """Fig 15 UDF-style domain maintenance == tensor inj_free domains."""
    p = Pattern(3, [(0, 1), (1, 2)], (0, 1, 0))
    eng = MiningEngine(GL)
    domains = [set() for _ in range(p.n)]

    def udf(pe, count):
        if count > 0:
            for i, v in pe.determined:
                domains[i].add(v)

    eng.run_partial_embeddings(p, udf)
    counter = CountingEngine(GL)
    for i in range(p.n):
        tensor_dom = set(np.nonzero(counter.inj_free(p, i) > 0.5)[0].tolist())
        assert domains[i] == tensor_dom
