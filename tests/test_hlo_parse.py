"""Trip-count-aware HLO analysis: validated against cost_analysis."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed import hlo_parse


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile()


def _flops(compiled) -> float:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):      # jax < 0.5: one dict per device
        ca = ca[0]
    return ca["flops"]


def test_matches_cost_analysis_scan_free():
    def f(x, w1, w2):
        return jnp.sum(jnp.tanh((x @ w1) @ w2))

    c = _compile(f, jax.ShapeDtypeStruct((32, 64), jnp.float32),
                 jax.ShapeDtypeStruct((64, 128), jnp.float32),
                 jax.ShapeDtypeStruct((128, 96), jnp.float32))
    parsed = hlo_parse.analyze_text(c.as_text(), 1)
    cost = _flops(c)
    assert abs(parsed.flops - cost) / cost < 0.05


def test_scan_body_multiplied_by_trip_count():
    L = 12

    def g(x, w):
        def body(h, _):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(body, x, None, length=L)
        return h.sum()

    c = _compile(g, jax.ShapeDtypeStruct((16, 32), jnp.float32),
                 jax.ShapeDtypeStruct((32, 32), jnp.float32))
    parsed = hlo_parse.analyze_text(c.as_text(), 1)
    one_body = 2 * 16 * 32 * 32
    assert parsed.flops > L * one_body * 0.9
    raw = _flops(c)
    assert raw < parsed.flops / 3          # cost_analysis undercounts scans


def test_nested_scan_trip_products():
    def h(x):
        def outer(c, _):
            def inner(d, _):
                return d * 1.5 + 1.0, None
            d, _ = jax.lax.scan(inner, c, None, length=5)
            return d, None
        c2, _ = jax.lax.scan(outer, x, None, length=7)
        return c2.sum()

    c = _compile(h, jax.ShapeDtypeStruct((128,), jnp.float32))
    parsed = hlo_parse.analyze_text(c.as_text(), 1)
    # 7*5 inner iterations, 2 flops each over 128 elems
    assert parsed.flops >= 7 * 5 * 128 * 2 * 0.9


def test_dtype_and_shape_parse():
    assert hlo_parse._bytes_of("bf16[128,256]{1,0}") == 128 * 256 * 2
    assert hlo_parse._bytes_of("(f32[8], s32[4])") == 32 + 16
    assert hlo_parse._bytes_of("pred[10]") == 10


def test_ring_traffic_model():
    assert hlo_parse._ring_traffic("all-reduce", 1000, 2) == 1000
    assert hlo_parse._ring_traffic("all-gather", 1600, 16) == 1500
    assert hlo_parse._ring_traffic("collective-permute", 77, 4) == 77
    assert hlo_parse._ring_traffic("reduce-scatter", 100, 4) == 300
