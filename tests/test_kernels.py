"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.bitset import pack_bitsets

RNG = np.random.default_rng(0)


def _rand(shape, dtype):
    return jnp.asarray(RNG.normal(size=shape), dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("M,N,K", [(128, 128, 128), (256, 128, 384),
                                   (64, 96, 32), (200, 130, 70)])
def test_sddmm_matches_ref(M, N, K, dtype):
    lhs, rhs = _rand((M, K), dtype), _rand((N, K), dtype)
    mask = jnp.asarray(RNG.random((M, N)) < 0.3, jnp.float32)
    got = ops.sddmm(lhs, rhs, mask, bm=64, bn=64, bk=32, interpret=True)
    want = ref.sddmm_ref(lhs, rhs, mask)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("M,N,K", [(128, 128, 128), (96, 64, 160)])
def test_matreduce_matches_ref(M, N, K, dtype):
    lhs, rhs = _rand((M, K), dtype), _rand((N, K), dtype)
    mask = jnp.asarray(RNG.random((M, N)) < 0.5, jnp.float32)
    got = float(ops.masked_matmul_reduce(lhs, rhs, mask, bm=64, bn=64,
                                         bk=32, interpret=True))
    want = float(ref.matreduce_ref(lhs, rhs, mask))
    assert abs(got - want) < (abs(want) * 3e-2 + 1.0)


def test_triangle_count_kernel_matches_engine():
    from repro.core.counting import CountingEngine
    from repro.core.pattern import clique
    from repro.graph.generators import erdos_renyi
    g = erdos_renyi(150, 10.0, seed=4)
    adj = g.dense_adjacency(np.float32, pad=False)
    got = float(ops.triangle_count(adj, interpret=True))
    want = CountingEngine(g).edge_induced(clique(3))
    assert abs(got - want) < 1e-3


@pytest.mark.parametrize("E,W", [(256, 4), (512, 16), (64, 7)])
def test_bitset_intersect_matches_ref(E, W):
    a = RNG.integers(0, 2**32, size=(E, W), dtype=np.uint32)
    b = RNG.integers(0, 2**32, size=(E, W), dtype=np.uint32)
    from repro.kernels.bitset import bitset_intersect
    blk = 64 if E % 64 == 0 else 1
    got = np.asarray(bitset_intersect(jnp.asarray(a), jnp.asarray(b),
                                      block=blk, interpret=True))
    want = ref.bitset_popcount_ref(a, b)
    np.testing.assert_array_equal(got, want)


def test_common_neighbors_counts_triangles():
    from repro.graph.generators import erdos_renyi
    g = erdos_renyi(100, 8.0, seed=6)
    adj = g.dense_adjacency(np.float32, pad=False) > 0.5
    cn = np.asarray(ops.common_neighbors(np.asarray(adj), g.edges,
                                         interpret=True))
    # sum over edges of common neighbours = 3 * #triangles
    from repro.core.counting import CountingEngine
    from repro.core.pattern import clique
    tri = CountingEngine(g).edge_induced(clique(3))
    assert cn.sum() == 3 * tri


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,D,causal", [
    (1, 128, 2, 64, True), (2, 256, 2, 64, True),
    (1, 128, 1, 128, False), (2, 64, 4, 32, True)])
def test_flash_attention_kernel_matches_ref(B, S, H, D, causal, dtype):
    q, k, v = (_rand((B, S, H, D), dtype) for _ in range(3))
    got = ops.flash_attention(q, k, v, causal=causal, bq=64, bk=64,
                              interpret=True)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    want = ref.flash_attention_ref(qf, kf, vf, causal=causal)
    want = want.reshape(B, H, S, D).transpose(0, 2, 1, 3)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_kernel_matches_model_layer():
    """Kernel result == the model's XLA flash path (layers.py)."""
    from repro.models.layers import flash_attention as xla_flash
    q, k, v = (_rand((2, 128, 2, 32), jnp.float32) for _ in range(3))
    got = ops.flash_attention(q, k, v, causal=True, bq=32, bk=32,
                              interpret=True)
    # layers.py works per-head already
    want = xla_flash(q, k, v, causal=True, block=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
