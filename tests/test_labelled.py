"""Labelled decomposition end-to-end: quotient/shrinkage exactness,
compiler decomposition-join plans for labelled patterns, level-wise FSM
equivalence, domain plans, plan-cache eviction."""
import itertools

import numpy as np
import pytest

from repro import compiler
from repro.compiler import frontend, lowering
from repro.compiler.cache import PlanCache, plan_key
from repro.compiler.ir import (CutJoin, ShrinkageCorrect, domain_keys,
                               free_skeleton, pattern_key)
from repro.core.counting import CountingEngine, brute_force_edge_induced
from repro.core.decomposition import cutting_sets
from repro.core.fsm import fsm, mini_support
from repro.core.pattern import Pattern, chain, mark_free, tailed_triangle
from repro.graph.generators import erdos_renyi, triangle_rich

GL = triangle_rich(30, 4, seed=3, num_labels=2)

LABELLED = [
    Pattern(3, [(0, 1), (1, 2)], (0, 1, 0)),
    Pattern(4, [(0, 1), (1, 2), (0, 2), (2, 3)], (0, 1, 0, 1)),
    Pattern(4, [(0, 1), (1, 2), (2, 3)], (1, 0, 0, 1)),
    Pattern(5, [(0, 1), (1, 2), (2, 3), (3, 0), (0, 4)], (0, 0, 1, 1, 0)),
]


@pytest.fixture(scope="module")
def eng():
    return CountingEngine(GL)


# -- canonical keys hash labels (golden lock) --------------------------------------

def test_pattern_key_hashes_labels():
    """Same skeleton, different labels => different CSE keys; labelled
    isomorphs => one key.  This is what keeps labelled and unlabelled
    quotients from colliding in the shared pool."""
    skel = chain(3)
    k0 = pattern_key(skel)
    k1 = pattern_key(Pattern(3, skel.edges, (0, 1, 0)))
    k2 = pattern_key(Pattern(3, skel.edges, (1, 0, 1)))
    assert len({k0, k1, k2}) == 3
    # isomorphic relabelling of vertices (labels carried): same key
    p = Pattern(3, [(0, 1), (1, 2)], (0, 1, 0))
    q = Pattern(3, [(2, 1), (1, 0)], (0, 1, 0))
    assert pattern_key(p) == pattern_key(q)


def test_mark_free_roundtrip_labels():
    """mark_free packs real labels with cut-rank markers; free_skeleton
    restores them exactly."""
    p = Pattern(4, [(0, 1), (1, 2), (2, 3)], (1, 0, 0, 1))
    marked, qc, free_c = mark_free(p, (1, 3))
    assert len(free_c) == 2
    skel = free_skeleton(qc)
    assert skel.edges == qc.edges
    assert sorted(skel.labels) == sorted(p.labels)
    # unlabelled patterns keep the pre-existing marker-only encoding
    u = chain(4)
    _, uc, ufree = mark_free(u, (0,))
    assert max(uc.labels) < 16 and free_skeleton(uc).labels is None


# -- labelled quotients / shrinkage exactness --------------------------------------

@pytest.mark.parametrize("p", LABELLED)
def test_labelled_decomposed_candidates_exact(eng, p):
    """CutJoin/ShrinkageCorrect plans are exact for every cutting set of
    every labelled pattern: labelled shrinkage multiplicities and
    label-masked factors reproduce brute force."""
    want = brute_force_edge_induced(GL, p)
    checked = 0
    for cut in cutting_sets(p):
        cand = frontend.decomposed_candidate(p, cut, graph_n=GL.n)
        if cand is None:
            continue
        plan = frontend.assemble([(p, cand)])
        got = lowering.lower(plan, GL, counter=eng).count(p)
        assert abs(got - want) < 1e-6, (p, sorted(cut))
        checked += 1
    assert checked >= 1                    # the gate is gone


def test_labelled_pattern_compiles_to_decomposition_join(eng):
    """Acceptance: a labelled >= 4-vertex pattern compiles to a
    decomposition-join plan (not the direct Möbius fallback) and its
    count matches brute force exactly."""
    p = Pattern(4, [(0, 1), (1, 2), (0, 2), (2, 3)], (0, 1, 0, 1))
    cp = compiler.compile((p,), GL, cache=False, counter=eng)
    assert cp.plan.meta["styles"][pattern_key(p)] == "decomposed"
    ops = cp.plan.op_counts()
    assert ops.get("CutJoin", 0) >= 1 and ops.get("ShrinkageCorrect", 0) >= 1
    assert cp.count(p) == brute_force_edge_induced(GL, p)


def test_labelled_shrinkage_property():
    """Property test (hypothesis): labelled shrinkage multiplicities
    reproduce brute-force injective counts on random labelled graphs,
    for every eligible cutting set."""
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    skeletons = [tailed_triangle(), chain(4),
                 Pattern(4, [(0, 1), (1, 2), (2, 3), (3, 0)])]

    @settings(max_examples=12, deadline=None)
    @given(seed=st.integers(0, 10_000),
           si=st.integers(0, len(skeletons) - 1),
           labs=st.lists(st.integers(0, 1), min_size=4, max_size=4))
    def check(seed, si, labs):
        g = erdos_renyi(14, 3.0, seed=seed, num_labels=2)
        p = Pattern(4, skeletons[si].edges, tuple(labs))
        eng = CountingEngine(g)
        want = brute_force_edge_induced(g, p)
        for cut in cutting_sets(p):
            cand = frontend.decomposed_candidate(p, cut, graph_n=g.n)
            if cand is None:
                continue
            plan = frontend.assemble([(p, cand)])
            got = lowering.lower(plan, g, counter=eng).count(p)
            assert abs(got - want) < 1e-6, (tuple(labs), sorted(cut))

    check()


def test_labelled_quotients_merge_same_label_only():
    """Quotient construction refuses label-conflicting merges and
    carries merged labels."""
    p = Pattern(3, [(0, 1), (1, 2)], (0, 1, 0))
    q, m = p.quotient_with_map([[0, 2], [1]])
    assert q is not None and sorted(q.labels) == [0, 1]
    bad, _ = p.quotient_with_map([[0, 1], [2]])
    assert bad is None                    # adjacent AND label conflict
    conflict, _ = Pattern(3, [(0, 1)], (0, 1, 1)).quotient_with_map(
        [[0, 2], [1]])
    assert conflict is None               # non-adjacent, labels differ


# -- domains / FSM -----------------------------------------------------------------

def test_domain_plan_matches_direct(eng):
    pats = tuple(LABELLED[:3])
    cp = compiler.compile(pats, GL, cache=False, counter=eng, domains=True)
    for p in pats:
        assert cp.mini_support(p) == mini_support(eng, p), p
        doms = cp.domains(p)
        c = p.canonical()
        assert set(doms) == {o[0] for o in c.vertex_orbits()}
        for rep, dom in doms.items():
            ref = eng.inj_free(c, rep)
            assert np.allclose(dom, ref), (p, rep)


@pytest.mark.slow
def test_domain_plan_cse_across_siblings():
    """Sibling patterns sharing a parent share free-hom contractions:
    the joint domain plan is smaller than the sum of individual ones."""
    sibs = [Pattern(3, [(0, 1), (1, 2)], (0, 0, l)) for l in (0, 1)] + \
           [Pattern(3, [(0, 1), (1, 2), (0, 2)], (0, 0, l)) for l in (0, 1)]
    joint = compiler.compile(tuple(sibs), GL, cache=False,
                             domains=True).plan
    separate = sum(len(compiler.compile((p,), GL, cache=False,
                                        domains=True).plan.nodes)
                   for p in sibs)
    assert len(joint.nodes) < separate


def test_fsm_compiled_matches_direct_two_labels():
    """Level-wise compiled FSM == direct fallback FSM on a 2-label
    graph (frequent sets and supports identical)."""
    g = erdos_renyi(32, 4.0, seed=9, num_labels=2)
    r_c = fsm(g, min_support=3, max_vertices=3)
    r_d = fsm(g, min_support=3, max_vertices=3, use_compiler=False)
    assert r_c.frequent == r_d.frequent
    assert r_c.compiled_levels == r_c.levels and r_c.fallbacks == 0
    assert r_d.compiled_levels == 0
    assert len(r_c.frequent) > 0


def test_inj_free_all_matches_per_vertex(eng):
    for p in LABELLED[:2]:
        dom = eng.inj_free_all(p)
        assert dom.shape == (p.n, GL.n)
        for v in range(p.n):
            # reference: independent expansion (pre-batching semantics)
            from repro.core import homomorphism as H
            from repro.core.quotient import mobius, partitions
            ref = np.zeros(GL.n)
            for sigma in partitions(tuple(range(p.n))):
                q, blk = p.quotient_with_map(sigma)
                if q is None:
                    continue
                ref += mobius(sigma) * np.asarray(
                    H.hom_count(q, eng.A, free=(blk[v],),
                                unary=eng._unary_for(q)), np.float64)
            assert np.allclose(dom[v], ref), (p, v)


def test_batcher_serves_support_requests(eng):
    from repro.serve.batching import PatternQueryBatcher, PatternRequest
    b = PatternQueryBatcher(GL, max_batch=4)
    pats = (LABELLED[0], LABELLED[1])
    for i in range(4):
        b.submit(PatternRequest(uid=i, patterns=pats, support=(i % 2 == 0)))
    b.run_to_completion()
    assert len(b.finished) == 4
    for req in b.finished:
        assert req.done and not req.error
        if req.support:
            assert req.supports == {p: mini_support(eng, p) for p in pats}
        else:
            for p in pats:
                assert abs(req.counts[p] - eng.edge_induced(p)) < 1e-6


def test_domains_cache_interplay():
    """domains=True misses a domain-less cached plan and recompiles; the
    richer plan then serves domain-less lookups from cache."""
    cache = PlanCache()
    pats = (LABELLED[0],)
    cp1 = compiler.compile(pats, GL, cache=cache)
    assert not cp1.plan.meta["domains"]
    cp2 = compiler.compile(pats, GL, cache=cache, domains=True)
    assert not cp2.from_cache                 # no domain nodes: recompile
    cp3 = compiler.compile(pats, GL, cache=cache)
    assert cp3.from_cache                     # superset plan serves counts
    cp4 = compiler.compile(pats, GL, cache=cache, domains=True)
    assert cp4.from_cache
    assert cp4.mini_support(pats[0]) == cp2.mini_support(pats[0])


# -- plan cache eviction -----------------------------------------------------------

@pytest.mark.slow
def test_plan_cache_disk_lru_eviction(tmp_path):
    """A 3-entry store overflows: stalest entries (by mtime, refreshed
    on read) are evicted, newest survive, and the evictions stat counts
    them."""
    import os
    import time
    cache = PlanCache(str(tmp_path), max_disk_entries=3)
    sets = [(chain(4),), (chain(5),), (tailed_triangle(),),
            (chain(4), chain(5))]
    keys = [plan_key(s, GL) for s in sets]
    now = time.time()
    for i, s in enumerate(sets[:3]):
        compiler.compile(s, GL, cache=cache)
        # stagger mtimes deterministically: sets[0] is stalest
        os.utime(cache._file(keys[i]), (now - 100 + i, now - 100 + i))
    assert cache.evictions == 0
    # reading entry 0 refreshes its recency: entry 1 becomes stalest
    fresh = PlanCache(str(tmp_path), max_disk_entries=3)
    assert fresh.get(keys[0]) is not None
    compiler.compile(sets[3], GL, cache=fresh)     # 4th entry: overflow
    assert fresh.evictions == 1
    on_disk = set(os.listdir(tmp_path))
    assert f"plan-{keys[1]}.json" not in on_disk   # LRU victim
    for k in (keys[0], keys[2], keys[3]):
        assert f"plan-{k}.json" in on_disk
    # victim misses on a cold instance; survivors hit
    cold = PlanCache(str(tmp_path))
    assert cold.get(keys[1]) is None
    assert cold.get(keys[0]) is not None
