"""Mesh-execution tier: block-sharded CutJoin factors and data-parallel
request fan-out (``repro.distributed.cutjoin``).

Every sharded result must be bit-for-bit equal to its single-device
oracle — the mesh tier changes where flops run, never what they
compute.  Multi-device checks spawn subprocesses with forced host
devices (the main pytest process keeps its ambient device count, so
the suite passes identically on the single-device CI leg and the
``--xla_force_host_platform_device_count=8`` leg); cost-model and
verifier checks are pure host code.
"""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, devices: int = 8):
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=SRC)
    return subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, env=env,
                          timeout=560)


def test_sharded_joins_match_single_device():
    """The kernel-level equality matrix: cut sizes 1-3, non-divisible n
    (padding path), axis-subset tri factors, keep-axis locals, and the
    sharded dense join — all bit-for-bit against the single-device
    wrappers on an 8-way forced host mesh."""
    r = _run("""
        import numpy as np
        from repro.distributed import cutjoin as dcj, meshes
        from repro.kernels import ops

        mesh = meshes.data_mesh()
        assert meshes.num_shards(mesh) == 8
        rng = np.random.default_rng(0)

        for n in (40, 65, 130):              # 65, 130: padding path
            v = [rng.integers(0, 7, size=(n,)).astype(np.float64)
                 for _ in range(2)]
            b = ops.cutjoin_exact_block(v); assert b is not None
            assert dcj.sharded_cutjoin(v, mesh=mesh, distinct=False,
                                       block=b) == \\
                ops.cutjoin_reduce(v, distinct=False, bm=b, bn=b), n

            Ms = [rng.integers(0, 6, size=(n, n)).astype(np.float64)
                  for _ in range(3)]
            b = ops.cutjoin_exact_block(Ms); assert b is not None
            assert dcj.sharded_cutjoin(Ms, mesh=mesh, block=b) == \\
                ops.cutjoin_reduce(Ms, bm=b, bn=b), n

            for keep in (0, 1):
                got = dcj.sharded_cutjoin_keep(Ms, keep=keep, mesh=mesh,
                                               block=b)
                ref = ops.cutjoin_reduce_keep(Ms, keep=keep, bm=b, bn=b)
                assert np.array_equal(got, ref), (n, keep)

        axes = [(0, 1), (1, 2), (0, 2)]      # axis-subset tri factors
        for n in (24, 33):                   # 33: padding path
            Ms = [rng.integers(0, 5, size=(n, n)).astype(np.float64)
                  for _ in axes]
            b = ops.cutjoin_exact_block(Ms); assert b is not None
            assert dcj.sharded_cutjoin3(Ms, axes, n=n, mesh=mesh,
                                        block=b) == \\
                ops.cutjoin_reduce3(Ms, axes, n=n, block=b), n
            for keep in (0, 1, 2):
                got = dcj.sharded_cutjoin3_keep(Ms, axes, keep=keep, n=n,
                                                mesh=mesh, block=b)
                ref = ops.cutjoin_reduce3_keep(Ms, axes, keep=keep, n=n,
                                               block=b)
                assert np.array_equal(got, ref), (n, keep)

        # full 3-D factor alongside a pair factor
        n = 26
        Ms = [rng.integers(0, 4, size=(n, n, n)).astype(np.float64),
              rng.integers(0, 4, size=(n, n)).astype(np.float64)]
        axes = [(0, 1, 2), (0, 2)]
        b = ops.cutjoin_exact_block(Ms); assert b is not None
        assert dcj.sharded_cutjoin3(Ms, axes, n=n, mesh=mesh, block=b) == \\
            ops.cutjoin_reduce3(Ms, axes, n=n, block=b)

        # dense fallback route: f64, no guard, big magnitudes welcome
        import jax, jax.numpy as jnp
        big = float(1 << 30)
        for n, k in ((33, 2), (17, 3)):
            Ms = [rng.integers(0, 3, size=(n,) * k).astype(np.float64)
                  * big for _ in range(2)]
            with jax.experimental.enable_x64():
                ref = float(jnp.sum(jnp.prod(jnp.stack(
                    [jnp.asarray(M) for M in Ms]), axis=0)))
            assert dcj.sharded_dense_join(Ms, k, mesh=mesh) == ref, (n, k)
        print("OK")
    """)
    assert "OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_mesh_plan_counts_bitforbit():
    """Compiled plans with a mesh bound: counts (unlabelled and
    labelled) and keep-axis local counts bit-for-bit equal to the
    meshless plan, with the sharded routes actually taken."""
    r = _run("""
        from repro import compiler, obs
        from repro.core.counting import CountingEngine
        from repro.core.pattern import Pattern, chain, cycle
        from repro.distributed import meshes
        from repro.graph import generators as gen

        mesh = meshes.data_mesh(4)
        g = gen.erdos_renyi(72, 7.0, seed=3)
        pats = (cycle(4), chain(4))
        base = compiler.compile(pats, g, counter=CountingEngine(g),
                                cache=False)
        tr = obs.Tracer()
        cp = compiler.compile(pats, g, counter=CountingEngine(g),
                              cache=False, mesh=mesh)
        cp.tracer = tr
        for p in pats:
            assert cp.count(p) == base.count(p), p

        routes = set()
        def walk(s):
            routes.add(s.attrs.get("route"))
            for c in s.children:
                walk(c)
        for root in tr.roots:
            walk(root)
        assert ("kernel-sharded" in routes or "xla-sharded" in routes), \\
            routes

        # labelled pattern through the same mesh-bound pipeline
        gl = gen.erdos_renyi(60, 6.0, seed=5, num_labels=3)
        pl = Pattern(3, [(0, 1), (1, 2)], labels=(0, 1, 0))
        bl = compiler.compile((pl,), gl, counter=CountingEngine(gl),
                              cache=False)
        cl = compiler.compile((pl,), gl, counter=CountingEngine(gl),
                              cache=False, mesh=mesh)
        assert cl.count(pl) == bl.count(pl)

        # keep-axis local counts (anchored per-vertex vectors)
        import numpy as np
        p = cycle(4)
        b2 = compiler.compile(p, g, counter=CountingEngine(g),
                              cache=False, local=True)
        c2 = compiler.compile(p, g, counter=CountingEngine(g),
                              cache=False, local=True, mesh=mesh)
        for anchor in range(p.n):
            if not b2.has_local(p, anchor):
                continue
            assert np.array_equal(c2.local_counts(p, anchor),
                                  b2.local_counts(p, anchor)), anchor
        print("OK")
    """)
    assert "OK" in r.stdout, r.stdout + r.stderr


def test_small_graph_falls_back_single_device():
    """n < shards: the executor refuses to shard wholesale, counts the
    ``cutjoin.shard_fallbacks_compile`` reason (phase-split — serving a
    cached plan counts ``..._execute`` instead), and still serves exact
    counts."""
    r = _run("""
        from repro import compiler, obs
        from repro.core.counting import CountingEngine
        from repro.core.pattern import cycle
        from repro.distributed import meshes
        from repro.graph import generators as gen

        mesh = meshes.data_mesh(8)
        g = gen.erdos_renyi(6, 2.0, seed=2)       # n=6 < 8 shards
        p = cycle(4)
        base = compiler.compile(p, g, counter=CountingEngine(g),
                                cache=False).count(p)
        got = compiler.compile(p, g, counter=CountingEngine(g),
                               cache=False, mesh=mesh).count(p)
        assert got == base, (got, base)
        snap = obs.snapshot()
        assert any("shard_fallbacks" in k for k in snap), snap
        print("OK")
    """)
    assert "OK" in r.stdout, r.stdout + r.stderr


def test_guard_refusal_under_mesh_stays_exact():
    """Factor magnitudes past ``exact_block``'s bound: the kernel route
    refuses, the mesh tier lands on the sharded (or single-device)
    dense route and the count still matches the meshless plan."""
    r = _run("""
        import numpy as np
        from repro import compiler
        from repro.core.counting import CountingEngine
        from repro.core.pattern import cycle
        from repro.distributed import meshes
        from repro.graph import generators as gen
        from repro.kernels import ops

        mesh = meshes.data_mesh(4)
        g = gen.erdos_renyi(64, 6.0, seed=7)
        p = cycle(4)
        base = compiler.compile(p, g, counter=CountingEngine(g),
                                cache=False)
        cp = compiler.compile(p, g, counter=CountingEngine(g),
                              cache=False, mesh=mesh)

        # poison the factor magnitudes the way a pathological graph
        # would: the guard must refuse, the count must not change route
        big = float(1 << 30)
        Ms = [np.full((16, 16), big), np.full((16, 16), big)]
        assert ops.cutjoin_exact_block(Ms) is None
        assert cp.count(p) == base.count(p)
        print("OK")
    """)
    assert "OK" in r.stdout, r.stdout + r.stderr


def test_batcher_mesh_fanout_matches_single():
    """PatternQueryBatcher with a mesh: grouped requests fan out over
    device slots and every count equals the meshless batcher's."""
    r = _run("""
        from repro.core.pattern import chain, cycle
        from repro.distributed import meshes
        from repro.graph import generators as gen
        from repro.serve.batching import PatternQueryBatcher, PatternRequest

        g = gen.erdos_renyi(56, 6.0, seed=9)
        pats = (cycle(4), chain(4))
        reqs = lambda: [PatternRequest(uid=i, patterns=pats)
                        for i in range(6)]

        plain = PatternQueryBatcher(g, max_batch=8)
        for q in reqs():
            plain.submit(q)
        plain.run_to_completion()

        meshed = PatternQueryBatcher(g, max_batch=8,
                                     mesh=meshes.data_mesh())
        for q in reqs():
            meshed.submit(q)
        meshed.run_to_completion()

        assert len(plain.finished) == len(meshed.finished) == 6
        for a, b in zip(plain.finished, meshed.finished):
            assert not a.error and not b.error
            assert a.counts == b.counts, (a.counts, b.counts)
        print("OK")
    """)
    assert "OK" in r.stdout, r.stdout + r.stderr


def test_join_batch_matches_serial():
    """MeshExecutor.join_batch on the ambient mesh (any device count):
    one fused dispatch, bit-for-bit with per-request kernel calls."""
    import numpy as np
    from repro.distributed import cutjoin as dcj, meshes
    from repro.kernels import ops

    rng = np.random.default_rng(11)
    stacks = rng.integers(0, 6, size=(11, 2, 48, 48)).astype(np.float64)
    block = min(b for b in (ops.cutjoin_exact_block(list(s))
                            for s in stacks) if b is not None)
    serial = np.asarray([ops.cutjoin_reduce(list(s), bm=block, bn=block)
                         for s in stacks])
    ex = dcj.MeshExecutor(meshes.data_mesh())
    assert np.array_equal(ex.join_batch(stacks), serial)


# -- cost model: tile floors and the per-device collective term ---------------------


def test_tile_floor_matches_legacy_above_tile():
    from repro.compiler.costing import DENSE_TILE, tile_floor
    for n in (128, 200, 512, 1024):
        for w in (1, 2, 3):
            legacy = (max(n, DENSE_TILE) / DENSE_TILE) ** w
            assert tile_floor(n, w) == pytest.approx(legacy), (n, w)


def test_tile_floor_differentiates_small_n():
    """The ROADMAP sharp edge: below the tile size the old floor pinned
    every candidate to 1.0 — the new floor scales with n so selection
    tests at n <= 130 exercise real cost differences."""
    from repro.compiler.costing import tile_floor
    assert tile_floor(64, 2) < tile_floor(128, 2) < tile_floor(130, 2)
    assert tile_floor(64, 1) == pytest.approx(0.5)
    assert tile_floor(64, 3) == pytest.approx(0.5)   # width>1 capped by tile
    assert tile_floor(0, 2) == tile_floor(1, 2)      # degenerate graphs
    assert tile_floor(64, 0) == 1.0


def test_kernel_join_cost_devices_term():
    """More devices: per-device work shrinks, a log2(d) collective term
    appears — never free, monotone in d for fixed work."""
    from repro.compiler.costing import _kernel_join_cost
    axes = ((0, 1), (0, 1))
    c1 = _kernel_join_cost(2, axes, 1024, 1 << 27, devices=1)
    c8 = _kernel_join_cost(2, axes, 1024, 1 << 27, devices=8)
    assert c8 < c1                       # sharding pays off at n=1024
    import math
    tiny = _kernel_join_cost(2, axes, 16, 1 << 27, devices=8)
    assert tiny > math.log2(8)           # collective term never waived


# -- static shard-legality diagnostics ----------------------------------------------


def _plan_and_info(n=24, deg=4.0, seed=13):
    from repro import compiler
    from repro.analysis import GraphInfo
    from repro.core.counting import CountingEngine
    from repro.core.pattern import cycle
    from repro.graph import generators as gen
    g = gen.erdos_renyi(n, deg, seed=seed)
    cp = compiler.compile(cycle(4), g, counter=CountingEngine(g),
                          cache=False)
    return cp.plan, GraphInfo.from_graph(g)


def test_shard_check_diagnostics():
    from repro import analysis
    plan, info = _plan_and_info(n=24)

    assert analysis.shard_check(plan, info, 1).diagnostics == []

    res = analysis.shard_check(plan, info, 48)      # n < shards
    assert any(d.code == "shard-small-graph" for d in res.warnings)

    res = analysis.shard_check(plan, info, 5)       # 24 % 5 != 0
    assert any(d.code == "shard-indivisible" for d in res.warnings)
    assert res.ok                                   # advisory only

    res = analysis.shard_check(plan, info, 4, budget=1)
    assert any(d.code == "shard-budget-overflow" for d in res.warnings)


def test_precertify_num_shards_is_noop():
    """Per-shard blocks are certified by the global certificate (a
    slice max never exceeds the global max), so num_shards must not
    change precertification output."""
    from repro import analysis
    plan, info = _plan_and_info(n=40, deg=5.0)
    assert analysis.precertify(plan, info) == \
        analysis.precertify(plan, info, num_shards=8)
