"""Per-architecture smoke + decode-path consistency tests (reduced configs)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import reduced_config
from repro.configs.registry import ARCH_IDS, get_config
from repro.models.transformer import Model, init_cache, param_specs
from repro.models.params import count_params
from repro.serve.engine import make_decode_step, make_prefill_step

KEY = jax.random.PRNGKey(0)
B, S = 2, 32


def _inputs(cfg, key, shape):
    if cfg.input_mode == "embeddings":
        return jax.random.normal(key, shape + (cfg.d_model,), jnp.float32)
    return jax.random.randint(key, shape, 0, cfg.vocab_size)


def _kw(cfg, key):
    if cfg.family == "vlm":
        return {"image_embeds": jax.random.normal(
            key, (B, cfg.num_image_tokens, cfg.d_model))}
    return {}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = reduced_config(get_config(arch))
    model = Model(cfg)
    params = model.init(KEY)
    logits, _, aux = model(params, _inputs(cfg, KEY, (B, S)),
                           mode="train", **_kw(cfg, KEY))
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ["qwen3-4b", "mamba2-1.3b",
                                  "jamba-1.5-large-398b",
                                  "deepseek-v3-671b",
                                  "llama-3.2-vision-11b"])
def test_decode_matches_full_forward(arch):
    """prefill(x[:T]) + decode(x[T]) logits == forward(x[:T+1])[:, T]."""
    cfg = reduced_config(get_config(arch), remat=False)
    model = Model(cfg)
    params = model.init(KEY)
    T = 23          # unique dim size so the KV seq axis is unambiguous
    x = _inputs(cfg, KEY, (B, T + 1))
    kw = _kw(cfg, KEY)
    full_logits, _, _ = model(params, x, mode="train", **kw)

    decode = make_decode_step(cfg)
    # prefill over T tokens, then grow every seq-capacity axis by one slot
    _, caches, _ = model(params, x[:, :T], mode="prefill", **kw)

    def grow(c):
        pads = [(0, 1) if d == T else (0, 0) for d in c.shape]
        return jnp.pad(c, pads)

    caches = jax.tree.map(grow, caches)
    pos = jnp.full((B,), T, jnp.int32)
    tok = x[:, T:T + 1]
    logits_dec, _ = decode(params, caches, tok, pos, **kw)
    want = np.asarray(full_logits[:, T, :], np.float32)
    got = np.asarray(logits_dec, np.float32)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_prefill_logits_match_forward():
    cfg = reduced_config(get_config("qwen3-4b"), remat=False)
    model = Model(cfg)
    params = model.init(KEY)
    x = _inputs(cfg, KEY, (B, S))
    full_logits, _, _ = model(params, x, mode="train")
    prefill = make_prefill_step(cfg)
    last, caches = prefill(params, x)
    np.testing.assert_allclose(np.asarray(last, np.float32),
                               np.asarray(full_logits[:, -1], np.float32),
                               rtol=2e-3, atol=2e-3)


def test_flash_attention_matches_dense():
    from repro.models.layers import causal_attention, flash_attention
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = jax.random.normal(k1, (2, 64, 4, 16))
    k = jax.random.normal(k2, (2, 64, 4, 16))
    v = jax.random.normal(k3, (2, 64, 4, 16))
    dense = causal_attention(q, k, v, flash_block=64)
    flash = flash_attention(q, k, v, causal=True, block=16)
    np.testing.assert_allclose(np.asarray(flash), np.asarray(dense),
                               rtol=1e-5, atol=1e-5)


def test_ssd_chunked_matches_recurrent():
    """Chunked SSD == step-by-step recurrence."""
    from repro.models.ssm import ssd_chunked
    rng = np.random.default_rng(0)
    Bb, L, H, P, N = 2, 32, 3, 4, 8
    xs = jnp.asarray(rng.normal(size=(Bb, L, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, size=(Bb, L, H)), jnp.float32)
    A = -jnp.asarray(rng.uniform(0.5, 2.0, size=(H,)), jnp.float32)
    B_ = jnp.asarray(rng.normal(size=(Bb, L, H, N)), jnp.float32)
    C_ = jnp.asarray(rng.normal(size=(Bb, L, H, N)), jnp.float32)
    y, final = ssd_chunked(xs, dt, A, B_, C_, chunk=8)
    # reference recurrence
    state = np.zeros((Bb, H, P, N))
    ys = []
    xs_n, dt_n, B_n, C_n = map(np.asarray, (xs, dt, B_, C_))
    A_n = np.asarray(A)
    for t in range(L):
        dA = np.exp(dt_n[:, t] * A_n[None, :])            # (B,H)
        state = state * dA[..., None, None] + np.einsum(
            "bh,bhp,bhn->bhpn", dt_n[:, t], xs_n[:, t], B_n[:, t])
        ys.append(np.einsum("bhpn,bhn->bhp", state, C_n[:, t]))
    ref = np.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y), ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(final), state, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("arch,target_b", [
    ("jamba-1.5-large-398b", 398.6), ("deepseek-7b", 6.9),
    ("deepseek-v3-671b", 671.0), ("dbrx-132b", 131.6),
    ("granite-20b", 20.0), ("qwen3-4b", 4.0), ("mamba2-1.3b", 1.3),
])
def test_full_config_param_counts(arch, target_b):
    cfg = get_config(arch)
    n = count_params(param_specs(cfg)) / 1e9
    assert abs(n - target_b) / target_b < 0.06, (arch, n)
    assert cfg.param_count() == count_params(param_specs(cfg))


def test_moe_capacity_drops_overflow():
    from repro.configs.base import MoEConfig
    from repro.models.moe import capacity
    assert capacity(1, 8, 256, 1.25) == 1
    assert capacity(4096, 2, 16, 1.25) == 640


def test_segments_cover_all_layers():
    from repro.models.transformer import build_segments
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        segs = build_segments(cfg)
        assert sum(len(s.slots) * s.n for s in segs) == cfg.num_layers
