"""Expert-parallel MoE (shard_map + all_to_all) vs the einsum path."""
import os
import subprocess
import sys
import textwrap

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, devices: int = 8):
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=SRC)
    return subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, env=env,
                          timeout=560)


def test_ep_matches_einsum_and_grads():
    r = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import reduced_config
        from repro.configs.registry import get_config
        from repro.models.transformer import Model
        from repro.distributed.meshes import sharding_ctx
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh((2, 4), ("data", "model"))
        cfg = reduced_config(get_config("dbrx-132b"))
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        x = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                               cfg.vocab_size)
        ref, _, _ = model(params, x, mode="train")     # einsum path
        with sharding_ctx(mesh, None):                 # EP path
            got, _, _ = jax.jit(lambda p, t: model(p, t, mode="train"))(
                params, x)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(ref, np.float32),
                                   rtol=2e-3, atol=2e-3)
        def loss(p):
            with sharding_ctx(mesh, None):
                l, _, _ = model(p, x, mode="train")
            return jnp.mean(l.astype(jnp.float32) ** 2)
        g = jax.jit(jax.grad(loss))(params)
        gn = sum(float(jnp.sum(jnp.abs(v))) for v in jax.tree.leaves(g))
        assert np.isfinite(gn) and gn > 0
        print("OK")
    """)
    assert "OK" in r.stdout, r.stdout + r.stderr


def test_full_mesh_ep_when_experts_divide_mesh():
    """E == data*model => full-mesh EP (whole experts per device)."""
    r = _run("""
        import dataclasses, jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import reduced_config
        from repro.configs.registry import get_config
        from repro.models.transformer import Model
        from repro.distributed.meshes import sharding_ctx
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh((2, 4), ("data", "model"))
        cfg = reduced_config(get_config("dbrx-132b"))
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, num_experts=8, top_k=2, capacity_factor=8.0))
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        x = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                               cfg.vocab_size)
        ref, _, _ = model(params, x, mode="train")
        with sharding_ctx(mesh, {"experts": ("data", "model")}):
            got, _, _ = jax.jit(lambda p, t: model(p, t, mode="train"))(
                params, x)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(ref, np.float32),
                                   rtol=2e-3, atol=2e-3)
        print("OK")
    """)
    assert "OK" in r.stdout, r.stdout + r.stderr
