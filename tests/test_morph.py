"""Pattern-morphing count algebra (``compiler.morph``): identity
correctness against brute force, store persistence/versioning, the
compile fast path and held-hom costing, end-to-end consumers, and the
morph-off bit-for-bit guarantee."""
import itertools
import json
import os

import numpy as np
import pytest

from repro import analysis, compiler, obs
from repro.compiler import costing, frontend
from repro.compiler import morph as morphlib
from repro.compiler.cache import PlanCache, graph_signature, plan_key
from repro.compiler.ir import pattern_key
from repro.core.pattern import Pattern, chain, clique, cycle
from repro.core.quotient import quotient_terms
from repro.graph import generators as gen
from repro.graph.storage import Graph


# -- brute-force oracles ----------------------------------------------------------
# memoised by (pattern key, graph signature): hypothesis examples reuse a
# handful of graphs and the same small quotients (K2, P3, ...) constantly

_BRUTE_MEMO: dict = {}


def _adj(g):
    adj = set()
    for u, v in map(tuple, g.edges):
        adj.add((u, v))
        adj.add((v, u))
    return adj


def _brute(kind, q, g, tuples):
    memo_key = (kind, pattern_key(q), graph_signature(g))
    if memo_key in _BRUTE_MEMO:
        return _BRUTE_MEMO[memo_key]
    adj = _adj(g)
    total = 0
    for f in tuples:
        if q.labels is not None and g.labels is not None and any(
                g.labels[f[v]] != q.labels[v] for v in range(q.n)):
            continue
        if all((f[u], f[v]) in adj for u, v in q.edges):
            total += 1
    _BRUTE_MEMO[memo_key] = total
    return total


def brute_hom(q, g):
    """hom(q, g) by enumeration (label-respecting when both carry labels)."""
    return _brute("hom", q, g,
                  itertools.product(range(g.n), repeat=q.n))


def brute_inj(p, g):
    """inj(p, g): injective homomorphisms by enumeration."""
    return _brute("inj", p, g,
                  itertools.permutations(range(g.n), p.n))


def warm_with_brute_homs(p, g, store):
    """Populate the store with brute-force homs of every quotient of p."""
    gsig = graph_signature(g)
    for _, q in quotient_terms(p.canonical()):
        store.put(gsig, "hom", q, brute_hom(q, g))
    return gsig


# -- pattern_key inversion --------------------------------------------------------

def test_pattern_key_roundtrip():
    pats = [chain(3), chain(5), cycle(4), cycle(5), clique(4),
            Pattern(4, [(0, 1), (1, 2), (2, 3), (0, 3), (0, 2)]),
            Pattern(3, [(0, 1), (1, 2)], labels=(1, 0, 1)),
            Pattern(4, [(0, 1), (1, 2), (2, 3)], labels=(0, 2, 0, 1))]
    for p in pats:
        pc = p.canonical()
        assert morphlib.pattern_from_key(pattern_key(p)) == pc


# -- golden identity locks --------------------------------------------------------

def test_golden_wedge_triangle_identity():
    """inj(wedge) = hom(wedge) - hom(K2); count(K3) = hom(K3) / 6."""
    wedge = chain(3)
    terms = quotient_terms(wedge.canonical())
    by_pattern = {q: c for c, q in terms}
    assert by_pattern == {wedge.canonical(): 1, clique(2).canonical(): -1}
    assert quotient_terms(clique(3)) == ((1, clique(3)),)

    g = gen.erdos_renyi(24, 4.0, seed=11)
    store = morphlib.CountStore()
    gsig = warm_with_brute_homs(wedge, g, store)
    cand = morphlib.derive(wedge, store, gsig)
    assert cand.complete
    assert cand.value * wedge.aut_order() == brute_inj(wedge, g)

    store2 = morphlib.CountStore()
    gsig2 = warm_with_brute_homs(clique(3), g, store2)
    tri = morphlib.derive(clique(3), store2, gsig2)
    assert tri.complete and tri.divisor == 6
    assert tri.value * 6 == brute_inj(clique(3), g)


def test_golden_4path_4cycle_identities():
    """Coefficient locks: inj(C4) = hom(C4) - 2 hom(P3) + hom(K2);
    inj(P4) = hom(P4) - 2 hom(P3) - hom(K3) + hom(K2)."""
    p3, c4, p4 = chain(3).canonical(), cycle(4).canonical(), \
        chain(4).canonical()
    c4_terms = {q: c for c, q in quotient_terms(c4)}
    assert c4_terms == {c4: 1, p3: -2, clique(2).canonical(): 1}
    p4_terms = {q: c for c, q in quotient_terms(p4)}
    assert p4_terms == {p4: 1, p3: -2, clique(3).canonical(): -1,
                        clique(2).canonical(): 1}

    g = gen.triangle_rich(20, 3, seed=5)
    for p in (c4, p4):
        store = morphlib.CountStore()
        gsig = warm_with_brute_homs(p, g, store)
        cand = morphlib.derive(p, store, gsig)
        assert cand.complete
        assert cand.value * p.aut_order() == brute_inj(p, g)
        assert analysis.morph_check(cand).ok


# -- derived identities == brute force --------------------------------------------

def _pattern_from_bits(n, bits, labels=None):
    pairs = [(i, j) for i in range(n) for j in range(i + 1, n)]
    return Pattern(n, [e for t, e in enumerate(pairs) if bits >> t & 1],
                   labels=labels)


def _graph_for(kind, seed, num_labels=0):
    return (gen.erdos_renyi(9, 3.0, seed=seed, num_labels=num_labels)
            if kind == "er"
            else gen.rmat(3, 3.0, seed=seed, num_labels=num_labels))


def _check_derived(p, g):
    """derive() over brute-warmed quotient homs reproduces the
    brute-force injective count integer-exactly, and morph_check holds."""
    store = morphlib.CountStore()
    gsig = warm_with_brute_homs(p, g, store)
    cand = morphlib.derive(p, store, gsig)
    assert cand.complete
    assert cand.value * p.aut_order() == brute_inj(p, g)
    assert analysis.morph_check(cand).ok


def test_derived_identity_matches_brute_force_hypothesis():
    """Property test: random connected <=5-vertex patterns on er/rmat
    generator graphs — the derived inclusion–exclusion coefficients
    reproduce brute-force injective counts exactly."""
    pytest.importorskip("hypothesis")
    from hypothesis import assume, given, settings
    from hypothesis import strategies as st

    @settings(max_examples=20, deadline=None)
    @given(n=st.integers(3, 5), bits=st.integers(1, (1 << 10) - 1),
           kind=st.sampled_from(["er", "rmat"]), seed=st.integers(0, 3))
    def prop(n, bits, kind, seed):
        p = _pattern_from_bits(n, bits)
        assume(p.is_connected() and p.m > 0)
        _check_derived(p, _graph_for(kind, seed))

    prop()


def test_labelled_derived_identity_matches_brute_force_hypothesis():
    pytest.importorskip("hypothesis")
    from hypothesis import assume, given, settings
    from hypothesis import strategies as st

    @settings(max_examples=15, deadline=None)
    @given(bits=st.integers(1, (1 << 6) - 1),
           labels=st.tuples(*[st.integers(0, 1)] * 4),
           seed=st.integers(0, 3))
    def prop(bits, labels, seed):
        p = _pattern_from_bits(4, bits, labels=labels)
        assume(p.is_connected())
        _check_derived(p, _graph_for("er", seed, num_labels=2))

    prop()


def test_derived_identity_matches_brute_force_seeded():
    """Deterministic sweep of the same property — runs even where
    hypothesis isn't installed (it is optional across this suite)."""
    rng = np.random.default_rng(7)
    checked = 0
    while checked < 12:
        n = int(rng.integers(3, 6))
        bits = int(rng.integers(1, 1 << (n * (n - 1) // 2)))
        labels = (tuple(int(x) for x in rng.integers(0, 2, n))
                  if rng.integers(0, 2) else None)
        p = _pattern_from_bits(n, bits, labels=labels)
        if not (p.is_connected() and p.m > 0):
            continue
        kind = "er" if rng.integers(0, 2) else "rmat"
        g = _graph_for(kind, int(rng.integers(0, 4)),
                       num_labels=2 if labels is not None else 0)
        _check_derived(p, g)
        checked += 1


# -- morph_check is a real check --------------------------------------------------

def test_morph_check_catches_corruption():
    c4 = cycle(4).canonical()
    good = morphlib.MorphCandidate(
        pattern=c4, terms=quotient_terms(c4), missing=(),
        divisor=c4.aut_order())
    assert analysis.morph_check(good).ok
    # flip one coefficient -> the complete-graph endpoints diverge
    bad_terms = tuple((c if q.m != c4.m else -c, q)
                      for c, q in quotient_terms(c4))
    bad = morphlib.MorphCandidate(pattern=c4, terms=bad_terms, missing=(),
                                  divisor=c4.aut_order())
    r = analysis.morph_check(bad)
    assert not r.ok and "morph-endpoint-complete" in r.codes()
    # wrong automorphism divisor
    off = morphlib.MorphCandidate(pattern=c4, terms=quotient_terms(c4),
                                  missing=(), divisor=3)
    assert "morph-divisor" in analysis.morph_check(off).codes()


# -- lattice explorer -------------------------------------------------------------

def test_morph_neighbours_and_family():
    tri, wedge = clique(3).canonical(), chain(3).canonical()
    assert morphlib.morph_neighbours(wedge) == (tri,)
    assert morphlib.morph_neighbours(tri) == (wedge,)
    fam4, fam5 = morphlib.motif_family(4), morphlib.motif_family(5)
    assert len(fam4) == 6 and len(fam5) == 21
    assert all(p.is_connected() and p.n == 4 for p in fam4)
    # distance-2 frontier from C4 reaches everything but the clique end
    assert len(morphlib.morph_neighbours(cycle(4), distance=3)) == 5


# -- store persistence ------------------------------------------------------------

def test_count_store_disk_roundtrip_and_version_drift(tmp_path):
    store = morphlib.CountStore(str(tmp_path))
    assert store.put("g1", "hom", chain(3), 42.0) == 1
    assert store.put("g1", "hom", chain(3), 42) == 0     # idempotent
    store.put("g1", "inj", cycle(4), 7)
    store.sync()
    fresh = morphlib.CountStore(str(tmp_path))
    assert fresh.get("g1", "hom", chain(3)) == 42
    assert fresh.get("g1", "inj", cycle(4)) == 7
    assert fresh.held_hom_keys("g1") == {f"hom:{pattern_key(chain(3))}"}
    # stamp a future format version: clean miss, counted
    f = fresh._file("g1")
    with open(f) as fh:
        doc = json.load(fh)
    doc["version"] = morphlib.MORPH_FORMAT_VERSION + 1
    with open(f, "w") as fh:
        fh.write(json.dumps(doc))
    drifted = morphlib.CountStore(str(tmp_path))
    assert drifted.get("g1", "hom", chain(3)) is None
    assert drifted.stats["format_misses"] == 1


def test_count_store_sync_failure_is_counted(tmp_path, monkeypatch):
    store = morphlib.CountStore(str(tmp_path))
    store.put("g1", "hom", chain(3), 5)

    def boom(*a, **k):
        raise OSError("read-only store dir")
    monkeypatch.setattr(os, "replace", boom)
    store.sync()                      # must not raise
    assert store.stats["sync_failures"] == 1
    assert store.get("g1", "hom", chain(3)) == 5   # memory tier intact


# -- harvest + compile fast path --------------------------------------------------

def test_compiled_count_harvests_into_store():
    g = gen.erdos_renyi(24, 4.0, seed=2)
    store = morphlib.CountStore()
    cp = compiler.compile((chain(4),), g, cache=False, morph=store)
    cp.count(chain(4))
    gsig = graph_signature(g)
    held = store._mem[gsig]
    assert f"inj:{pattern_key(chain(4))}" in held
    assert held[f"inj:{pattern_key(chain(4))}"] == brute_inj(chain(4), g)
    assert any(k.startswith("hom:") for k in held)


def test_fast_path_serves_family_member_without_search():
    g = gen.erdos_renyi(48, 5.0, seed=2)
    store = morphlib.CountStore()
    # warm: the 5-path compiles decomposed-subset, whose scalar quotient
    # homs (P3, K2 among them) close the wedge identity
    compiler.compile((chain(5),), g, cache=False, morph=store).count(chain(5))
    hits0 = obs.get("morph.hits", 0.0)
    cp = compiler.compile((chain(3),), g, cache=False, morph=store)
    assert cp.plan.meta.get("morph") is True
    assert cp.plan.meta["styles"] == {pattern_key(chain(3)): "morph"}
    assert obs.get("morph.hits", 0.0) == hits0 + 1
    direct = compiler.compile((chain(3),), g, cache=False).count(chain(3))
    assert cp.count(chain(3)) == direct


def test_missing_counts_fall_back_to_search():
    g = gen.erdos_renyi(48, 5.0, seed=2)
    store = morphlib.CountStore()           # empty: nothing closes
    misses0 = obs.get("morph.missing_compiles", 0.0)
    cp = compiler.compile((cycle(4),), g, cache=False, morph=store)
    assert cp.plan.meta.get("morph") is None       # searched normally
    assert obs.get("morph.missing_compiles", 0.0) == misses0 + 1
    direct = compiler.compile((cycle(4),), g, cache=False).count(cycle(4))
    assert cp.count(cycle(4)) == direct


def test_held_hom_prices_zero_in_costing():
    g = gen.erdos_renyi(40, 4.0, seed=1)
    from repro.core.apct import APCT
    apct = APCT(g)
    cand = frontend.direct_candidate(chain(3))
    hom_nodes = [nd for nd in cand.nodes if nd.key.startswith("hom:")
                 and not getattr(nd, "free", ())]
    assert hom_nodes
    node = hom_nodes[0]
    assert costing.node_cost(node, apct, g.n) > 0.0
    assert costing.node_cost(node, apct, g.n, held={node.key}) == 0.0
    held = {nd.key for nd in hom_nodes}
    free_cost = costing.candidate_cost(cand, apct, g.n, {}, held=held)
    assert free_cost < costing.candidate_cost(cand, apct, g.n, {})


# -- morph-off stays bit-for-bit --------------------------------------------------

def test_morph_off_unchanged_and_cache_unpolluted():
    g = gen.erdos_renyi(48, 5.0, seed=2)
    cache = PlanCache()
    p = chain(3)
    baseline = compiler.compile((p,), g, cache=False).plan.to_json()
    # a morph compile (fast path) must not write the plan cache
    store = morphlib.CountStore()
    compiler.compile((chain(5),), g, cache=False, morph=store).count(chain(5))
    cp = compiler.compile((p,), g, cache=cache, morph=store)
    assert cp.plan.meta.get("morph") is True
    assert plan_key((p,), g) not in cache
    # ...and a later morph=False compile is byte-identical to baseline
    after = compiler.compile((p,), g, cache=cache, morph=False)
    assert after.plan.meta.get("morph") is None
    assert after.plan.to_json() == baseline


# -- consumers --------------------------------------------------------------------

def test_mining_engine_threads_morph():
    from repro.core.engine import MiningEngine
    g = gen.erdos_renyi(48, 5.0, seed=4)
    store = morphlib.CountStore()
    eng = MiningEngine(g, morph=store)
    plain = MiningEngine(g)
    for p in (chain(4), chain(3), clique(3)):
        assert eng.get_pattern_count(p) == plain.get_pattern_count(p)
    assert eng.compiler_fallbacks == 0
    assert len(store) > 0


def test_batcher_threads_morph():
    from repro.serve.batching import PatternQueryBatcher, PatternRequest
    g = gen.erdos_renyi(48, 5.0, seed=4)
    store = morphlib.CountStore()
    b = PatternQueryBatcher(g, cache=PlanCache(), morph=store)
    plain = PatternQueryBatcher(g, cache=PlanCache())
    for i, p in enumerate((chain(4), chain(3))):
        b.submit(PatternRequest(uid=i, patterns=(p,)))
        plain.submit(PatternRequest(uid=i, patterns=(p,)))
    b.run_to_completion()
    plain.run_to_completion()
    got = {r.uid: dict(r.counts) for r in b.finished}
    want = {r.uid: dict(r.counts) for r in plain.finished}
    assert not any(r.error for r in b.finished)
    assert got == want
    assert len(store) > 0


def test_fsm_feeds_and_reads_count_store():
    from repro.core.fsm import fsm
    g = gen.erdos_renyi(40, 4.0, seed=6, num_labels=2)
    store = morphlib.CountStore()
    with_store = fsm(g, min_support=2, max_vertices=3, count_store=store)
    without = fsm(g, min_support=2, max_vertices=3)
    assert with_store.frequent == without.frequent
    assert with_store.fallbacks == 0
    assert len(store) > 0                 # levels harvested their counts


# -- satellites -------------------------------------------------------------------

def test_plancache_utime_failure_counted(tmp_path, monkeypatch):
    g = gen.erdos_renyi(40, 4.0, seed=0)
    cache = PlanCache(str(tmp_path), max_disk_entries=8)
    p = chain(3)
    compiler.compile((p,), g, cache=cache)
    key = plan_key((p,), g)
    before = obs.get("plancache.utime_failures", 0.0)

    def boom(*a, **k):
        raise OSError("read-only cache dir")
    monkeypatch.setattr(os, "utime", boom)
    assert cache.get(key) is not None     # memory-tier recency refresh
    cache._mem.clear()
    assert cache.get(key) is not None     # cold disk read
    assert obs.get("plancache.utime_failures", 0.0) >= before + 2


def test_graph_invalidate_signature():
    g = Graph(4, np.array([[0, 1], [1, 2]]))
    s1 = graph_signature(g)
    assert graph_signature(g) == s1       # memoised
    g.edges = np.asarray([[0, 1], [1, 2], [2, 3]], g.edges.dtype)
    g.m = 3
    assert graph_signature(g) == s1       # stale without invalidation
    g.invalidate_signature()
    assert graph_signature(g) != s1
    assert g._csr is None and g._dense is None
