"""Observability layer: metrics registry / StatsView semantics, the
plan-execution tracer (golden trace shape, span-nesting-matches-IR,
coverage, exports), cost-model drift aggregation, per-phase batcher
fallback accounting, and PlanCache eviction metrics."""
import json

import numpy as np
import pytest

from repro import compiler, obs
from repro.obs.drift import (aggregate, group_key, pairs_from_trace,
                             spearman)
from repro.obs.metrics import MetricsRegistry, StatsView
from repro.core.counting import CountingEngine
from repro.core.pattern import Pattern, chain, clique, cycle
from repro.graph.generators import erdos_renyi

K5_MINUS_EDGE = Pattern(5, [(u, v) for u in range(5)
                            for v in range(u + 1, 5) if (u, v) != (3, 4)])

G = erdos_renyi(24, 4.0, seed=1)


def _traced(p, g=G, *, cutjoin_kernel=True, local=False):
    tr = obs.Tracer()
    cp = compiler.compile(p, g, counter=CountingEngine(g), cache=False,
                          cutjoin_kernel=cutjoin_kernel, local=local)
    cp.tracer = tr
    cp.count(p)
    return tr, cp


# -- metrics registry --------------------------------------------------------------

def test_registry_counter_gauge_histogram():
    r = MetricsRegistry()
    assert r.counter("c") == 1
    assert r.counter("c", 4) == 5
    assert r.get("c") == 5
    r.gauge("g", 2.5)
    r.gauge("g", 7.0)                       # gauges overwrite
    assert r.get("g") == 7.0
    for v in (1.0, 3.0, 2.0):
        r.observe("h", v)
    h = r.get("h")
    assert h == {"count": 3, "sum": 6.0, "min": 1.0, "max": 3.0,
                 "mean": 2.0, "last": 2.0}
    assert r.get("absent", default=None) is None


def test_registry_labels_separate_series():
    r = MetricsRegistry()
    r.counter("k", cut=2)
    r.counter("k", 2, cut=3)
    assert r.get("k", cut=2) == 1
    assert r.get("k", cut=3) == 2
    assert r.get("k") == 0.0                # unlabelled series untouched
    assert r.series("k") == {(("cut", 2),): 1.0, (("cut", 3),): 2.0}
    snap = r.snapshot()
    assert snap["k"] == {"cut=2": 1.0, "cut=3": 2.0}
    json.loads(r.dump())                    # serialisable
    r.reset()
    assert r.snapshot() == {}


def test_stats_view_local_reads_registry_mirror():
    r = MetricsRegistry()
    v = StatsView("pfx", keys=("a", "b"), registry=r, tier="x")
    assert v["a"] == 0 and dict(v) == {"a": 0, "b": 0}
    v["a"] += 1
    v["a"] += 2
    assert v["a"] == 3 and isinstance(v["a"], int)
    assert r.get("pfx.a", tier="x") == 3
    # equality with plain dicts: the contract the old ad-hoc dicts gave
    assert v == {"a": 3, "b": 0}
    # a local reset never decrements the registry (monotonic counters)
    v["a"] = 0
    assert v["a"] == 0
    assert r.get("pfx.a", tier="x") == 3
    v["a"] += 1
    assert v["a"] == 1 and r.get("pfx.a", tier="x") == 4


# -- tracer ------------------------------------------------------------------------

def test_golden_trace_shape_3cut():
    """Trace-shape lock on the K5-minus-edge tri-join plan: the span
    tree mirrors the evaluation recursion — one execute root, the
    ShrinkageCorrect output under it, the CutJoin (kernel route, guard
    granted) with its factor Contracts beneath, and the correction's
    Möbius/Intersect chain — and memo hits open no spans."""
    tr, cp = _traced(K5_MINUS_EDGE)
    assert len(tr.roots) == 1
    root = tr.roots[0]
    assert root.kind == "execute" and root.attrs["op"] == "count"
    (shrink,) = root.children
    assert shrink.kind == "ShrinkageCorrect"
    assert shrink.attrs["route"] == "host"
    kinds = [c.kind for c in shrink.children]
    assert kinds == ["CutJoin", "MobiusCombine"]
    join, mob = shrink.children
    assert join.attrs["cut_size"] == 3
    assert join.attrs["route"] == "kernel"
    assert join.attrs["exact_block"] is not None
    assert join.attrs["predicted"] is not None
    shapes = join.attrs["factor_shapes"]
    assert shapes and all(all(d == G.n for d in s) for s in shapes)
    assert all(c.kind == "Contract" for c in join.children)
    assert all(c.attrs["route"] == "einsum-free" for c in join.children)
    assert [c.kind for c in mob.children] == ["Intersect"]
    assert mob.children[0].attrs["route"] == "enumeration"
    # second read: everything memoised, no new spans
    n_before = sum(1 for _ in tr.walk())
    cp.count(K5_MINUS_EDGE)
    assert sum(1 for _ in tr.walk()) == n_before + 1    # just the root


def test_trace_route_xla_dense_when_kernel_off():
    tr, cp = _traced(K5_MINUS_EDGE, cutjoin_kernel=False)
    joins = [s for s in tr.walk() if s.kind == "CutJoin"]
    assert joins and all(s.attrs["route"] == "xla-dense" for s in joins)
    tk, ck = _traced(K5_MINUS_EDGE, cutjoin_kernel=True)
    assert cp.count(K5_MINUS_EDGE) == ck.count(K5_MINUS_EDGE)


def test_trace_coverage_and_self_time():
    tr, cp = _traced(K5_MINUS_EDGE)
    cov = tr.coverage()
    assert cov is not None and 0.95 <= cov <= 1.0 + 1e-9
    for s in tr.walk():
        child_total = sum(c.duration_s for c in s.children)
        assert s.duration_s >= 0.0
        assert abs(s.self_s - max(0.0, s.duration_s - child_total)) < 1e-12


def test_span_nesting_matches_ir_structure():
    """Property: the trace tree is a subtree of the plan DAG — every
    node span's children are refs of that node, and every root's single
    child is the read's output node.  Randomised over patterns via
    hypothesis when available."""
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    pats = [cycle(4), chain(4), K5_MINUS_EDGE, cycle(5)]

    @settings(max_examples=4, deadline=None)
    @given(st.integers(0, len(pats) - 1), st.booleans())
    def check(i, kernel):
        p = pats[i]
        tr, cp = _traced(p, cutjoin_kernel=kernel)
        for s in tr.walk():
            if s.kind == "execute":
                assert len(s.children) <= 1
                continue
            if s.kind == "guard-scan":
                # the exact_block factor scan, nested under its join —
                # not an IR node, and it evaluates nothing
                assert not s.children
                continue
            node = cp.plan.nodes[s.name]
            assert type(node).__name__ == s.kind
            refs = set(node.refs())
            for c in s.children:
                if c.kind == "guard-scan":
                    continue
                assert c.name in refs, (s.name, c.name, refs)

    check()


def test_tracer_annotate_and_error_attr():
    tr = obs.Tracer()
    with pytest.raises(ValueError):
        with tr.span("boom"):
            tr.annotate(x=1)
            raise ValueError("nope")
    assert tr.roots[0].attrs == {"x": 1, "error": "ValueError"}
    tr.annotate(y=2)                        # outside any span: no-op
    assert "y" not in tr.roots[0].attrs


def test_trace_exports(tmp_path):
    tr, cp = _traced(K5_MINUS_EDGE)
    d = tr.to_dict()
    assert d["meta"]["backend"] and d["coverage"] is not None
    assert d["spans"][0]["kind"] == "execute"
    assert d["spans"][0]["children"][0]["dur_us"] >= 0
    json.loads(tr.to_json())

    chrome = tr.to_chrome()
    n_spans = sum(1 for _ in tr.walk())
    assert len(chrome["traceEvents"]) == n_spans
    assert all(e["ph"] == "X" and e["dur"] >= 0
               for e in chrome["traceEvents"])
    # attrs must be JSON-primitive in chrome args (lists repr'd)
    json.dumps(chrome)

    p1 = tr.save(str(tmp_path / "t.json"))
    p2 = tr.save(str(tmp_path / "t.chrome.json"))
    assert "spans" in json.load(open(p1))
    assert "traceEvents" in json.load(open(p2))


def test_untraced_plan_opens_no_spans():
    cp = compiler.compile(cycle(4), G, counter=CountingEngine(G),
                          cache=False)
    assert cp.tracer is None
    cp.count(cycle(4))                      # must not touch any tracer


# -- predicted costs on the plan ---------------------------------------------------

def test_plan_meta_node_costs():
    """Compilation records finite per-node APCT predictions for the
    committed nodes, keyed into plan.nodes — the predicted side of the
    drift pairs."""
    cp = compiler.compile(K5_MINUS_EDGE, G, counter=CountingEngine(G),
                          cache=False, local=True)
    costs = cp.plan.meta["node_costs"]
    assert costs
    for k, v in costs.items():
        assert k in cp.plan.nodes
        assert np.isfinite(v) and v >= 0.0
    # every node the count evaluation touches carries a prediction
    tr = obs.Tracer()
    cp.tracer = tr
    cp._values.clear()
    cp.count(K5_MINUS_EDGE)
    for s in tr.walk():
        if s.kind != "execute":
            assert s.attrs["predicted"] is not None, s.name


# -- drift accounting --------------------------------------------------------------

def test_spearman():
    assert spearman([1, 2, 3], [10, 20, 30]) == pytest.approx(1.0)
    assert spearman([1, 2, 3], [30, 20, 10]) == pytest.approx(-1.0)
    assert spearman([1, 2, 3, 4], [1, 3, 2, 4]) == pytest.approx(0.8)
    assert spearman([1, 1, 2], [1, 2, 3]) is not None   # ties averaged
    assert spearman([1], [2]) is None       # too few pairs
    assert spearman([1, 1], [2, 3]) is None  # degenerate side
    assert spearman([1, 2], [2, 3, 4]) is None  # length mismatch


def test_drift_pairs_and_aggregate():
    tr, cp = _traced(K5_MINUS_EDGE)
    pairs = pairs_from_trace(tr.to_dict())
    assert pairs
    keys = {group_key(p) for p in pairs}
    assert "CutJoin|cut=3|kernel" in keys
    assert any(k.startswith("Contract|") for k in keys)
    for p in pairs:
        assert p["predicted"] is not None and p["measured_us"] >= 0.0
        assert p["cls"] in obs.drift.NODE_KINDS

    report = aggregate(pairs)
    assert report["n_pairs"] == len(pairs)
    assert set(report["groups"]) == keys
    for g in report["groups"].values():
        assert g["n"] >= 1 and g["predicted_sum"] >= 0.0
    # rendering and the bench summary never throw on real reports
    text = obs.drift.render(report)
    assert "CutJoin|cut=3|kernel" in text
    summary = obs.drift.bench_summary(report)
    assert set(summary) == keys


def test_drift_aggregate_synthetic():
    """Known pairs → known report: spread = max/min ratio per group."""
    pairs = [
        {"cls": "Contract", "cut": None, "route": "einsum",
         "backend": "cpu", "predicted": 1.0, "measured_us": 10.0},
        {"cls": "Contract", "cut": None, "route": "einsum",
         "backend": "cpu", "predicted": 2.0, "measured_us": 40.0},
        {"cls": "CutJoin", "cut": 2, "route": "kernel",
         "backend": "cpu", "predicted": 5.0, "measured_us": 5.0},
    ]
    r = aggregate(pairs)
    g = r["groups"]["Contract|cut=-|einsum"]
    assert g["n"] == 2
    assert g["rank_corr"] == pytest.approx(1.0)
    assert g["ratio_spread"] == pytest.approx(2.0)      # 20 / 10
    assert r["groups"]["CutJoin|cut=2|kernel"]["ratio_spread"] is None
    assert r["overall_rank_corr"] is not None


# -- per-phase batcher fallbacks ---------------------------------------------------

def test_batcher_fallback_compile_phase(monkeypatch):
    from repro import compiler as compiler_mod
    from repro.serve.batching import PatternQueryBatcher, PatternRequest

    def boom(*a, **k):
        raise RuntimeError("compiler down")

    monkeypatch.setattr(compiler_mod, "compile", boom)
    b = PatternQueryBatcher(G, max_batch=2)
    for i in range(2):
        b.submit(PatternRequest(uid=i, patterns=(chain(4),)))
    b.run_to_completion()
    assert len(b.finished) == 2
    assert b.stats["fallbacks"] == 2
    assert b.stats["fallbacks_compile"] == 2
    assert b.stats["fallbacks_execute"] == 0
    assert b.stats["errors"] == 0


def test_batcher_fallback_execute_phase(monkeypatch):
    """A plan that compiles but refuses at run time (e.g. PlanTooWide)
    must land in the execute-phase bucket, not the compile one."""
    from repro.compiler.lowering import CompiledPlan
    from repro.serve.batching import PatternQueryBatcher, PatternRequest

    def boom(self, p):
        raise RuntimeError("PlanTooWide at execution")

    monkeypatch.setattr(CompiledPlan, "count", boom)
    b = PatternQueryBatcher(G, max_batch=2)
    b.submit(PatternRequest(uid=0, patterns=(chain(4),)))
    b.run_to_completion()
    req = b.finished[0]
    assert req.done and not req.error
    assert req.counts[chain(4)] == CountingEngine(G).edge_induced(chain(4))
    assert b.stats["fallbacks"] == 1
    assert b.stats["fallbacks_execute"] == 1
    assert b.stats["fallbacks_compile"] == 0


def test_batcher_stats_dict_compat():
    """The stats facade still behaves like the old plain dict."""
    from repro.serve.batching import PatternQueryBatcher, PatternRequest
    b = PatternQueryBatcher(G, max_batch=2)
    b.submit(PatternRequest(uid=0, patterns=(clique(3),)))
    b.run_to_completion()
    assert b.stats["steps"] == 1 and b.stats["compiles"] == 1
    assert set(b.stats) >= {"steps", "compiles", "cache_hits",
                            "fallbacks", "errors"}
    assert isinstance(dict(b.stats)["steps"], int)


# -- plan cache eviction metrics ---------------------------------------------------

@pytest.mark.slow
def test_plancache_eviction_metrics(tmp_path):
    from repro.compiler import PlanCache, plan_key
    reg = obs.REGISTRY
    base_age = reg.get("plancache.eviction.age_s", default=None)
    n_before = base_age["count"] if isinstance(base_age, dict) else 0

    cache = PlanCache(str(tmp_path), max_disk_entries=2)
    pats = [chain(3), chain(4), cycle(4), cycle(5)]
    for p in pats:
        compiler.compile(p, G, counter=CountingEngine(G), cache=cache)
    assert cache.evictions >= 2
    age = reg.get("plancache.eviction.age_s", default=None)
    size = reg.get("plancache.eviction.bytes", default=None)
    assert age["count"] - n_before >= 2
    assert age["min"] >= 0.0
    assert size["min"] > 0                  # real plan files have bytes
    # instance counters stay exact and int-typed through the facade
    assert isinstance(cache.evictions, int)
    assert cache.stats["evictions"] == cache.evictions


def test_plancache_clear_keeps_registry_monotonic(tmp_path):
    from repro.compiler import PlanCache
    reg = obs.REGISTRY
    cache = PlanCache()
    compiler.compile(chain(3), G, counter=CountingEngine(G), cache=cache)
    assert cache.misses == 1
    before = reg.get("plancache.misses", tier="mem")
    cache.clear()
    assert cache.misses == 0                # local reset
    assert reg.get("plancache.misses", tier="mem") == before   # monotonic


# -- kernel / api counters ---------------------------------------------------------

def test_kernel_call_counters():
    from repro.kernels import ops
    reg = obs.REGISTRY
    before = reg.get("kernel.calls", op="cutjoin_reduce", cut=2)
    M = np.ones((8, 8))
    ops.cutjoin_reduce([M, M])
    assert reg.get("kernel.calls", op="cutjoin_reduce", cut=2) == before + 1
    granted = reg.get("kernel.exact_block", outcome="granted")
    precertified = reg.get("kernel.exact_block", outcome="precertified")
    assert granted + precertified >= 1


def test_api_compile_fallback_counter(monkeypatch):
    from repro import api
    from repro.api import local as api_local
    reg = obs.REGISTRY
    before = reg.get("api.compile_fallbacks", entry="local_counts")

    def boom(*a, **k):
        raise RuntimeError("compiler down")

    monkeypatch.setattr(api_local, "_compile_local", boom)
    lc = api.local_counts(chain(4), G)
    assert lc.counts is not None
    assert reg.get("api.compile_fallbacks",
                   entry="local_counts") == before + 1
