"""Partial-embedding API: differential/property harness.

Ground truth is brute-force enumeration bucketed by cut assignment; every
invariant is checked with *integer equality* — local counts are exact
counts, not approximations:

  * the full local tensor equals the bucketed enumeration entrywise, for
    every eligible cutting set, across unlabelled and labelled patterns
    and every graph generator;
  * anchored local counts sum to the global injective count, and equal
    the engine's ``inj_free`` domain vectors entrywise;
  * Σ_v vertex_counts(v) == n_p · inj(p) / |Aut| (each embedding counted
    once per pattern position, orbit-weighted);
  * the |cut| <= 2 keep-axis Pallas kernel agrees bit-for-bit with the
    f64 XLA fallback (both exact integers under the chunk guard);
  * local counts are invariant under graph vertex relabelling
    (hypothesis property, derandomized in CI via conftest profiles).

Plus golden IR locks for ``LocalCount`` plans and the plan-format-v5
drift tests (v3 entries miss cleanly — no strip-and-serve).
"""
import numpy as np
import pytest

from repro import compiler
from repro.api import exists, local_counts, pattern_domains, vertex_counts
from repro.compiler import frontend, lowering
from repro.compiler.cache import PlanCache, plan_key
from repro.compiler.ir import (LocalCount, MobiusCombine,
                               PLAN_FORMAT_VERSION, Plan, local_key,
                               pattern_key)
from repro.core.counting import CountingEngine, brute_force_edge_induced
from repro.core.decomposition import cutting_sets
from repro.core.engine import MiningEngine
from repro.core.fsm import mini_support, mini_support_dense
from repro.core.pattern import (Pattern, chain, clique, cycle,
                                pseudo_clique, star, tailed_triangle)
from repro.graph.generators import (erdos_renyi, rmat, small_world,
                                    triangle_rich)
from repro.graph.storage import Graph

HOUSE = Pattern(5, [(0, 1), (1, 2), (2, 3), (3, 0), (0, 4), (1, 4)])

GRAPHS = {
    "er": erdos_renyi(20, 4.0, seed=1),
    "rmat": rmat(4, 5.0, seed=2),
    "ws": small_world(22, 4, 0.2, seed=3),
    "tri": triangle_rich(24, 4, seed=4),
    "tri-lab": triangle_rich(24, 4, seed=5, num_labels=2),
}

PATTERNS = [chain(4), cycle(4), tailed_triangle(), star(4), HOUSE]
LABELLED = [Pattern(3, [(0, 1), (1, 2)], (0, 1, 0)),
            Pattern(4, [(0, 1), (1, 2), (0, 2), (2, 3)], (0, 1, 0, 1)),
            Pattern(4, [(0, 1), (1, 2), (2, 3)], (1, 0, 0, 1))]

_ENGINES = {}


def eng_for(gname):
    if gname not in _ENGINES:
        _ENGINES[gname] = CountingEngine(GRAPHS[gname])
    return _ENGINES[gname]


def brute_local(g, p, cut):
    """Oracle: injective embedding tuples bucketed by cut assignment."""
    m = MiningEngine.__new__(MiningEngine)      # enumeration only
    m.graph = g
    cut_list = sorted(cut)
    out = np.zeros((g.n,) * len(cut_list))
    for emb in MiningEngine._enumerate(m, p):
        out[tuple(emb[c] for c in cut_list)] += 1
    return out


# -- the core differential: local tensor == bucketed enumeration ------------------

@pytest.mark.parametrize("gname", sorted(GRAPHS))
def test_local_tensor_matches_enumeration(gname):
    """Every eligible cutting set of every pattern: the reduce-free
    local tensor equals brute force entrywise, and its sum reproduces
    the global injective count (integer equality)."""
    g = GRAPHS[gname]
    eng = eng_for(gname)
    pats = PATTERNS + (LABELLED if g.labels is not None else [])
    checked = 0
    for p in pats:
        inj = brute_force_edge_induced(g, p) * p.aut_order()
        for cut in cutting_sets(p):
            cand = frontend.local_candidate(p, cut, graph_n=g.n)
            if cand is None:
                continue
            plan = Plan()
            for node in cand.nodes:
                plan.add(node)
            cp = lowering.lower(plan, g, counter=eng)
            L = np.asarray(cp.value(cand.out_key))
            assert np.array_equal(L, brute_local(g, p, cut)), \
                (gname, p, sorted(cut))
            assert L.sum() == inj, (gname, p, sorted(cut))
            checked += 1
    assert checked >= len(pats)


@pytest.mark.parametrize("gname", ["er", "tri-lab"])
@pytest.mark.slow
def test_anchored_sums_to_global_and_matches_domains(gname):
    """Anchored vectors: Σ_u A_v[u] == inj(p) for every anchor v, and
    A_v equals the engine's inj_free domain entrywise — whichever route
    (decomposition join or flat Möbius) the plan took."""
    g = GRAPHS[gname]
    eng = eng_for(gname)
    pats = [chain(4), tailed_triangle(), clique(4)] + \
        (LABELLED[:2] if g.labels is not None else [])
    for p in pats:
        inj = brute_force_edge_induced(g, p) * p.aut_order()
        for v in range(p.n):
            lc = local_counts(p, g, anchor=v, counter=eng, cache=False)
            assert lc.counts.sum() == inj, (gname, p, v, lc.style)
            assert np.array_equal(lc.counts, eng.inj_free(p, v)), \
                (gname, p, v, lc.style)


@pytest.mark.slow
def test_vertex_counts_orbit_invariant():
    """Σ_u vertex_counts[u] == n_p · inj(p) / |Aut|: each edge-induced
    embedding contributes once per pattern position (integer equality
    after the orbit weighting)."""
    g = GRAPHS["er"]
    eng = eng_for("er")
    for p in [chain(4), cycle(4), tailed_triangle(), clique(4), HOUSE]:
        want = p.n * brute_force_edge_induced(g, p)
        vc = vertex_counts(p, g, counter=eng, cache=False)
        assert vc.sum() == want, (p, vc.sum(), want)
        assert np.all(vc >= 0)


def test_vertex_counts_matches_per_vertex_brute_force():
    """vertex_counts[u] == # edge-induced embeddings containing u,
    counted from the raw enumeration."""
    g = GRAPHS["rmat"]
    m = MiningEngine.__new__(MiningEngine)
    m.graph = g
    for p in (tailed_triangle(), cycle(4)):
        per_emb = {}
        for emb in MiningEngine._enumerate(m, p):
            per_emb[tuple(sorted(emb))] = \
                per_emb.get(tuple(sorted(emb)), 0) + 1
        want = np.zeros(g.n)
        for key, c in per_emb.items():
            assert c % p.aut_order() == 0
            for u in key:
                want[u] += c // p.aut_order()
        vc = vertex_counts(p, g, counter=eng_for("rmat"), cache=False)
        assert np.array_equal(vc, want), p


# -- keep-axis kernel: bit-for-bit vs the XLA path ---------------------------------

@pytest.mark.parametrize("n", [24, 100, 150])
@pytest.mark.parametrize("k", [1, 2, 3])
@pytest.mark.parametrize("keep", [0, 1])
def test_keep_axis_kernel_bitforbit(n, k, keep):
    """cutjoin_reduce_keep == the f64 masked mask-and-sum on integer
    factors, bit-for-bit, across factor counts, non-tile-multiple n,
    and both keep axes."""
    from repro.kernels import ops
    rng = np.random.default_rng(n * 10 + k * 2 + keep)
    Fs = [rng.integers(0, 7, size=(n, n)).astype(np.float64)
          for _ in range(k)]
    assert ops.cutjoin_exact_block(Fs) is not None
    got = ops.cutjoin_reduce_keep(Fs, keep=keep)
    prod = np.ones((n, n))
    for F in Fs:
        prod *= F
    np.fill_diagonal(prod, 0.0)
    want = prod.sum(axis=1 - keep)
    assert got.shape == (n,) and got.dtype == np.float64
    assert np.array_equal(got, want)


def test_keep_axis_kernel_through_lowering_bitforbit():
    """An anchored |cut| = 2 plan evaluated with the kernel tier and
    with ``cutjoin_kernel=False`` (XLA fallback) returns bit-identical
    vectors."""
    g = GRAPHS["ws"]
    p = cycle(5)                          # anchored cuts have size 2
    ck = compiler.compile((p,), g, counter=CountingEngine(g),
                          cache=False, local=True)
    cx = compiler.compile((p,), g, counter=CountingEngine(g),
                          cache=False, local=True, cutjoin_kernel=False)
    key = local_key(p, 0)
    assert ck.plan.meta["local_cuts"][key] is not None
    # anchored cuts of a 5-cycle have size 2; the tri tier may commit a
    # 3-cut when the model prices it cheaper — either way the kernel
    # tier (pair or tri keep-axis) must match the XLA oracle exactly
    assert len(ck.plan.meta["local_cuts"][key]) in (2, 3)
    assert 0 in ck.plan.meta["local_cuts"][key]
    a, b = ck.local_counts(p, 0), cx.local_counts(p, 0)
    assert np.array_equal(a, b)
    assert np.array_equal(a, CountingEngine(g).inj_free(p, 0))


def test_exact_guard_falls_back_to_xla():
    """Factors beyond the f32 chunk guard must still evaluate exactly
    (the keep-axis path falls through to the f64 XLA join)."""
    from repro.kernels import ops
    n = 40
    big = float(1 << 23)
    Fs = [np.full((n, n), big), np.full((n, n), 4.0)]
    assert ops.cutjoin_exact_block(Fs) is None
    prod = np.full((n, n), big * 4.0)
    np.fill_diagonal(prod, 0.0)
    want = prod.sum(axis=1)
    # lowering-level check: _eval_local takes the fallback
    from repro.compiler.lowering import _join_keep
    import jax
    import jax.numpy as jnp
    with jax.experimental.enable_x64():
        got = np.asarray(_join_keep(jnp.stack(
            [jnp.asarray(F) for F in Fs]), 0), np.float64)
    assert np.array_equal(got, want)


# -- existence fast path -----------------------------------------------------------

@pytest.mark.slow
def test_exists_matches_engine():
    g = GRAPHS["er"]
    eng = eng_for("er")
    for p in [chain(4), clique(3), clique(4), clique(6), cycle(5),
              star(5)]:
        assert exists(p, g, counter=eng, cache=False) == \
            eng.existence(p), p


def test_exists_early_exit_skips_join():
    """A graph with no triangles: any pattern containing one dies at its
    triangle factor, before the join or shrinkage corrections — counted
    by the plan's early-exit stat."""
    g = Graph(12, [(i, (i + 1) % 12) for i in range(12)])   # 12-cycle
    p = tailed_triangle()
    cp = compiler.compile((p,), g, cache=False, local=True)
    assert cp.exists(p) is False
    assert cp.stats["exists_early_exits"] == 1
    assert exists(p, g, cache=False) is False
    assert brute_force_edge_induced(g, p) == 0


# -- consumers: FSM MINI support and the pseudo-clique miner -----------------------

def test_mini_support_api_matches_dense():
    """MINI support through anchored local counts == the legacy dense
    inj_free_all route, labelled and unlabelled."""
    eng = eng_for("tri-lab")
    for p in LABELLED + [chain(3)]:
        assert mini_support(eng, p) == mini_support_dense(eng, p), p


def test_pattern_domains_match_inj_free():
    eng = eng_for("tri-lab")
    p = LABELLED[1]
    doms = pattern_domains(eng, p)
    assert set(doms) == {o[0] for o in p.vertex_orbits()}
    for rep, vec in doms.items():
        assert np.array_equal(vec, eng.inj_free(p, rep)), rep


def test_pseudo_clique_miner_differential():
    """Miner per-vertex participation == brute-force enumeration of
    every pseudo-clique pattern, and totals match the engine counts."""
    from repro.core.search import mine_pseudo_cliques
    g = GRAPHS["er"]
    eng = eng_for("er")
    r = mine_pseudo_cliques(g, 4, missing=1, counter=eng,
                            use_compiler=False)
    m = MiningEngine.__new__(MiningEngine)
    m.graph = g
    want = np.zeros(g.n)
    tot = {}
    for p in pseudo_clique(4, 1):
        cnt = {}
        for emb in MiningEngine._enumerate(m, p):
            cnt[tuple(sorted(emb))] = cnt.get(tuple(sorted(emb)), 0) + 1
        tot[p] = 0
        for key, c in cnt.items():
            tot[p] += c // p.aut_order()
            for u in key:
                want[u] += c // p.aut_order()
    assert np.array_equal(r.per_vertex, want)
    for p, v in r.totals.items():
        assert v == tot[p.canonical()], p
    assert r.hotspots == sorted(
        (u for u in range(g.n) if want[u] >= 1),
        key=lambda u: (-want[u], u))


# -- serving -----------------------------------------------------------------------

def test_batcher_serves_local_requests():
    from repro.serve.batching import PatternQueryBatcher, PatternRequest
    g = GRAPHS["tri"]
    eng = eng_for("tri")
    b = PatternQueryBatcher(g, max_batch=4)
    pats = (chain(4), tailed_triangle())
    for i in range(4):
        b.submit(PatternRequest(uid=i, patterns=pats, local=True,
                                anchor=(0 if i % 2 else None)))
    b.run_to_completion()
    assert len(b.finished) == 4
    assert b.stats["compiles"] == 1                # one local plan
    for req in b.finished:
        assert req.done and not req.error
        for p in pats:
            arr = req.local_counts[p]
            inj = brute_force_edge_induced(g, p) * p.aut_order()
            assert arr is not None and arr.sum() == inj
            if req.anchor is not None:
                assert np.array_equal(arr, eng.inj_free(p, req.anchor))


def test_batcher_local_fallback_on_compile_failure(monkeypatch):
    from repro import compiler as compiler_mod
    from repro.serve.batching import PatternQueryBatcher, PatternRequest

    def boom(*a, **k):
        raise RuntimeError("compiler down")

    g = GRAPHS["tri"]
    monkeypatch.setattr(compiler_mod, "compile", boom)
    b = PatternQueryBatcher(g, max_batch=2)
    b.submit(PatternRequest(uid=0, patterns=(chain(4), clique(4)),
                            local=True, anchor=0))
    b.run_to_completion()
    req = b.finished[0]
    assert req.done and not req.error and b.stats["fallbacks"] == 1
    eng = eng_for("tri")
    for p in (chain(4), clique(4)):
        assert np.array_equal(req.local_counts[p], eng.inj_free(p, 0))


# -- golden IR locks ---------------------------------------------------------------

def test_golden_local_plan_tailed_triangle():
    """Tailed triangle, cut {2}: a LocalCount over one kept axis with
    two factors (triangle + edge) and a nonempty anchored shrinkage
    correction; the anchored-at-2 output aliases the same node."""
    p = tailed_triangle()
    cand = frontend.local_candidate(p, frozenset({2}), graph_n=24)
    assert cand is not None and cand.style == "local"
    out = cand.nodes[-1]
    assert isinstance(out, LocalCount)
    assert out.cut_size == 1 and out.keep == (0,)
    assert len(out.factors) == 2                   # one M_i per subpattern
    assert len(out.corrections) >= 1               # triangle shrinkage
    for _, ref in out.corrections:
        assert ref.startswith("homf:")
    # anchored at the cut vertex: same join, same keep
    canda = frontend.local_candidate(p, frozenset({2}), graph_n=24,
                                     anchor=2)
    assert canda.nodes[-1].key == out.key


def test_golden_local_plan_keep_axes():
    """4-chain, cut {1, 2}: the reduce-free tensor keeps both axes;
    anchoring vertex 1 keeps only axis 0."""
    p = chain(4)
    cut = frozenset({1, 2})
    full = frontend.local_candidate(p, cut, graph_n=24)
    anch = frontend.local_candidate(p, cut, graph_n=24, anchor=1)
    nf, na = full.nodes[-1], anch.nodes[-1]
    assert nf.cut_size == na.cut_size == 2
    assert nf.keep == (0, 1) and na.keep == (0,)
    assert nf.factors == na.factors                # same join, new output
    assert nf.key != na.key


def test_golden_anchored_direct_candidate():
    """Cliques have no cutting set: the anchored fallback is one flat
    Möbius combine over single-free-vertex hom tensors."""
    cand = frontend.anchored_direct_candidate(clique(4), 0)
    out = cand.nodes[-1]
    assert isinstance(out, MobiusCombine) and out.divisor == 1
    assert cand.style == "local-direct"
    assert all(ref.startswith("homf:") for _, ref in out.terms)


def test_local_key_orbit_and_isomorph_stable():
    """local_key collapses automorphism-orbit anchors and isomorphic
    renumberings; anchored and unanchored namespaces never collide even
    when marker labels mimic real labels."""
    p = chain(4)
    assert local_key(p, 0) == local_key(p, 3)      # end vertices: one orbit
    assert local_key(p, 1) == local_key(p, 2)
    assert local_key(p, 0) != local_key(p, 1)
    q = Pattern(4, [(3, 2), (2, 1), (1, 0)])       # same chain renumbered
    assert local_key(q, 3) == local_key(p, 0)
    lab = Pattern(3, [(0, 1), (1, 2)], (0, 0, 1))
    assert local_key(lab) != local_key(chain(3), 2)


# -- plan cache: format v5, no strip-and-serve -------------------------------------

def test_plan_format_v5_drift(tmp_path):
    """v4 (or any non-v5) on-disk entries miss cleanly: a pre-axis-subset
    reader version must never be half-loaded with |cut| = 3 factors
    expanded over the full cut (nor a pre-LocalCount one with local
    outputs stripped)."""
    import json
    g = GRAPHS["er"]
    cache = PlanCache(str(tmp_path))
    pats = (chain(4),)
    key = plan_key(pats, g)
    cp = compiler.compile(pats, g, cache=cache, local=True)
    assert cp.plan.to_dict()["version"] == PLAN_FORMAT_VERSION == 5
    d = json.loads(open(cache._file(key)).read())
    assert any(nd["op"] == "local" for nd in d["nodes"])
    for stale in (4, 3, 1, None):
        d2 = dict(d)
        if stale is None:
            d2.pop("version", None)
        else:
            d2["version"] = stale
        with open(cache._file(key), "w") as fh:
            fh.write(json.dumps(d2))
        fresh = PlanCache(str(tmp_path))
        assert fresh.get(key) is None, stale
    with pytest.raises(ValueError):
        Plan.from_dict({"version": 3, "nodes": [], "outputs": {}})


def test_local_cache_interplay_no_strip_and_serve():
    """A cached plan without local outputs misses a local=True request
    (recompile, never served stripped); the richer local plan then
    serves count-only lookups from cache."""
    g = GRAPHS["er"]
    cache = PlanCache()
    pats = (chain(4),)
    cp1 = compiler.compile(pats, g, cache=cache)
    assert not cp1.plan.meta["local"]
    cp2 = compiler.compile(pats, g, cache=cache, local=True)
    assert not cp2.from_cache                  # no local outputs: recompile
    assert cp2.has_local(pats[0]) and cp2.has_local(pats[0], 0)
    cp3 = compiler.compile(pats, g, cache=cache)
    assert cp3.from_cache                      # superset plan serves counts
    cp4 = compiler.compile(pats, g, cache=cache, local=True)
    assert cp4.from_cache
    assert np.array_equal(cp4.local_counts(pats[0]),
                          cp2.local_counts(pats[0]))
    assert cp4.count(pats[0]) == cp1.count(pats[0])


def test_unanchored_tensor_canonical_across_renumberings():
    """The unanchored output key collapses isomorphic renumberings, so
    the tensor must be expressed in canonical-form numbering: a caller
    holding a different renumbering gets the same well-defined answer
    (axes name canonical vertices), never a tensor whose axes silently
    refer to someone else's numbering."""
    g = GRAPHS["er"]
    cache = PlanCache()
    p = chain(4)                                   # path 0-1-2-3
    q = Pattern(4, [(0, 2), (0, 3), (3, 1)])       # same path renumbered
    assert pattern_key(p) == pattern_key(q)
    lc_p = local_counts(p, g, cache=cache)         # compiles
    lc_q = local_counts(q, g, cache=cache)         # cache hit, same entry
    assert lc_q.from_cache
    assert lc_p.axes == lc_q.axes
    assert np.array_equal(lc_p.counts, lc_q.counts)
    # the axes are a genuine cutting set of the canonical form, and the
    # tensor matches brute force on that form
    pc = p.canonical()
    assert frozenset(lc_p.axes) in set(cutting_sets(pc))
    assert np.array_equal(lc_p.counts,
                          brute_local(g, pc, frozenset(lc_p.axes)))
    # uncompiled direct path: same canonical semantics
    lc_d = local_counts(q, g, use_compiler=False)
    assert np.array_equal(lc_d.counts,
                          brute_local(g, pc, frozenset(lc_d.axes)))


def test_anchored_axes_name_the_anchor():
    g = GRAPHS["er"]
    lc = local_counts(chain(4), g, anchor=2, cache=False)
    assert lc.axes == (2,) and lc.counts.shape == (g.n,)


def test_domains_local_union_no_cache_ping_pong():
    """Alternating domains=True and local=True requests for one pattern
    set must not evict each other: the recompile unions the stored
    plan's flags, so the third request (and everything after) hits."""
    g = GRAPHS["tri-lab"]
    pats = (LABELLED[0],)
    cache = PlanCache()
    cp1 = compiler.compile(pats, g, cache=cache, domains=True)
    cp2 = compiler.compile(pats, g, cache=cache, local=True)
    assert not cp2.from_cache                  # first local: recompile...
    assert cp2.plan.meta["domains"] and cp2.plan.meta["local"]  # ...union
    cp3 = compiler.compile(pats, g, cache=cache, domains=True)
    cp4 = compiler.compile(pats, g, cache=cache, local=True)
    assert cp3.from_cache and cp4.from_cache   # both flavors now hit
    assert cp3.mini_support(pats[0]) == cp1.mini_support(pats[0])


def test_local_counts_returns_a_copy():
    """Served arrays must not alias the plan's node-value memo: an
    in-place edit by one caller must not corrupt later answers."""
    g = GRAPHS["er"]
    p = chain(4)
    cp = compiler.compile((p,), g, cache=False, local=True)
    a = cp.local_counts(p, 0)
    a *= 0.0                                   # hostile caller
    b = cp.local_counts(p, 0)
    assert np.array_equal(b, CountingEngine(g).inj_free(p, 0))
    assert not np.array_equal(a, b)


def test_local_roundtrip_executes_identically():
    g = GRAPHS["tri"]
    pats = (chain(4), tailed_triangle())
    cp = compiler.compile(pats, g, cache=False, local=True)
    rt = Plan.from_json(cp.plan.to_json())
    assert rt == cp.plan
    cp2 = lowering.lower(rt, g)
    for p in pats:
        assert np.array_equal(cp2.local_counts(p), cp.local_counts(p))
        for orbit in p.vertex_orbits():
            assert np.array_equal(cp2.local_counts(p, orbit[0]),
                                  cp.local_counts(p, orbit[0]))


# -- hypothesis: relabelling invariance --------------------------------------------

def test_local_counts_invariant_under_relabelling():
    """Property: permuting graph vertices permutes anchored local-count
    vectors (and vertex_counts) by the same permutation — the counts
    are a graph invariant, not an artifact of vertex order.  Runs
    derandomized under the CI profile (see conftest)."""
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    pats = [chain(4), tailed_triangle(), cycle(4)]

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 10_000), pi=st.integers(0, 2),
           perm_seed=st.integers(0, 10_000))
    def check(seed, pi, perm_seed):
        g = erdos_renyi(14, 3.0, seed=seed)
        p = pats[pi]
        perm = np.random.default_rng(perm_seed).permutation(g.n)
        g2 = Graph(g.n, np.stack([perm[g.edges[:, 0]],
                                  perm[g.edges[:, 1]]], 1))
        e1, e2 = CountingEngine(g), CountingEngine(g2)
        for v in (0, p.n - 1):
            a = local_counts(p, g, anchor=v, counter=e1,
                             use_compiler=False).counts
            b = local_counts(p, g2, anchor=v, counter=e2,
                             use_compiler=False).counts
            assert np.array_equal(b[perm], a), (seed, pi, v)
        va = vertex_counts(p, g, counter=e1, use_compiler=False)
        vb = vertex_counts(p, g2, counter=e2, use_compiler=False)
        assert np.array_equal(vb[perm], va)

    check()


def test_labelled_local_counts_invariant_under_relabelling():
    """Same property on a labelled graph: labels travel with their
    vertices under the permutation."""
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    p = Pattern(4, [(0, 1), (1, 2), (0, 2), (2, 3)], (0, 1, 0, 1))

    @settings(max_examples=6, deadline=None)
    @given(seed=st.integers(0, 10_000), perm_seed=st.integers(0, 10_000))
    def check(seed, perm_seed):
        g = erdos_renyi(14, 3.5, seed=seed, num_labels=2)
        perm = np.random.default_rng(perm_seed).permutation(g.n)
        labels2 = np.empty(g.n, g.labels.dtype)
        labels2[perm] = g.labels
        g2 = Graph(g.n, np.stack([perm[g.edges[:, 0]],
                                  perm[g.edges[:, 1]]], 1), labels2)
        a = local_counts(p, g, anchor=3, counter=CountingEngine(g),
                         use_compiler=False).counts
        b = local_counts(p, g2, anchor=3, counter=CountingEngine(g2),
                         use_compiler=False).counts
        assert np.array_equal(b[perm], a), (seed, perm_seed)

    check()
