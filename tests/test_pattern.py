"""Pattern machinery: canonical forms, automorphisms, motifs, quotients."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.motifs import motif_patterns
from repro.core.pattern import (Pattern, chain, clique, cycle, pseudo_clique,
                                star, tailed_triangle)
from repro.core.quotient import (mobius, partitions, quotient_terms,
                                 shrinkage_patterns)


def test_motif_counts_match_oeis():
    # connected graphs on n vertices: A001349
    assert [len(motif_patterns(k)) for k in (3, 4, 5, 6)] == [2, 6, 21, 112]


def test_aut_orders():
    assert chain(3).aut_order() == 2
    assert clique(3).aut_order() == 6
    assert cycle(4).aut_order() == 8
    assert star(5).aut_order() == 24
    assert clique(5).aut_order() == 120
    assert tailed_triangle().aut_order() == 2


def test_pseudo_clique_family():
    # k=1 (paper's PC experiments): clique plus clique-minus-one-edge
    fam = pseudo_clique(5, 1)
    assert len(fam) == 1                      # one iso class of K5 minus edge
    assert all(p.m == 9 for p in fam)


@st.composite
def random_pattern(draw, max_n=6):
    n = draw(st.integers(3, max_n))
    edges = []
    for i in range(n):
        for j in range(i + 1, n):
            if draw(st.booleans()):
                edges.append((i, j))
    p = Pattern(n, edges)
    return p


@given(random_pattern())
@settings(max_examples=60, deadline=None)
def test_canonical_invariant_under_relabel(p):
    rng = np.random.default_rng(p._hash % (2**32))
    perm = tuple(rng.permutation(p.n).tolist())
    q = p.relabel(perm)
    assert p.canonical() == q.canonical()


@given(random_pattern(max_n=5))
@settings(max_examples=40, deadline=None)
def test_aut_contains_identity_and_is_group_sized(p):
    auts = p.automorphisms()
    assert tuple(range(p.n)) in auts
    # closure under composition
    a, b = auts[0], auts[-1]
    comp = tuple(b[a[i]] for i in range(p.n))
    assert comp in auts


def test_partition_counts_are_bell_numbers():
    bell = [1, 1, 2, 5, 15, 52]
    for k in range(4):
        assert sum(1 for _ in partitions(tuple(range(k)))) == bell[k]


def test_mobius_singletons():
    assert mobius([[0], [1], [2]]) == 1
    assert mobius([[0, 1], [2]]) == -1
    assert mobius([[0, 1, 2]]) == 2


def test_quotient_terms_three_chain():
    # inj(3-chain) = hom(3-chain) - hom(single-edge)   (merge endpoints)
    terms = quotient_terms(chain(3))
    d = {q: c for c, q in terms}
    assert d[chain(3).canonical()] == 1
    assert d[chain(2).canonical()] == -1
    assert len(d) == 2


def test_clique_has_no_cutting_set():
    from repro.core.decomposition import cutting_sets
    assert cutting_sets(clique(4)) == ()
    assert len(cutting_sets(chain(4))) > 0


def test_shrinkage_excludes_within_component():
    # Fig 8: merging 3 and 4 (different components) produces p';
    # merging within a component is not a shrinkage
    p = Pattern(5, [(0, 1), (0, 2), (1, 2), (1, 3), (2, 3), (1, 4), (2, 4)])
    shr = shrinkage_patterns(p, frozenset({0, 1, 2}))
    assert len(shr) == 1
    q, mult = shr[0]
    assert q.n == 4 and mult == 1
