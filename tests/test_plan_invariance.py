"""Property: counts are invariant to the contraction plan.

The decomposition (cutting set -> elimination order) may change cost by
orders of magnitude but never the value — the system-level equivalence the
paper's §4.4 'preserving equivalence of computation' demands.  Hypothesis
drives random patterns x random orders x random graphs.
"""
import itertools
import random

import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import homomorphism as H
from repro.core.counting import CountingEngine
from repro.core.decomposition import candidates, cutting_sets, subpatterns
from repro.core.pattern import Pattern, chain
from repro.graph.generators import erdos_renyi

G = erdos_renyi(48, 5.0, seed=11)
A = jnp.asarray(G.dense_adjacency(np.float64, pad=False))


@st.composite
def connected_pattern(draw, max_n=5):
    n = draw(st.integers(3, max_n))
    edges = [(i, draw(st.integers(0, i - 1))) for i in range(1, n)]  # tree
    for i in range(n):
        for j in range(i + 1, n):
            if draw(st.booleans()):
                edges.append((i, j))
    return Pattern(n, edges)


@given(connected_pattern(), st.integers(0, 10_000))
@settings(max_examples=40, deadline=None)
def test_hom_invariant_to_elimination_order(p, seed):
    rng = random.Random(seed)
    base = float(H.hom_count(p, A))
    order = list(range(p.n))
    rng.shuffle(order)
    got = float(H.hom_count(p, A, order=tuple(order)))
    assert abs(got - base) < 1e-6 * max(1.0, abs(base))


@given(connected_pattern(max_n=5))
@settings(max_examples=25, deadline=None)
def test_inj_invariant_to_cut_choice(p):
    eng = CountingEngine(G)
    base = eng.inj(p, cut=None)
    for cut in list(cutting_sets(p))[:4]:
        assert abs(eng.inj(p, cut=cut) - base) < 1e-6 * max(1.0, abs(base))


@given(connected_pattern(max_n=5))
@settings(max_examples=25, deadline=None)
def test_subpatterns_cover_pattern(p):
    """Coverage guarantee holds structurally for every cutting set."""
    for cut in list(cutting_sets(p))[:6]:
        subs = subpatterns(p, cut)
        covered = set()
        for sub, vmap in subs:
            covered.update(vmap.keys())
        assert covered == set(range(p.n))
        # each subpattern = one component + the whole cut
        for sub, vmap in subs:
            assert set(cut) <= set(vmap)


def test_hom_chain_equals_matrix_power():
    """hom(k-chain) == 1ᵀ A^{k-1} 1 — exact closed form."""
    ones = jnp.ones((A.shape[0],), A.dtype)
    m = A
    for k in range(3, 6):
        m = m @ A if k > 3 else A @ A
        want = float(ones @ (m @ ones))
        got = float(H.hom_count(chain(k), A))
        assert abs(got - want) < 1e-6 * max(1.0, want)
