"""Continuous batching engine: correctness vs sequential generation."""
import jax
import numpy as np
import pytest

from repro.configs.base import reduced_config
from repro.configs.registry import get_config
from repro.models.transformer import Model, init_cache
from repro.serve.batching import ContinuousBatcher, Request
from repro.serve.engine import greedy_sample, make_decode_step

CFG = reduced_config(get_config("qwen3-4b"), num_layers=2, remat=False)
KEY = jax.random.PRNGKey(0)


def _sequential_generate(cfg, params, prompt, max_new, capacity=64):
    """Reference: full forward re-run per generated token."""
    model = Model(cfg)
    toks = list(prompt)
    out = []
    import jax.numpy as jnp
    for _ in range(max_new):
        logits, _, _ = model(params, jnp.asarray([toks]), mode="train")
        t = int(np.asarray(greedy_sample(logits[0, -1:]))[0])
        out.append(t)
        toks.append(t)
    return out


@pytest.fixture(scope="module")
def setup():
    model = Model(CFG)
    params = model.init(KEY)
    return model, params


@pytest.mark.slow
def test_batcher_matches_sequential(setup):
    model, params = setup
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, CFG.vocab_size, int(rng.integers(4, 9)))
               .astype(np.int32) for _ in range(5)]
    b = ContinuousBatcher(CFG, params, slots=2, capacity=64)
    for i, p in enumerate(prompts):
        b.submit(Request(uid=i, prompt=p, max_new_tokens=6))
    b.run_to_completion()
    assert len(b.finished) == 5
    for req in b.finished:
        want = _sequential_generate(CFG, params, list(req.prompt), 6)
        assert req.generated == want, (req.uid, req.generated, want)


def test_batcher_max_one_token_retires_at_admission(setup):
    """max_new_tokens=1: the prefill-sampled token is the whole output —
    the request must retire at admission, never occupy a slot, and never
    decode an extra token."""
    model, params = setup
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, CFG.vocab_size, 6).astype(np.int32)
    b = ContinuousBatcher(CFG, params, slots=2, capacity=32)
    b.submit(Request(uid=0, prompt=prompt, max_new_tokens=1))
    b.run_to_completion()
    assert len(b.finished) == 1
    req = b.finished[0]
    assert req.done and len(req.generated) == 1
    assert req.generated == _sequential_generate(CFG, params, list(prompt), 1)
    assert not b.active                       # slot was never occupied


def test_batcher_eos_on_first_token_retires_at_admission(setup):
    """A request whose prefill-sampled first token is EOS retires at
    admission instead of decoding one token past EOS."""
    model, params = setup
    rng = np.random.default_rng(4)
    prompt = rng.integers(0, CFG.vocab_size, 5).astype(np.int32)
    first = _sequential_generate(CFG, params, list(prompt), 1)[0]
    b = ContinuousBatcher(CFG, params, slots=2, capacity=32)
    b.submit(Request(uid=0, prompt=prompt, max_new_tokens=8, eos_id=first))
    b.run_to_completion()
    assert len(b.finished) == 1
    req = b.finished[0]
    assert req.done and req.generated == [first]
    assert not b.active


def test_batcher_freed_slot_readmits_same_step(setup):
    """Requests retiring at admission free their slot for the next
    queued request within the same step."""
    model, params = setup
    rng = np.random.default_rng(5)
    b = ContinuousBatcher(CFG, params, slots=1, capacity=32)
    for i in range(3):
        p = rng.integers(0, CFG.vocab_size, 5).astype(np.int32)
        b.submit(Request(uid=i, prompt=p, max_new_tokens=1))
    b.step()
    assert len(b.finished) == 3               # all drained in one step
    assert all(len(r.generated) == 1 for r in b.finished)


def test_batcher_slot_reuse(setup):
    model, params = setup
    rng = np.random.default_rng(2)
    b = ContinuousBatcher(CFG, params, slots=2, capacity=48)
    for i in range(6):
        p = rng.integers(0, CFG.vocab_size, 5).astype(np.int32)
        b.submit(Request(uid=i, prompt=p, max_new_tokens=4))
    steps = b.run_to_completion()
    assert len(b.finished) == 6
    # 2 slots, 6 requests x 4 tokens => at least 3 waves of decode steps
    assert steps >= 9
