"""Adjacency-sharded contractions (``repro.distributed.contract``).

The tentpole invariant: with the adjacency row-sharded over the
``("data",)`` mesh, every hom count and free-hom cut tensor is
bit-for-bit equal to the single-device engine — the collective route
changes where the einsums run and where the tensors live, never a
single bit of what they compute — and the dense n x n adjacency never
materialises anywhere (asserted via the engine's lazy ``_A_dense``
staying unbuilt and the ``einsum-sharded`` route annotations).

Multi-device checks spawn subprocesses with forced host devices, same
as ``test_mesh_join``; cache/cost checks are pure host code.
"""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, devices: int = 8):
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=SRC)
    return subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, env=env,
                          timeout=560)


_DIFFERENTIAL = """
    import numpy as np
    from repro.core.counting import CountingEngine
    from repro.core.pattern import Pattern, chain, clique, cycle
    from repro.distributed import meshes
    from repro.graph import generators as gen

    mesh = meshes.data_mesh()
    d = meshes.num_shards(mesh)

    for n in (96, 97):                    # 97: not divisible by any d > 1
        for num_labels in (0, 3):
            g = gen.erdos_renyi(n, 6.0, seed=3, num_labels=num_labels)
            ref = CountingEngine(g)
            sh = CountingEngine(g, mesh=mesh)
            pats = [cycle(4), chain(4), clique(3), chain(3)]
            if num_labels:
                pats += [Pattern(4, cycle(4).edges, labels=(0, 1, 2, 0)),
                         Pattern(3, ((0, 1), (1, 2)), labels=(2, 0, 1))]
            for p in pats:
                for free in ((), (0,), (0, 1)):
                    free = tuple(v for v in free if v < p.n)
                    if free:
                        a = np.asarray(ref.hom_free_tensor(p, free))
                        b = np.asarray(sh.hom_free_tensor(p, free))
                        assert np.array_equal(a, b), \\
                            (n, num_labels, sorted(p.edges), free)
                    else:
                        assert ref.hom(p) == sh.hom(p), \\
                            (n, num_labels, sorted(p.edges))
            if d > 1:
                # the sharded engine never built a dense n x n adjacency
                assert sh._A_dense is None
                t = sh.hom_free_tensor(cycle(4), (0, 1))
                if n % d == 0:
                    # no padding -> the cut tensor stays sliced on axis 0
                    assert t.sharding.spec[0] == "data", t.sharding.spec
    print("OK")
"""


@pytest.mark.slow
def test_sharded_contract_matches_single_device_8dev():
    """The acceptance matrix at 8 devices: labelled/unlabelled,
    divisible and indivisible n, scalar homs and free tensors."""
    r = _run(_DIFFERENTIAL, devices=8)
    assert "OK" in r.stdout, r.stdout + r.stderr


def test_sharded_contract_matches_single_device_1dev():
    """Same matrix at 1 device: a 1-device mesh binds to nothing (the
    engine keeps the single-device route) and everything still agrees."""
    r = _run(_DIFFERENTIAL, devices=1)
    assert "OK" in r.stdout, r.stdout + r.stderr


def test_compiled_plan_contract_route_sharded():
    """compile(mesh=): Contract nodes take the ``einsum-sharded`` route,
    counts match the meshless plan bit-for-bit, and the mesh-bound
    engine never materialises the dense adjacency."""
    r = _run("""
        from repro import compiler, obs
        from repro.core.counting import CountingEngine
        from repro.core.motifs import motif_patterns
        from repro.distributed import meshes
        from repro.graph import generators as gen

        mesh = meshes.data_mesh()
        g = gen.erdos_renyi(96, 7.0, seed=2)
        pats = motif_patterns(4)
        eng = CountingEngine(g, mesh=mesh)
        tr = obs.Tracer()
        cp = compiler.compile(pats, g, counter=eng, cache=False, mesh=mesh)
        cp.tracer = tr
        base = compiler.compile(pats, g, counter=CountingEngine(g),
                                cache=False)
        for p in pats:
            assert cp.count(p) == base.count(p), sorted(p.edges)

        routes = {}
        def walk(s):
            r = s.attrs.get("route")
            if r:
                routes[r] = routes.get(r, 0) + 1
            for c in s.children:
                walk(c)
        for root in tr.roots:
            walk(root)
        assert "einsum-sharded" in routes, routes
        assert "einsum" not in routes, routes   # nothing fell back
        assert eng._A_dense is None
        print("OK")
    """)
    assert "OK" in r.stdout, r.stdout + r.stderr


def test_sharded_dense_keep_join_matches_oracle():
    """``sharded_dense_join_keep`` (the guard-refusal keep-axis route)
    against a plain-numpy oracle: k in {2, 3}, every keep axis,
    divisible and padding n."""
    r = _run("""
        import numpy as np
        from repro.distributed import cutjoin as dcj, meshes

        mesh = meshes.data_mesh()
        rng = np.random.default_rng(5)
        for n in (40, 37):                       # 37: padding path
            for k in (2, 3):
                Ms = [rng.integers(0, 5, size=(n,) * k).astype(np.float64)
                      for _ in range(2)]
                stack = np.stack(Ms)
                for keep in range(k):
                    red = tuple(a + 1 for a in range(k) if a != keep)
                    ref = np.sum(np.prod(stack, axis=0), axis=tuple(
                        a for a in range(k) if a != keep))
                    got = dcj.sharded_dense_join_keep(Ms, k, keep=keep,
                                                      mesh=mesh)
                    assert np.array_equal(got, ref), (n, k, keep)
        print("OK")
    """)
    assert "OK" in r.stdout, r.stdout + r.stderr


def test_keep_axis_guard_refusal_routes_sharded():
    """Keep-axis joins that can't take the kernel route under a mesh
    (here: kernel tier disabled outright) land on ``xla-sharded-keep``
    — not the old wholesale single-device fallback — and the per-vertex
    counts stay bit-for-bit."""
    r = _run("""
        import numpy as np
        from repro import compiler, obs
        from repro.api.local import plan_vertex_counts
        from repro.core.counting import CountingEngine
        from repro.core.pattern import chain
        from repro.distributed import meshes
        from repro.graph import generators as gen

        mesh = meshes.data_mesh()
        g = gen.erdos_renyi(96, 8.0, seed=2)
        p = chain(4)
        tr = obs.Tracer()
        cp = compiler.compile(p, g, counter=CountingEngine(g, mesh=mesh),
                              cache=False, mesh=mesh, local=True,
                              cutjoin_kernel=False)
        cp.tracer = tr
        ref = compiler.compile(p, g, counter=CountingEngine(g),
                               cache=False, local=True,
                               cutjoin_kernel=False)
        assert np.array_equal(plan_vertex_counts(cp, p),
                              plan_vertex_counts(ref, p))
        routes = set()
        def walk(s):
            routes.add(s.attrs.get("route"))
            for c in s.children:
                walk(c)
        for root in tr.roots:
            walk(root)
        assert "xla-sharded-keep" in routes, routes
        assert "xla-keep" not in routes, routes
        # the new route is not a fallback — no shard_fallbacks counted
        snap = obs.snapshot()
        assert not any("shard_fallbacks" in k for k in snap), snap
        print("OK")
    """)
    assert "OK" in r.stdout, r.stdout + r.stderr


def test_shard_fallback_counters_split_by_phase():
    """One fallback per phase: a fresh compile that serves a count
    increments ``..._compile`` only; re-serving the cached plan
    increments ``..._execute`` only — no double counting."""
    r = _run("""
        from repro import compiler, obs
        from repro.compiler import PlanCache
        from repro.core.counting import CountingEngine
        from repro.core.pattern import cycle
        from repro.distributed import meshes
        from repro.graph import generators as gen

        mesh = meshes.data_mesh()
        g = gen.erdos_renyi(6, 2.0, seed=1)       # n=6 < 8 -> small-n
        p = cycle(4)
        cache = PlanCache()
        c1 = compiler.compile(p, g, cache=cache, mesh=mesh).count(p)
        snap = obs.snapshot()
        compile_hits = snap.get("cutjoin.shard_fallbacks_compile", {})
        assert sum(compile_hits.values()) == 1, snap
        assert "cutjoin.shard_fallbacks_execute" not in snap, snap

        cp2 = compiler.compile(p, g, cache=cache, mesh=mesh)
        assert cp2.from_cache
        assert cp2.count(p) == c1
        snap = obs.snapshot()
        assert sum(snap["cutjoin.shard_fallbacks_compile"].values()) == 1
        assert sum(snap["cutjoin.shard_fallbacks_execute"].values()) == 1
        print("OK")
    """)
    assert "OK" in r.stdout, r.stdout + r.stderr


def test_plan_cache_mesh_device_compat():
    """A plan compiled with a mesh must not be served to a meshless
    caller, nor a meshless plan to a mesh-bound caller; same-mesh hits
    still serve."""
    r = _run("""
        from repro import compiler
        from repro.compiler import PlanCache
        from repro.core.pattern import cycle
        from repro.distributed import meshes
        from repro.graph import generators as gen

        mesh = meshes.data_mesh()
        g = gen.erdos_renyi(64, 6.0, seed=1)
        p = cycle(4)
        cache = PlanCache()
        a = compiler.compile(p, g, cache=cache, mesh=mesh)
        assert not a.from_cache
        assert a.plan.meta["mesh_devices"] == 8

        b = compiler.compile(p, g, cache=cache)          # meshless
        assert not b.from_cache                          # recompiled
        assert b.plan.meta["mesh_devices"] == 1

        c = compiler.compile(p, g, cache=cache, mesh=mesh)
        assert not c.from_cache                          # overwrite was meshless

        d2 = compiler.compile(p, g, cache=cache, mesh=mesh)
        assert d2.from_cache                             # same config serves
        assert a.count(p) == b.count(p) == d2.count(p)
        print("OK")
    """)
    assert "OK" in r.stdout, r.stdout + r.stderr


def test_config_compatible_unit():
    """The compat predicate itself, including legacy entries that
    predate the ``mesh_devices`` field (valid for meshless callers
    only)."""
    from repro.compiler import config_compatible
    from repro.compiler.ir import Plan

    plan = Plan()
    plan.meta.update({"budget": 1 << 27, "max_cutjoin_cut": 3,
                      "mesh_devices": 8})
    ok = dict(budget=1 << 27, max_cutjoin_cut=3)
    assert config_compatible(plan, **ok, mesh_devices=8)
    assert not config_compatible(plan, **ok, mesh_devices=1)
    assert not config_compatible(plan, **ok, mesh_devices=4)
    assert not config_compatible(plan, budget=1, max_cutjoin_cut=3,
                                 mesh_devices=8)

    legacy = Plan()                       # written before the field existed
    legacy.meta.update({"budget": 1 << 27, "max_cutjoin_cut": 3})
    assert config_compatible(legacy, **ok, mesh_devices=1)
    assert not config_compatible(legacy, **ok, mesh_devices=8)


def test_contract_cost_devices_term():
    """More devices: per-device contraction work shrinks, a log2(d)
    per-step collective surcharge appears — never free, and a 1-device
    mesh prices identically to no mesh."""
    import math

    from repro.compiler.costing import _contract_cost
    from repro.compiler.ir import Contract
    from repro.core import homomorphism as H
    from repro.core.apct import APCT
    from repro.core.pattern import cycle
    from repro.graph import generators as gen

    g = gen.erdos_renyi(512, 6.0, seed=1)
    apct = APCT(g)
    p = cycle(4)
    node = Contract(key="c", pattern=p, order=H.greedy_plan(p, ()))
    budget = 1 << 27
    c1 = _contract_cost(node, apct, g.n, budget)
    assert c1 == _contract_cost(node, apct, g.n, budget, devices=1)
    c8 = _contract_cost(node, apct, g.n, budget, devices=8)
    assert c8 < c1                       # sharding pays off at n=512
    # the collective term is never waived: with tiny per-device work the
    # log2(d) surcharge dominates
    tiny = gen.erdos_renyi(8, 2.0, seed=2)
    t8 = _contract_cost(node, APCT(tiny), tiny.n, budget, devices=8)
    assert t8 > math.log2(8)


def test_shard_check_covers_contract_nodes():
    """``shard-budget-overflow`` now reports Contract nodes whose
    per-shard residency (row block + widest replicated intermediate)
    exceeds the cap."""
    from repro import analysis, compiler
    from repro.analysis import GraphInfo
    from repro.core.counting import CountingEngine
    from repro.core.pattern import cycle
    from repro.graph import generators as gen

    g = gen.erdos_renyi(24, 4.0, seed=13)
    cp = compiler.compile(cycle(4), g, counter=CountingEngine(g),
                          cache=False)
    info = GraphInfo.from_graph(g)
    res = analysis.shard_check(cp.plan, info, 4, budget=1)
    contract_keys = {k for k, n in cp.plan.nodes.items()
                     if type(n).__name__ == "Contract"}
    assert contract_keys, "plan has no Contract nodes?"
    flagged = {d.node for d in res.warnings
               if d.code == "shard-budget-overflow"}
    assert contract_keys & flagged, (contract_keys, flagged)
    # a sane budget flags nothing on this tiny plan
    res2 = analysis.shard_check(cp.plan, info, 4, budget=1 << 27)
    assert not [d for d in res2.warnings
                if d.code == "shard-budget-overflow"]
