"""Partial symmetry breaking: orbit detection and oriented counting."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import homomorphism as H
from repro.core import symmetry as SYM
from repro.core.pattern import Pattern, chain, clique, star
from repro.graph.generators import erdos_renyi

G = erdos_renyi(24, 4.0, seed=9)
A = jnp.asarray(G.dense_adjacency(np.float64, pad=False))


def test_orbit_detection():
    assert SYM.interchangeable_orbits(clique(3)) == [(0, 1, 2)]
    tt = Pattern(4, [(0, 1), (0, 2), (1, 2), (2, 3)])
    assert (0, 1) in SYM.interchangeable_orbits(tt)
    assert SYM.interchangeable_orbits(star(4)) == [(1, 2, 3)]
    assert SYM.interchangeable_orbits(chain(4)) == []


@pytest.mark.parametrize("p,orbit", [
    (clique(3), (0, 1, 2)),
    (clique(4), (0, 1, 2, 3)),
    (Pattern(4, [(0, 1), (0, 2), (1, 2), (2, 3)]), (0, 1)),
    (Pattern(5, [(0, 1), (0, 2), (1, 2), (2, 3), (3, 4)]), (0, 1)),
])
def test_oriented_equals_hom_on_clique_orbits(p, orbit):
    h = float(H.hom_count(p, A))
    o = float(SYM.hom_oriented(p, A, orbit))
    assert abs(h - o) < 1e-6 * max(1.0, abs(h))


def test_oriented_independent_orbit_distinct_semantics():
    """For independent orbits, the oriented count equals hom restricted to
    pairwise-distinct orbit assignments (what decomposed inj needs)."""
    p = star(4)
    n = A.shape[0]
    off = 1.0 - jnp.eye(n, dtype=A.dtype)
    aug = Pattern(4, list(p.edges) + [(1, 2), (1, 3), (2, 3)])
    et = {(1, 2): off, (1, 3): off, (2, 3): off}
    ref = float(H.hom_count(aug, A, edge_tensors=et))
    got = float(SYM.hom_oriented(p, A, (1, 2, 3)))
    assert abs(ref - got) < 1e-6 * max(1.0, abs(ref))


def test_full_sb_incompatible_with_decomposition():
    """Fig 25: restricting each subpattern independently breaks the join —
    the oriented subpattern tensors no longer multiply to the unoriented
    product."""
    n = A.shape[0]
    U = jnp.triu(A, 1)
    # 3-chain with cut at the middle vertex: two edge subpatterns
    # unrestricted: M(v) = deg(v); restricted: M_<(v) counts only larger ids
    deg = jnp.sum(A, axis=1)
    m_lt = jnp.sum(U, axis=1)
    joined_full = float(jnp.sum(deg * deg))     # wedges from the join
    joined_broken = float(jnp.sum(m_lt * m_lt))
    assert joined_broken < joined_full          # under-counts => incompatible


def test_psb_speedup_factor():
    assert SYM.psb_speedup_estimate(clique(3), (0, 1, 2)) == 6.0
