"""Training substrate: optimizer, checkpoint/restart, fault tolerance,
compression, data pipeline, and end-to-end loss descent."""
import os
import signal

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import reduced_config
from repro.configs.registry import get_config
from repro.train import checkpoint as ckpt
from repro.train import compression as comp
from repro.train import optimizer as opt_mod
from repro.train.data import TokenPipeline
from repro.train.fault_tolerance import (PreemptionGuard, StepWatchdog,
                                         resume_or_init)
from repro.train.train_step import init_state, make_train_step

CFG = reduced_config(get_config("qwen3-4b"), num_layers=2)
OPT = opt_mod.OptConfig(lr=1e-2, warmup_steps=2, total_steps=50)


def _batch(step, batch=4, seq=16):
    pipe = TokenPipeline(CFG.vocab_size, seq, batch, seed=1)
    return {k: jnp.asarray(v) for k, v in pipe.batch_at(step).items()}


def test_loss_decreases():
    state = init_state(CFG, OPT, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(CFG, OPT, 1))
    losses = []
    for i in range(25):
        state, m = step(state, _batch(i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.2, losses[::6]
    assert np.isfinite(losses).all()


def test_microbatching_matches_full_batch():
    state = init_state(CFG, OPT, jax.random.PRNGKey(0))
    b = _batch(0, batch=4)
    s1, m1 = jax.jit(make_train_step(CFG, OPT, 1))(state, b)
    state2 = init_state(CFG, OPT, jax.random.PRNGKey(0))
    s2, m2 = jax.jit(make_train_step(CFG, OPT, 2))(state2, b)
    assert abs(float(m1["ce"]) - float(m2["ce"])) < 5e-3
    p1 = jax.tree.leaves(s1["params"])[0]
    p2 = jax.tree.leaves(s2["params"])[0]
    np.testing.assert_allclose(np.asarray(p1), np.asarray(p2),
                               rtol=1e-2, atol=1e-4)


def test_optimizer_schedule():
    c = opt_mod.OptConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    assert float(opt_mod.schedule(c, jnp.asarray(0))) == 0.0
    assert abs(float(opt_mod.schedule(c, jnp.asarray(10))) - 1e-3) < 1e-9
    assert float(opt_mod.schedule(c, jnp.asarray(100))) <= 1e-3 * 0.11


def test_checkpoint_roundtrip_and_restart(tmp_path):
    state = init_state(CFG, OPT, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(CFG, OPT, 1))
    for i in range(3):
        state, _ = step(state, _batch(i))
    ckpt.save(tmp_path, 3, state)
    like = init_state(CFG, OPT, jax.random.PRNGKey(1))
    restored, s = ckpt.restore_latest(tmp_path, like)
    assert s == 3
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_checkpointer_and_latest(tmp_path):
    state = init_state(CFG, OPT, jax.random.PRNGKey(0))
    w = ckpt.AsyncCheckpointer(tmp_path)
    w.save(5, state)
    w.save(10, state)     # waits for previous
    w.wait()
    assert ckpt.latest_step(tmp_path) == 10


def test_crash_mid_save_keeps_previous(tmp_path):
    state = init_state(CFG, OPT, jax.random.PRNGKey(0))
    ckpt.save(tmp_path, 1, state)
    # simulate a crash: a stale .tmp directory from a dead writer
    (tmp_path / "step_2.tmp").mkdir()
    (tmp_path / "step_2.tmp" / "arr_0.npy").write_bytes(b"garbage")
    assert ckpt.latest_step(tmp_path) == 1
    restored, s = ckpt.restore_latest(tmp_path, state)
    assert s == 1


def test_resume_or_init(tmp_path):
    state = init_state(CFG, OPT, jax.random.PRNGKey(0))
    got, start = resume_or_init(tmp_path, lambda: state)
    assert start == 0
    ckpt.save(tmp_path, 7, state)
    got, start = resume_or_init(tmp_path, lambda: state)
    assert start == 7


def test_preemption_guard():
    g = PreemptionGuard(signals=(signal.SIGUSR1,))
    assert not g.requested
    os.kill(os.getpid(), signal.SIGUSR1)
    assert g.requested
    g.restore_handlers()


def test_step_watchdog_flags_stragglers():
    import time
    w = StepWatchdog(threshold_x=3.0, window=16)
    for i in range(8):
        w.start()
        time.sleep(0.003)
        w.stop(i)
    w.start()
    time.sleep(0.1)
    w.stop(99)
    assert w.straggler_events and w.straggler_events[0][0] == 99


def test_int8_compression_error_feedback():
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)}
    r = comp.init_residuals(g)
    approx, r = comp.compress_with_feedback(g, r, "int8")
    rel = float(jnp.linalg.norm(approx["w"] - g["w"])
                / jnp.linalg.norm(g["w"]))
    assert rel < 0.02
    # error feedback: residual carries exactly the quantisation error
    np.testing.assert_allclose(np.asarray(r["w"]),
                               np.asarray(g["w"] - approx["w"]), atol=1e-6)
    # accumulated over steps, the mean of compressed grads approaches the
    # true gradient (feedback cancels bias)
    total = jnp.zeros_like(g["w"])
    r = comp.init_residuals(g)
    for _ in range(8):
        a, r = comp.compress_with_feedback(g, r, "int8")
        total = total + a["w"]
    np.testing.assert_allclose(np.asarray(total / 8), np.asarray(g["w"]),
                               atol=5e-3)


def test_topk_compression_wire_bytes():
    x = jnp.asarray(np.random.default_rng(0).normal(size=(1024,)),
                    jnp.float32)
    assert comp.wire_bytes(x, "int8") < 0.3 * comp.wire_bytes(x, "none")
    assert comp.wire_bytes(x, "topk", frac=0.01) < 0.03 * \
        comp.wire_bytes(x, "none")


def test_data_pipeline_deterministic_and_seekable():
    p1 = TokenPipeline(1000, 32, 4, seed=3)
    p2 = TokenPipeline(1000, 32, 4, seed=3)
    b5 = p1.batch_at(5)
    np.testing.assert_array_equal(b5["inputs"], p2.batch_at(5)["inputs"])
    assert not np.array_equal(b5["inputs"], p1.batch_at(6)["inputs"])
    assert b5["inputs"].shape == (4, 32)
    np.testing.assert_array_equal(b5["labels"][:, :-1], b5["inputs"][:, 1:])
