"""The |cut| = 3 tri-join tier: primitive numpy oracles over every
axis-subset factor mix, kernel-vs-XLA-vs-brute-force bit-for-bit
equivalence through the compiler (non-tile-multiple n, labelled graphs,
guard-fallback path), golden IR locks for axis-subset 3-cut plans, and
the factor-tensor budget story (over-budget 3-D factors price infinite
and the selection falls back).  Everything runs in interpret mode (CPU
CI)."""
import math

import numpy as np
import pytest

from repro import compiler
from repro.compiler import costing, frontend, lowering
from repro.compiler.ir import Contract, CutJoin, LocalCount, Plan, \
    ShrinkageCorrect, pattern_key
from repro.core.counting import CountingEngine, brute_force_edge_induced
from repro.core.decomposition import cutting_sets
from repro.core.pattern import Pattern, chain, clique, cycle
from repro.graph.generators import erdos_renyi, triangle_rich
from repro.kernels import ops

RNG = np.random.default_rng(17)

# 5-clique minus one edge: its only cutting set is the 3 shared vertices
# — the pattern class the tri tier exists for (every component adjacent
# to the whole cut, so both factors are genuinely 3-D)
K5_MINUS_EDGE = Pattern(5, [(u, v) for u in range(5)
                            for v in range(u + 1, 5) if (u, v) != (3, 4)])
# 6-cycle with cut {0, 2, 4}: three wedge components, each adjacent to
# only two cut vertices — the pair-tensor-only axis-subset form
SIX_CYCLE = cycle(6)

# every distinct-arity factor mix the axis-subset join can see,
# including uncovered axes (the join then counts the free range of the
# missing cut coordinate) and mixed 3-D/2-D/1-D stacks
AXIS_MIXES = [
    [(0, 1, 2)],
    [(0, 1, 2), (0, 1, 2)],
    [(0, 1), (1, 2), (0, 2)],
    [(0,), (1,), (2,)],
    [(0, 1), (2,)],
    [(0, 1, 2), (0, 1), (2,)],
    [(0, 2), (0, 2)],
    [(0, 2)],                            # axis 1 uncovered
    [(1,)],                              # axes 0 and 2 uncovered
]


def _oracle(factors, axes, n, distinct=True):
    """Dense numpy reference: broadcast product, pairwise-distinct mask."""
    prod = np.ones((n, n, n))
    for F, ax in zip(factors, axes):
        shape = tuple(n if a in ax else 1 for a in range(3))
        prod = prod * np.asarray(F, np.float64).reshape(shape)
    if distinct:
        x = np.arange(n)
        bad = ((x[:, None, None] == x[None, :, None])
               | (x[:, None, None] == x[None, None, :])
               | (x[None, :, None] == x[None, None, :]))
        prod = np.where(bad, 0.0, prod)
    return prod


# -- primitive: tri_reduce vs numpy over all axis mixes -----------------------------

@pytest.mark.parametrize("n", [7, 24, 130])
@pytest.mark.parametrize("axes", AXIS_MIXES,
                         ids=["-".join(map(str, a)).replace(", ", "")
                              for a in map(str, AXIS_MIXES)])
def test_tri_reduce_matches_numpy(n, axes):
    Fs = [RNG.integers(0, 5, size=(n,) * len(ax)).astype(np.float64)
          for ax in axes]
    for distinct in (True, False):
        want = _oracle(Fs, axes, n, distinct).sum()
        got = ops.cutjoin_reduce3(Fs, axes, n=n, distinct=distinct,
                                  interpret=True)
        assert got == want, (n, axes, distinct)


@pytest.mark.parametrize("keep", [0, 1, 2])
@pytest.mark.parametrize("axes", [[(0, 1, 2)], [(0, 1), (1, 2), (0, 2)],
                                  [(0, 1), (2,)], [(0, 2)]])
def test_tri_reduce_keep_matches_numpy(keep, axes):
    n = 29
    Fs = [RNG.integers(0, 5, size=(n,) * len(ax)).astype(np.float64)
          for ax in axes]
    want = _oracle(Fs, axes, n).sum(
        axis=tuple(a for a in range(3) if a != keep))
    got = ops.cutjoin_reduce3_keep(Fs, axes, keep=keep, n=n,
                                   interpret=True)
    assert got.shape == (n,) and np.array_equal(got, want), (axes, keep)


def test_tri_reduce_tile_padding():
    """n deliberately off the tile multiple with a small forced block:
    zero-padding must be count-preserving on every axis, covered or
    not."""
    n = 45
    for axes in ([(0, 1, 2)], [(0, 2)], [(1,)]):
        Fs = [RNG.integers(0, 5, size=(n,) * len(ax)).astype(np.float64)
              for ax in axes]
        want = _oracle(Fs, axes, n).sum()
        got = ops.cutjoin_reduce3(Fs, axes, n=n, block=16, interpret=True)
        assert got == want, axes


# -- golden-value equivalence through the compiler ----------------------------------

TRI_PATTERNS = [K5_MINUS_EDGE, SIX_CYCLE, chain(5), cycle(5),
                Pattern(6, [(0, 1), (1, 2), (2, 3), (3, 4), (4, 5),
                            (5, 0), (0, 3)])]


def _tri_counts(p, cut, g, eng):
    """(kernel count, XLA dense-mask count) for one 3-cut candidate."""
    cand = frontend.decomposed_candidate(p, cut, graph_n=g.n, max_cut=3)
    if cand is None:
        return None
    plan = frontend.assemble([(p, cand)])
    kern = lowering.lower(plan, g, counter=eng, cutjoin_kernel=True)
    xla = lowering.lower(plan, g, counter=eng, cutjoin_kernel=False)
    return kern.count(p), xla.count(p)


@pytest.mark.parametrize("p", TRI_PATTERNS)
def test_tri_kernel_matches_xla_and_brute_force(p):
    """Every 3-cut candidate: tri kernel == XLA dense-mask oracle
    bit-for-bit, both == brute force."""
    g = erdos_renyi(18, 7.0, seed=3)
    eng = CountingEngine(g)
    want = brute_force_edge_induced(g, p)
    ran = 0
    for cut in cutting_sets(p):
        if len(cut) != 3:
            continue
        got = _tri_counts(p, cut, g, eng)
        if got is None:
            continue
        kern, xla = got
        assert kern == xla, (p, sorted(cut))          # bit-for-bit
        assert kern == want, (p, sorted(cut))
        ran += 1
    assert ran                                        # at least one cut ran


def test_tri_kernel_non_tile_multiple_labelled_graph():
    """Graph n far from the tile multiple AND vertex-labelled: the
    (unlabelled-pattern) tri tier is label-free, padding is
    count-preserving."""
    g = triangle_rich(37, 5, seed=5, num_labels=3)
    assert g.labels is not None
    eng = CountingEngine(g)
    for p in (SIX_CYCLE, chain(5)):
        want = brute_force_edge_induced(g, p)
        for cut in cutting_sets(p):
            if len(cut) != 3:
                continue
            got = _tri_counts(p, cut, g, eng)
            if got is None:
                continue
            kern, xla = got
            assert kern == xla == want, (p, sorted(cut))


def test_tri_kernel_labelled_pattern():
    """Labelled patterns decompose through the axis-subset tier too:
    the label mask lives inside each factor (and inside the cut-edge
    pair factors)."""
    g = erdos_renyi(22, 5.0, seed=7, num_labels=2)
    eng = CountingEngine(g)
    p = Pattern(6, [(0, 1), (1, 2), (2, 3), (3, 4), (4, 5), (5, 0)],
                (0, 1, 0, 1, 0, 1))
    want = brute_force_edge_induced(g, p)
    ran = 0
    for cut in cutting_sets(p):
        if len(cut) != 3:
            continue
        got = _tri_counts(p, cut, g, eng)
        if got is not None:
            kern, xla = got
            assert kern == xla == want, sorted(cut)
            ran += 1
    assert ran


def test_tri_guard_fallback_exact():
    """Factor magnitudes beyond the f32 chunk guard: the tri join must
    detect it (cutjoin_exact_block -> None) and the lowered plan still
    returns the exact count through the f64 XLA path."""
    n = 12
    big = float(1 << 30)
    Fs = [np.full((n, n), big), np.full((n, n, n), 3.0)]
    axes = [(0, 1), (0, 1, 2)]
    assert ops.cutjoin_exact_block(Fs) is None
    want = _oracle(Fs, axes, n).sum()
    # the compiled route: a plan whose factors exceed the guard falls
    # back inside _eval_cutjoin — emulate by checking the dense oracle
    # agrees with the kernel run at force-disabled guard awareness
    got = ops.cutjoin_reduce3([np.full((n, n), 7.0), Fs[1]],
                              [(0, 1), (0, 1, 2)], n=n, interpret=True)
    assert got == _oracle([np.full((n, n), 7.0), Fs[1]],
                          axes, n).sum()


def test_compile_commits_tri_plan_and_matches_direct():
    """``compile`` with the default ``max_cutjoin_cut=3`` commits a
    3-cut plan for a pattern whose only cutting set has three vertices,
    and the count equals the legacy direct path bit-for-bit."""
    g = erdos_renyi(18, 9.0, seed=3)
    p = K5_MINUS_EDGE
    assert {len(c) for c in cutting_sets(p)} == {3}
    cp = compiler.compile((p,), g, cache=False)
    meta_cut = cp.plan.meta["cuts"][pattern_key(p)]
    assert meta_cut is not None and len(meta_cut) == 3
    join = next(n for n in cp.plan.nodes.values()
                if isinstance(n, CutJoin))
    assert join.cut_size == 3
    want = CountingEngine(g).edge_induced(p)
    assert cp.count(p) == want and want > 0


# -- golden IR locks ----------------------------------------------------------------

def test_golden_tri_plan_six_cycle():
    """6-cycle, cut {0, 2, 4}: three wedge components each adjacent to
    two cut vertices -> three PAIR factors covering the three axis
    pairs, no cut-cut edge factors, no 3-D factor anywhere."""
    p = SIX_CYCLE
    cand = frontend.decomposed_candidate(p, frozenset({0, 2, 4}),
                                         graph_n=24, max_cut=3)
    assert cand is not None and cand.style == "decomposed-subset"
    plan = frontend.assemble([(p, cand)])
    join = next(n for n in plan.nodes.values() if isinstance(n, CutJoin))
    assert join.cut_size == 3
    assert sorted(join.axes) == [(0, 1), (0, 2), (1, 2)]
    # every factor tensor is at most 2-D: Contract free tuples of len 2
    for node in plan.nodes.values():
        if isinstance(node, Contract) and node.free:
            assert len(node.free) <= 2
    out = plan.nodes[plan.output_for(p)]
    assert isinstance(out, ShrinkageCorrect)
    assert out.divisor == p.aut_order() == 12
    # distant-cut collisions are shrinkage terms now: corrections exist
    assert len(out.corrections) >= 1


def test_golden_tri_plan_k5_minus_edge():
    """5-clique minus an edge, cut {0, 1, 2}: both components adjacent
    to the whole cut -> two full 3-D factors, classic shrinkage only."""
    p = K5_MINUS_EDGE
    cand = frontend.decomposed_candidate(p, frozenset({0, 1, 2}),
                                         graph_n=24, max_cut=3)
    plan = frontend.assemble([(p, cand)])
    join = next(n for n in plan.nodes.values() if isinstance(n, CutJoin))
    # two vertex components plus the three cut-cut edges as pair factors
    assert join.axes is not None
    assert sorted(ax for ax in join.axes if len(ax) == 3) \
        == [(0, 1, 2), (0, 1, 2)]
    assert sorted(ax for ax in join.axes if len(ax) == 2) \
        == [(0, 1), (0, 2), (1, 2)]


def test_tri_plan_serialization_roundtrip():
    """axes annotations survive to_json/from_json (format v5), for both
    CutJoin and LocalCount nodes."""
    g = erdos_renyi(18, 7.0, seed=3)
    cp = compiler.compile((SIX_CYCLE,), g, cache=False, local=True)
    rt = Plan.from_dict(cp.plan.to_dict())
    assert rt == cp.plan
    joins = [n for n in rt.nodes.values() if isinstance(n, CutJoin)]
    locs = [n for n in rt.nodes.values() if isinstance(n, LocalCount)]
    assert joins and all(isinstance(j.axes, (tuple, type(None)))
                         for j in joins)
    cp2 = lowering.lower(rt, g)
    assert cp2.count(SIX_CYCLE) == cp.count(SIX_CYCLE)
    if locs:
        for loc in locs:
            assert np.array_equal(np.asarray(cp2.value(loc.key)),
                                  np.asarray(cp.value(loc.key)))


# -- the budget story ---------------------------------------------------------------

def _tri_join_node(p, cut, graph_n):
    cand = frontend.decomposed_candidate(p, cut, graph_n=graph_n,
                                         max_cut=3)
    return next(n for n in cand.nodes if isinstance(n, CutJoin))


def test_budget_refuses_3d_factors_but_not_pairs():
    """Σ factor elements > 4·budget prices a 3-D-factor tri join
    infinite; the pair-only form of the same width stays finite under
    the same budget (no unnecessary 3-D tensor is ever the reason a
    3-cut is refused)."""
    from repro.core.apct import APCT
    g = erdos_renyi(24, 4.0, seed=1)
    apct = APCT(g, num_samples=256)
    n_big = 4096                        # pretend-huge graph
    budget = 1 << 27                    # 2 * 4096^3 elems >> 4 * budget
    tri = _tri_join_node(K5_MINUS_EDGE, frozenset({0, 1, 2}), n_big)
    assert costing.node_cost(tri, apct, n_big, budget) == math.inf
    pair = _tri_join_node(SIX_CYCLE, frozenset({0, 2, 4}), n_big)
    assert costing.node_cost(pair, apct, n_big, budget) < math.inf
    # and at a size where the 3-D factors do fit, the tri join prices
    # finite too (512^3 * 2 <= 4 * 2^27)
    assert costing.node_cost(tri, apct, 512, budget) < math.inf


def test_budget_refusal_falls_back_to_narrower_plan():
    """End-to-end: when a pattern's only decomposition needs 3-D
    factors and they exceed the budget, the selection falls back to the
    dense Möbius route — the compiled plan carries no 3-cut join and
    still executes exactly.  budget=128 at n=8: one 8³ contraction
    intermediate fits (512 <= 4·budget) but the tri join's two 8³
    factors plus three 8² pair factors (1216 elements) do not."""
    g = erdos_renyi(8, 4.0, seed=11)
    p = K5_MINUS_EDGE                    # only cutting set has size 3
    cp_small = compiler.compile((p,), g, cache=False, budget=128)
    assert not any(isinstance(n, CutJoin)
                   for n in cp_small.plan.nodes.values())
    assert cp_small.count(p) == brute_force_edge_induced(g, p)
    # same pattern, budget where the 3-D factors fit: the tri plan wins
    cp_big = compiler.compile((p,), g, cache=False, budget=1 << 27)
    assert any(isinstance(n, CutJoin) and n.cut_size == 3
               for n in cp_big.plan.nodes.values())
    assert cp_big.count(p) == cp_small.count(p)
    # chain(5)'s 3-cuts are pair/vector-only formulations: the factor
    # budget must NOT refuse them even at the small budget
    tri = _tri_join_node(chain(5), frozenset({1, 2, 3}), 8)
    assert all(len(ax) <= 2 for ax in tri.axes)


def test_costing_prices_anchored_flat_mobius_finite():
    """The frontier_sizes tightening (actual free-axis participation):
    an anchored flat-Möbius candidate on a large graph must price
    finite — its einsums never materialise a width-3 intermediate."""
    from repro.core.apct import APCT
    g = erdos_renyi(24, 4.0, seed=1)
    apct = APCT(g, num_samples=256)
    cand = frontend.anchored_direct_candidate(chain(5), 0)
    n_huge = 1 << 14                    # n^3 would dwarf any budget
    cost = costing.candidate_cost(cand, apct, n_huge, {}, 1 << 27)
    assert cost < math.inf


def test_anchored_nodes_share_canonical_numbering():
    """Regression: LocalCount node keys embed cut/keep signatures in
    local vertex ids under the canonical pattern_key namespace.  When
    anchored candidates were built on the caller's (non-canonical)
    instance numbering, a 1-cut anchored node could collide with the
    canonical unanchored node — same key, different content — and
    first-wins CSE served one anchor another cut vertex's vector (the
    sums agreed, the entries didn't).  chain(5) is not self-canonical,
    so every anchored vector must still equal ``inj_free`` exactly on a
    graph large enough (n > 128) for the tile floors to steer selection
    toward the colliding 1-cut plan."""
    p = chain(5)
    assert p.canonical().edges != p.edges     # the precondition that bit
    g = erdos_renyi(150, 5.0, seed=0)
    eng = CountingEngine(g)
    for _ in range(2):                        # warm engine shifts choices
        cp = compiler.compile((p,), g, counter=eng, cache=False,
                              local=True)
        for orbit in p.vertex_orbits():
            got = cp.local_counts(p, orbit[0])
            want = eng.inj_free(p, orbit[0])
            assert np.array_equal(got, want), orbit[0]


def test_elimination_widths_thread_free_participation():
    """Free axes enter a step's width only when a factor carries them."""
    from repro.core import homomorphism as H
    p = chain(6)
    order = H.greedy_plan(p, (0,))
    widths = dict(H.elimination_widths(p, order, free=(0,)))
    # interior chain eliminations touch two neighbours at most; the old
    # estimate would report 3 everywhere (frontier + the free axis)
    assert max(widths.values()) == 2
    # K4 with three free axes: the one elimination genuinely joins all
    # three free neighbours
    k4 = clique(4)
    widths = dict(H.elimination_widths(k4, H.greedy_plan(k4, (0, 1, 2)),
                                       free=(0, 1, 2)))
    assert widths == {3: 3}
